"""End-to-end driver: federated training of a reduced LM (~the '100M-class'
end-to-end requirement scaled to this CPU container) for a few hundred
client steps across rounds, with any registry architecture as the client
model.

  PYTHONPATH=src python examples/fl_train_lm.py --arch qwen2-0.5b --rounds 8
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--algorithm", default="fedavg")
    args = ap.parse_args()

    from repro.configs.registry import get_arch
    from repro.core import (ClientStateManager, ParrotServer,
                            SequentialExecutor, make_algorithm)
    from repro.data import make_lm_clients
    from repro.models import lm

    cfg = get_arch(args.arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)

    def loss_fn(p, batch):
        return lm.loss_and_aux(p, batch, cfg)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    data = make_lm_clients(60, vocab=cfg.vocab_size, seq_len=32,
                           batch_size=4, mean_samples=8, seed=0)
    algo = make_algorithm(args.algorithm, grad_fn, lr=0.1, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm) for k in range(4)]
    server = ParrotServer(params=params, algorithm=algo, executors=execs,
                          data_by_client=data, clients_per_round=12, seed=0)

    eval_batch = {
        "inputs": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
    }
    for _ in range(args.rounds):
        m = server.run_round()
        loss = float(lm.loss_and_aux(server.params, eval_batch, cfg))
        print(f"round {m.round}: clients={m.n_clients} "
              f"makespan={m.makespan:.2f}s eval_loss={loss:.4f}")
    print("done — federated LM training via Parrot on", cfg.name)


if __name__ == "__main__":
    main()
