"""Trace a heterogeneous round on all three engines (DESIGN.md §13).

Runs a few rounds of the same heterogeneous workload — ``dynamic_env``
compute drift plus a constrained uniform uplink — under BSP, semi-sync and
async with ``telemetry=True``, exports one Chrome-trace/Perfetto JSON per
engine (open in https://ui.perfetto.dev or chrome://tracing), and prints
the per-executor busy/comm/idle fractions the span tracer derived.  The
utilization table is the paper's "computing utility" argument in one
screen: the BSP barrier idles every fast lane until the straggler lands;
semi-sync's deadline and async's pipeline reclaim that time.

  PYTHONPATH=src python examples/trace_round.py [--rounds N] [--out DIR]
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (ClientStateManager, NetworkModel, ParrotServer,
                        SequentialExecutor, TickTimer, make_algorithm,
                        validate_trace)
from repro.core.executor import dynamic_env
from repro.data import make_classification_clients

K = 4
ENGINES = [
    ("bsp", "bsp", {}),
    ("semi-sync", "semi-sync", {"deadline_frac": 0.7, "over_select": 1.2,
                                "chunk_size": 4}),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 4}),
]


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def build(engine, opts, rounds):
    grad_fn = jax.jit(jax.value_and_grad(_loss_fn))
    params = {"w": jnp.zeros((16, 4)), "b": jnp.zeros((4,))}
    data = make_classification_clients(60, dim=16, n_classes=4,
                                       mean_samples=40, batch_size=10,
                                       seed=1)
    algo = make_algorithm("fedavg", grad_fn, lr=0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=dynamic_env(K, rounds),
                                timer=TickTimer(1.0)) for k in range(K)]
    net = NetworkModel.uniform(uplink_bps=2e5, downlink_bps=1e6,
                               latency_s=0.05)
    return ParrotServer(params=params, algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=16,
                        round_engine=engine, engine_opts=opts,
                        network=net, telemetry=True, seed=7)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--out", default=None,
                    help="trace output directory (default: a temp dir)")
    args = ap.parse_args()
    out = args.out or tempfile.mkdtemp(prefix="parrot_traces_")

    print(f"{'engine':<10} {'exec':>4} {'busy':>7} {'comm':>7} {'idle':>7}")
    for name, engine, opts in ENGINES:
        srv = build(engine, opts, args.rounds)
        for _ in range(args.rounds):
            m = srv.run_round()
        path = f"{out}/trace_{name.replace('-', '_')}.json"
        srv.telemetry.tracer.export(path)
        errors = validate_trace(path)
        for k, u in sorted(m.extra["utilization"].items()):
            tag = name if k == 0 else ""
            print(f"{tag:<10} {k:>4} {u['busy_frac']:>6.1%} "
                  f"{u['comm_frac']:>6.1%} {u['idle_frac']:>6.1%}")
        status = "ok" if not errors else f"{len(errors)} violations"
        print(f"{'':<10} trace -> {path} ({status}, "
              f"{len(srv.telemetry.tracer.spans)} spans)")
    print("\nopen the traces in https://ui.perfetto.dev or chrome://tracing")


if __name__ == "__main__":
    main()
