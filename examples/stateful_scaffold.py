"""Stateful FL at scale: SCAFFOLD over 1000 clients with a memory-bounded
client state manager (paper §3.4), fault injection, checkpoint + resume.

Shows:
  - control variates held by the tiered state store (watch the spill stats)
  - an executor failing mid-round and the system recovering (elastic K)
  - checkpoint/restart producing the identical model

  PYTHONPATH=src python examples/stateful_scaffold.py
"""
import os
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_latest
from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm)
from repro.data import make_classification_clients


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return jnp.mean(lse - gold)


grad_fn = jax.jit(jax.value_and_grad(loss_fn))
params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}

work = tempfile.mkdtemp(prefix="parrot_scaffold_")
data = make_classification_clients(1000, dim=16, n_classes=8,
                                   mean_samples=30, seed=0)
algo = make_algorithm("scaffold", grad_fn, lr=0.1)

# state budget ~ K states: everything else spills to disk (O(s_d K) memory)
sm = ClientStateManager(os.path.join(work, "state"),
                        memory_budget_bytes=8 * 2048)
executors = [SequentialExecutor(k, algo, state_manager=sm) for k in range(8)]
executors[5].fail_at = (3, 2)      # executor 5 dies in round 3

server = ParrotServer(
    params=params, algorithm=algo, executors=executors, data_by_client=data,
    clients_per_round=50,
    checkpoint_manager=CheckpointManager(os.path.join(work, "ckpt"),
                                         every_rounds=2),
    seed=0)

for _ in range(6):
    m = server.run_round()
    print(f"round {m.round}: K={m.n_executors} failures={m.failures} "
          f"state_mem={sm.memory_bytes / 1e3:.0f}KB "
          f"state_disk={sm.disk_bytes() / 1e6:.1f}MB "
          f"spills={sm.stats['spills']}")

print("\nsimulating a crash + restart ...")
algo2 = make_algorithm("scaffold", grad_fn, lr=0.1)
sm2 = ClientStateManager(os.path.join(work, "state2"),
                         memory_budget_bytes=8 * 2048)
execs2 = [SequentialExecutor(k, algo2, state_manager=sm2) for k in range(7)]
server2 = ParrotServer(params=params, algorithm=algo2, executors=execs2,
                       data_by_client=data, clients_per_round=50, seed=0)
restored = restore_latest(server2, os.path.join(work, "ckpt"))
print(f"restored at round {restored}; continuing 2 more rounds")
for _ in range(2):
    m = server2.run_round()
    print(f"round {m.round}: K={m.n_executors}")
print("diff vs pre-crash params:",
      float(jnp.max(jnp.abs(server2.params["w"] - server.params["w"]))))
