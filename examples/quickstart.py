"""Quickstart: simulate 100 federated clients on 4 executors with Parrot.

Demonstrates the core loop in ~40 lines: define a model + grad_fn, pick an
FL algorithm, build executors, run rounds.  Hierarchical aggregation,
scheduling and state management are on by default.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm)
from repro.data import make_classification_clients


# 1. A model is just params + a grad function.
def loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return jnp.mean(lse - gold)


grad_fn = jax.jit(jax.value_and_grad(loss_fn))
params = {"w": jnp.zeros((32, 10)), "b": jnp.zeros((10,))}

# 2. A federated dataset: 100 clients, naturally heterogeneous sizes.
data = make_classification_clients(100, dim=32, n_classes=10,
                                   partition="natural", seed=0)

# 3. Pick an algorithm (stateful SCAFFOLD works the same as FedAvg here —
#    the state manager handles the control variates transparently).
algo = make_algorithm("fedavg", grad_fn, lr=0.05, local_epochs=2)

# 4. Executors = the "devices" of the paper; K=4 simulates all 100 clients.
sm = ClientStateManager(tempfile.mkdtemp())
executors = [SequentialExecutor(k, algo, state_manager=sm) for k in range(4)]

server = ParrotServer(params=params, algorithm=algo, executors=executors,
                      data_by_client=data, clients_per_round=20, seed=0)

for r in range(10):
    m = server.run_round()
    print(f"round {m.round}: makespan={m.makespan:.3f}s "
          f"comm={m.comm_bytes / 1e3:.1f}KB trips={m.comm_trips}")

print("final |w|:", float(jnp.linalg.norm(server.params["w"])))
