"""Heterogeneous + unstable devices (paper Figs. 6, 9, 11 setting).

Simulates the Appendix-A protocol: fixed slowdown ratios (Hete. GPU) and
cosine-drift instability (Dyn. GPU), then compares round makespans under
  (a) no scheduling, (b) Parrot all-history, (c) Parrot Time-Window,
then the round-engine modes (DESIGN.md §3): BSP scheduling can only
work *around* stragglers; semi-sync and async hide them.  The final
section prices communication from a FedScale-style bandwidth trace
(DESIGN.md §9): a constrained lognormal uplink population makes the rounds
comm-bound, and top-k delta compression buys most of the makespan back.

  PYTHONPATH=src python examples/heterogeneous_cluster.py
"""
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm)
from repro.core.executor import dynamic_env, hetero_gpus
from repro.data import make_classification_clients


def loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["y"][:, None].astype(jnp.int32),
                               axis=-1)[:, 0]
    return jnp.mean(lse - gold)


grad_fn = jax.jit(jax.value_and_grad(loss_fn))
ROUNDS = 10


def run(name, policy, speed, window=0, engine="bsp", engine_opts=None,
        clients_per_round=40, network=None, compressor=None):
    params = {"w": jnp.zeros((32, 10)), "b": jnp.zeros((10,))}
    data = make_classification_clients(200, dim=32, n_classes=10,
                                       partition="quantity_skew",
                                       partition_arg=5.0, seed=0)
    algo = make_algorithm("fedavg", grad_fn, lr=0.05)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm, speed_model=speed)
             for k in range(8)]
    srv = ParrotServer(params=params, algorithm=algo, executors=execs,
                       data_by_client=data,
                       clients_per_round=clients_per_round,
                       scheduler_policy=policy, time_window=window,
                       round_engine=engine, engine_opts=engine_opts,
                       network=network, compressor=compressor, seed=0)
    ms = [srv.run_round().makespan for _ in range(ROUNDS)]
    err = [h.estimation_error for h in srv.history
           if np.isfinite(h.estimation_error)]
    print(f"{name:28s} mean_makespan={np.mean(ms[3:]):.4f}s "
          f"est_err={np.mean(err) if err else float('nan'):.3f}")
    return float(np.mean(ms[3:]))


print("== Hete. GPU (fixed ratios 0/0.5/1/3) ==")
hete = hetero_gpus({k: [0.0, 0.5, 1.0, 3.0][k % 4] for k in range(8)})
a = run("unscheduled", "none", hete)
b = run("parrot", "parrot", hete)
print(f"speedup: {a / b:.2f}x\n")

print("== Dyn. GPU (cosine drift) ==")
dyn = dynamic_env(8, ROUNDS)
run("unscheduled", "none", dyn)
run("parrot all-history", "parrot", dyn, window=0)
run("parrot time-window(2)", "parrot", dyn, window=2)

print("\n== Round engines under Dyn. GPU (same scheduler, 96/round) ==")
c = run("bsp barrier", "parrot", dyn, clients_per_round=96)
run("semi-sync (deadline 0.55)", "parrot", dyn, engine="semi-sync",
    clients_per_round=96,
    engine_opts={"deadline_frac": 0.55, "over_select": 1.2, "chunk_size": 4})
d = run("async (lambda=0.5)", "parrot", dyn, engine="async",
        clients_per_round=96,
        engine_opts={"staleness_lambda": 0.5, "chunk_size": 8})
print(f"async hides the straggler tail: {c / d:.2f}x shorter rounds")

print("\n== Bandwidth trace (lognormal uplinks, median 40 kbps) ==")
from repro.core import NetworkModel                       # noqa: E402
from repro.core.compression import make_compressor        # noqa: E402
from repro.data import synthesize_capacity_trace          # noqa: E402

net = NetworkModel.from_trace(synthesize_capacity_trace(
    200, seed=7, dist="lognormal", median_uplink_kbps=40.0))
e = run("comm-free (no network)", "parrot", hete)
f = run("constrained uplink", "parrot", hete, network=net)
g = run("constrained + topk(5%)", "parrot", hete, network=net,
        compressor=make_compressor("topk", 0.05))
print(f"comm turns makespan {f / max(e, 1e-12):.0f}x worse; "
      f"topk wins {f / max(g, 1e-12):.2f}x of it back")
