"""§Roofline: derive the three roofline terms per (arch × shape × mesh) cell
from the dry-run artifacts (results/dryrun/*.json).

  compute     = HLO_FLOPs_per_dev / peak_FLOPs            (197 TFLOP/s bf16)
  memory      = HLO_bytes_per_dev / HBM_bw                (819 GB/s)
  collective  = collective_bytes_per_dev / link_bw        (~50 GB/s/link)

FLOPs/bytes are the trip-count-aware parse of the post-SPMD HLO
(launch/hlo_analysis.py); collective bytes use ring-algorithm factors.  The
bytes term is an upper-ish estimate: the CPU partitioner materialises f32
dot outputs and layout copies a TPU would fuse, so we also report a fused
estimate (bytes_fused ≈ bytes × F32_FUSE_DISCOUNT) and classify the
bottleneck on the fused number.  MFU proxy = model-FLOPs time / dominant
term.
"""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, "src")

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
F32_FUSE_DISCOUNT = 0.5    # CPU HLO f32-materialisation vs TPU fusion

DRYRUN_DIR = os.path.join("results", "dryrun")
OUT_MD = os.path.join("results", "roofline.md")


def _attn_flops(cfg, B, S, ctx=None) -> float:
    """Causal attention matmul FLOPs (qk + pv), forward, whole model."""
    if cfg.family == "ssm":
        return 0.0
    ctx = ctx if ctx is not None else S
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    per_tok = 2.0 * 2.0 * cfg.n_heads * cfg.hd * ctx
    causal = 0.5 if (S > 1 and not cfg.sliding_window) else 1.0
    return per_tok * B * S * causal * cfg.n_layers


def model_flops_per_device(rec: dict) -> float:
    """Useful FLOPs: 6·N_active·D + 3·attn for train; 2·N·D + attn for
    prefill; 2·N·B + attn(ctx) for decode."""
    from repro.configs.base import shape_by_name
    from repro.configs.registry import get_arch
    cfg = get_arch(rec["arch"])
    n_active = rec.get("model", {}).get("n_active_params",
                                        cfg.n_active_params())
    s = shape_by_name(rec["shape"])
    n_dev = rec["n_devices"]
    B, S = s.global_batch, s.seq_len
    if s.kind == "train":
        return (6.0 * n_active * B * S + 3.0 * _attn_flops(cfg, B, S)) / n_dev
    if s.kind == "prefill":
        return (2.0 * n_active * B * S + _attn_flops(cfg, B, S)) / n_dev
    # decode: one new token attending over the S-long cache
    return (2.0 * n_active * B + _attn_flops(cfg, B, 1, ctx=S)) / n_dev


def min_memory_bytes_per_device(rec: dict) -> float:
    """HBM-traffic floor: weights streamed once per pass (train: fwd + bwd
    reads + grad write + update rw ≈ 5×), plus the KV-cache/state read for
    decode, plus remat-boundary activation traffic for train."""
    from repro.configs.base import shape_by_name
    from repro.configs.registry import get_arch
    cfg = get_arch(rec["arch"])
    s = shape_by_name(rec["shape"])
    n_dev = rec["n_devices"]
    p_local = 2.0 * cfg.n_params() / n_dev            # bf16
    B, S = s.global_batch, s.seq_len
    act = 2.0 * B * S * cfg.d_model * cfg.n_layers / n_dev
    if s.kind == "train":
        micro = max(cfg.train_microbatches, 1)
        return 5.0 * p_local * micro + 4.0 * act
    if s.kind == "prefill":
        return p_local + 3.0 * act
    # decode: weights + cache (k and v) read once, one slot written
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    cache = 2.0 * 2.0 * B * ctx * cfg.n_kv_heads * cfg.hd         * cfg.n_layers / n_dev
    return p_local + cache


def analyze_cell(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    flops = rec["flops_per_device"]
    bytes_ = rec["bytes_per_device"]
    coll = rec["collectives"]["total_bytes_per_device"]
    t_c = flops / PEAK_FLOPS
    t_m = bytes_ / HBM_BW
    t_m_fused = bytes_ * F32_FUSE_DISCOUNT / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m_fused, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    mb = min_memory_bytes_per_device(rec)
    # best achievable step = the binding USEFUL roofline (compute or memory)
    t_best = max(mf / PEAK_FLOPS, mb / HBM_BW)
    step = max(terms.values())
    return {
        "cell": rec["cell"],
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": "multipod" if rec.get("multi_pod") else "pod",
        "compute_s": t_c,
        "memory_s": t_m_fused,
        "memory_raw_s": t_m,
        "collective_s": t_x,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": t_best / step if step else 0.0,
        "step_s": step,
        "mem_temp_gb": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


RECOMMEND = {
    "compute": ("compute-bound: raise MFU via larger per-chip tiles / fewer "
                "remat recomputes / MoE dispatch-FLOP reduction"),
    "memory": ("memory-bound: fuse attention/norms (Pallas kernels), keep "
               "activations bf16, shrink logits chunks"),
    "collective": ("collective-bound: re-shard to cut all-gather/all-reduce "
                   "volume (head-TP vs seq-TP, vocab-parallel head, EP for "
                   "MoE), overlap collectives with compute"),
}


def load_all(dryrun_dir: str = DRYRUN_DIR) -> List[dict]:
    rows = []
    if not os.path.isdir(dryrun_dir):
        return rows
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(dryrun_dir, fn)) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({"cell": rec["cell"], "skipped": rec["reason"]})
            continue
        if rec.get("status") == "error":
            rows.append({"cell": rec["cell"],
                         "error": rec.get("error", "?")[:120]})
            continue
        out = analyze_cell(rec)
        if out:
            rows.append(out)
    return rows


def write_markdown(rows: List[dict], path: str = OUT_MD) -> str:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    lines = [
        "# Roofline table (single-pod 16x16 unless noted)",
        "",
        "| cell | compute s | memory s | collective s | dominant | useful "
        "FLOPs | roofline frac | temp GB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['cell']} | — | — | — | skipped | — | — | — |")
            continue
        if "error" in r:
            lines.append(f"| {r['cell']} | — | — | — | ERROR | — | — | — |")
            continue
        lines.append(
            f"| {r['cell']} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | **{r['dominant']}** | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
            f"{r['mem_temp_gb']:.1f} |")
    lines.append("")
    lines.append("Per-cell next move (rule-based from the dominant term):")
    for r in rows:
        if "dominant" in r:
            lines.append(f"- `{r['cell']}`: {RECOMMEND[r['dominant']]}")
    text = "\n".join(lines)
    with open(path, "w") as f:
        f.write(text + "\n")
    return text


def run() -> None:
    from benchmarks.common import emit
    rows = load_all()
    if not rows:
        emit("roofline/no_dryrun_artifacts", 0.0,
             "run python -m repro.launch.dryrun --all first")
        return
    write_markdown(rows)
    ok = [r for r in rows if "dominant" in r]
    for r in ok:
        emit(f"roofline/{r['cell']}", r["step_s"] * 1e6,
             f"dom={r['dominant']};frac={r['roofline_fraction']:.3f};"
             f"useful={r['useful_flops_ratio']:.2f}")
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        emit("roofline/worst_fraction", worst["step_s"] * 1e6,
             f"{worst['cell']}={worst['roofline_fraction']:.4f}")


if __name__ == "__main__":
    run()
