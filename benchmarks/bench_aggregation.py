"""Flat-buffer hierarchical-aggregation fold: throughput and dispatch count.

Compares the batched multi-client fold (one ``agg_weighted_sum`` dispatch
per micro-batch of C clients over the flatten-once buffer) against the
legacy per-leaf C=1 path (one padded dispatch per pytree leaf per client)
on a >=1M-parameter model — the dispatch-overhead hot-spot the flat layout
eliminates.  Reported per configuration: fold time per client (us), the
effective delta-streaming rate (GB/s), and kernel dispatches per client.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.aggregation import ClientResult, LocalAggregator, Op
from repro.kernels import ops as kops

# ~1.13M params over 10 leaves (one deliberately non-block-aligned)
_LEAF_SHAPES = {
    "w0": (256, 512), "b0": (512,),
    "w1": (512, 512), "b1": (512,),
    "w2": (512, 512), "b2": (512,),
    "w3": (512, 512), "b3": (512,),
    "head": (512, 400), "odd": (1031,),
}
_OPS = {"delta": Op.WEIGHTED_AVG}


def _clients(m: int, seed: int = 0):
    key = jax.random.PRNGKey(seed)
    out = []
    for i in range(m):
        leaves = {name: jax.random.normal(
                      jax.random.fold_in(key, i * 101 + j), shape,
                      jnp.bfloat16)
                  for j, (name, shape) in enumerate(_LEAF_SHAPES.items())}
        out.append(ClientResult({"delta": leaves}, _OPS,
                                weight=float(1 + i % 7)))
    return out


def run() -> None:
    M = 64
    results = _clients(M)
    n = sum(int(np.prod(s)) for s in _LEAF_SHAPES.values())
    delta_bytes = n * 2      # bf16 deltas are what streams from HBM

    def fold_per_leaf(rs):
        acc = {k: jnp.zeros(s, jnp.float32) for k, s in _LEAF_SHAPES.items()}
        for r in rs:
            for k in _LEAF_SHAPES:
                acc[k] = kops.agg_fold(acc[k], r.payload["delta"][k],
                                       r.weight)
        return acc

    def fold_flat(rs, B):
        agg = LocalAggregator(_OPS, use_kernel=True, micro_batch=B)
        for r in rs:
            agg.fold(r)
        return agg.partial()["sums"]["buffers"]

    # --- legacy per-leaf C=1 baseline ------------------------------------
    jax.block_until_ready(fold_per_leaf(results[:2]))      # warm the jits
    kops.reset_agg_dispatch_count()
    t0 = time.perf_counter()
    jax.block_until_ready(fold_per_leaf(results))
    dt = time.perf_counter() - t0
    d_leaf = kops.agg_dispatch_count() / M
    emit("agg_fold/per_leaf_C1", dt / M * 1e6,
         f"GBps={M * delta_bytes / dt / 1e9:.2f};"
         f"dispatches_per_client={d_leaf:.3f};n_params={n}")
    t_leaf = dt

    # --- batched flat-buffer fold at C in {1, 4, 16, 64} ------------------
    for B in (1, 4, 16, 64):
        jax.block_until_ready(fold_flat(results[:B], B))   # warm the jit
        kops.reset_agg_dispatch_count()
        t0 = time.perf_counter()
        jax.block_until_ready(fold_flat(results, B))
        dt = time.perf_counter() - t0
        dpc = kops.agg_dispatch_count() / M
        emit(f"agg_fold/flat_C{B}", dt / M * 1e6,
             f"GBps={M * delta_bytes / dt / 1e9:.2f};"
             f"dispatches_per_client={dpc:.4f};"
             f"dispatch_ratio_vs_per_leaf={d_leaf / dpc:.0f}x;"
             f"speedup_vs_per_leaf={t_leaf / dt:.2f}x")
