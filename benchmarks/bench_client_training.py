"""Compiled client-training engine: eager vs jit-scan vs jit-scan+vmap.

The simulator's true hot path is sequential client training (Algorithm 2,
``Device_Executes``): the eager reference path dispatches one un-jitted op
per pytree leaf per SGD step per client, so at scale the round is dominated
by Python/XLA dispatch rather than FLOPs.  This bench measures local-SGD
throughput (client-steps/sec) on a ~1.2M-parameter deep MLP (142 leaves —
an LM-like leaf count, the dispatch-bound regime the engine targets) with
FedProx (its per-step proximal correction is one more eager per-leaf
tree-map the engine fuses away), for three paths:

  eager      — ``FLAlgorithm.client_update`` (per-leaf eager tree ops)
  jit-scan   — ``ClientStepEngine.run_client`` (one compiled lax.scan per
               client over all tau local steps)
  vmap B     — ``ClientStepEngine.run_block`` (one vmapped compiled scan
               per block of B clients) at B in {1, 4, 16}

Reported per path: client-steps/sec, speedup vs eager, and host dispatches
per client (compiled calls for the engine; python-level op issues for the
eager path, tau x (grad call + ~3 ops per leaf: proximal hook + update)).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import ClientData, make_algorithm
from repro.core.client_step import engine_for

# ~1.2M params over 142 leaves: deep narrow MLP
_DIMS = [128] * 71 + [400]
_BS, _NB, _M = 4, 8, 16          # batch size, batches/client, clients


def _mlp_params(seed=0):
    key = jax.random.PRNGKey(seed)
    p = {}
    for i, (a, b) in enumerate(zip(_DIMS[:-1], _DIMS[1:])):
        key, sub = jax.random.split(key)
        p[f"w{i}"] = jax.random.normal(sub, (a, b)) / np.sqrt(a)
        p[f"b{i}"] = jnp.zeros((b,))
    return p


def _loss(params, batch):
    h = batch["x"]
    last = len(_DIMS) - 2
    for i in range(last):
        h = jax.nn.relu(h @ params[f"w{i}"] + params[f"b{i}"])
    logits = h @ params[f"w{last}"] + params[f"b{last}"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def _clients(m):
    out = []
    for c in range(m):
        rng = np.random.default_rng(c)
        batches = [{"x": rng.normal(size=(_BS, _DIMS[0])).astype(np.float32),
                    "y": rng.integers(0, _DIMS[-1],
                                      size=(_BS,)).astype(np.int32)}
                   for _ in range(_NB)]
        out.append(ClientData(batches=batches, n_samples=_BS * _NB))
    return out


def run() -> None:
    grad_fn = jax.jit(jax.value_and_grad(_loss))
    params = _mlp_params()
    n = sum(int(np.prod(v.shape)) for v in params.values())
    n_leaves = len(params)
    datas = _clients(_M)
    algo = make_algorithm("fedprox", grad_fn, 0.05, local_epochs=1)
    payload = algo.broadcast_payload(params, algo.server_init(params))
    engine = engine_for(algo)
    steps = _M * _NB               # total client-steps per sweep

    def block(tree):
        jax.block_until_ready(jax.tree.leaves(tree)[0])

    def sweep_eager():
        for d in datas:
            res, _ = algo.client_update(payload, d, None)
        block(res.payload)

    def sweep_jit():
        for d in datas:
            res, _ = engine.run_client(payload, d)
        block(res.payload)

    def sweep_vmap(B):
        for i in range(0, _M, B):
            out, _ = engine.run_block(payload, datas[i:i + B])
        block(out)

    # one full sweep of M clients per path; reps are interleaved across the
    # paths and each path keeps its best rep, so a load spike on this
    # shared-CPU container cannot systematically bias one path's window
    sweeps = [("eager", sweep_eager), ("jit_scan", sweep_jit)]
    sweeps += [(f"jit_scan_vmap_B{B}", lambda B=B: sweep_vmap(B))
               for B in (1, 4, 16)]
    best = {name: float("inf") for name, _ in sweeps}
    for name, fn in sweeps:                    # warm the compile caches
        fn()
    for _ in range(4):
        for name, fn in sweeps:
            t0 = time.perf_counter()
            fn()
            best[name] = min(best[name], time.perf_counter() - t0)

    t_eager = best["eager"]
    d_eager = _NB * (1 + 3 * n_leaves)   # per step: grad + hook + update ops
    emit("client_train/eager", t_eager / _M * 1e6,
         f"steps_per_sec={steps / t_eager:.1f};"
         f"dispatches_per_client={d_eager};"
         f"n_params={n};n_leaves={n_leaves}")
    emit("client_train/jit_scan", best["jit_scan"] / _M * 1e6,
         f"steps_per_sec={steps / best['jit_scan']:.1f};"
         f"speedup_vs_eager={t_eager / best['jit_scan']:.2f}x;"
         f"dispatches_per_client=1")
    for B in (1, 4, 16):
        dt = best[f"jit_scan_vmap_B{B}"]
        emit(f"client_train/jit_scan_vmap_B{B}", dt / _M * 1e6,
             f"steps_per_sec={steps / dt:.1f};"
             f"speedup_vs_eager={t_eager / dt:.2f}x;"
             f"dispatches_per_client={1.0 / B:.4f}")
