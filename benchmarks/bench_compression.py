"""Compiled-compression benchmark (DESIGN.md §7): codec throughput — eager
per-span host codecs vs the one-dispatch compiled group codecs — and the
makespan-vs-loss frontier of {none, topk, int8, powersgd-r4, powersgd-r8}
under the constrained-uplink population of ``bench_network``.

What it demonstrates (ISSUE 7 acceptance): compressing a bench-sized flat
partial through the compiled path (one fused jit per group buffer, residual
device-resident) beats the eager reference (host numpy per span, residual
round-tripped through a dict) by >= 3x in MB/s, and the PowerSGD cells
extend the makespan/loss frontier beyond the sparse/quantized codecs under
a 40 kbps median uplink.

``BENCH_COMPRESSION_ROUNDS`` overrides the frontier round count and
``BENCH_COMPRESSION_REPS`` the throughput timing reps (CI smoke runs few).
"""
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import NetworkModel
from repro.core.aggregation import Op, wire_bytes
from repro.core.compression import make_compressor
from repro.core.flat import FlatLayout, flat_sums
from repro.data import synthesize_capacity_trace

ROUNDS = int(os.environ.get("BENCH_COMPRESSION_ROUNDS", "8"))
REPS = int(os.environ.get("BENCH_COMPRESSION_REPS", "30"))
SKIP = max(1, ROUNDS // 5)
N_CLIENTS = 120
CLIENTS_PER_ROUND = 32
K = 4
MEDIAN_KBPS = 40.0          # constrained last-mile uplink: comm-bound rounds


# ---------------------------------------------------------------------------
# codec throughput: eager per-span host codecs vs compiled one-dispatch, on
# flat partials at two broadcast sizes — "small" is the network benchmark's
# own mlp delta (2762 floats, where per-call dispatch overhead dominates
# both paths) and "large" is the same architecture scaled to ~183k floats
# (~731 KB), where the codec arithmetic dominates and the fused kernel's
# O(n log k) top-k beats the eager full argsort
# ---------------------------------------------------------------------------

SIZES = [("small", dict(dim=32, hidden=64, classes=10)),
         ("large", dict(dim=256, hidden=512, classes=100))]


def _bench_partial(shape, seed=0):
    ops = {"delta": Op.WEIGHTED_AVG}
    payload = {"delta": common.mlp_params(seed=seed, **shape)}
    layout = FlatLayout.build(ops, payload)
    bufs = layout.flatten(payload)
    return {"sums": flat_sums(dict(bufs)), "layout": layout,
            "weights": {"delta": 1.0}, "counts": {"delta": 1},
            "collected": {}, "n_clients": 1}


def _block(wire):
    """Force every segment of every compressed buffer to finish."""
    for buf in wire["sums"]["buffers"].values():
        if isinstance(buf, dict) and buf.get("__compressed__"):
            for kind, seg in buf["segments"]:
                if kind == "comp":
                    for v in seg.data.values():
                        jax.block_until_ready(v)
                else:
                    jax.block_until_ready(seg)
        else:
            np.asarray(buf)


def _throughput_mbps(comp, partial) -> float:
    raw = wire_bytes(partial)
    for _ in range(3):                      # warmup: jit compile + caches
        _block(comp.compress_partial(partial, key="exec0"))
    t0 = time.perf_counter()
    for _ in range(REPS):
        _block(comp.compress_partial(partial, key="exec0"))
    dt = time.perf_counter() - t0
    return (raw * REPS) / dt / 1e6


def _codec_throughput() -> None:
    cells = [
        ("topk/eager", lambda: make_compressor("topk", compiled=False)),
        ("topk/compiled", lambda: make_compressor("topk")),
        ("int8/eager", lambda: make_compressor("int8", compiled=False)),
        ("int8/compiled", lambda: make_compressor("int8")),
        ("powersgd-r4/compiled", lambda: make_compressor("powersgd",
                                                         rank=4)),
    ]
    for size, shape in SIZES:
        partial = _bench_partial(shape)
        kb = wire_bytes(partial) / 1024.0
        mbps = {}
        for name, mk in cells:
            mbps[name] = _throughput_mbps(mk(), partial)
            common.emit(f"compression/codec/{size}/{name}",
                        1e6 / max(mbps[name], 1e-9),  # us per MB processed
                        f"mbps={mbps[name]:.1f} payload_kb={kb:.1f}")
        for kind in ("topk", "int8"):
            ratio = (mbps[f"{kind}/compiled"]
                     / max(mbps[f"{kind}/eager"], 1e-9))
            common.emit(f"compression/codec/{size}/{kind}/compiled_vs_eager",
                        ratio, f"speedup_x={ratio:.2f}")


# ---------------------------------------------------------------------------
# makespan-vs-loss frontier under the constrained uplink
# ---------------------------------------------------------------------------

FRONTIER = [
    ("none", lambda: None),
    ("topk", lambda: make_compressor("topk", 0.05)),
    ("int8", lambda: make_compressor("int8")),
    ("powersgd-r4", lambda: make_compressor("powersgd", rank=4)),
    ("powersgd-r8", lambda: make_compressor("powersgd", rank=8)),
]


def _net() -> NetworkModel:
    return NetworkModel.from_trace(synthesize_capacity_trace(
        N_CLIENTS, seed=13, dist="uniform",
        median_uplink_kbps=MEDIAN_KBPS))


def _frontier() -> None:
    for name, mk in FRONTIER:
        srv = common.build_server(
            n_clients=N_CLIENTS, clients_per_round=CLIENTS_PER_ROUND, K=K,
            scheduler="parrot", warmup_rounds=2, network=_net(),
            compressor=mk())
        hist = [srv.run_round() for _ in range(ROUNDS)]
        makespan = float(np.mean([m.makespan for m in hist][SKIP:]))
        wire_kb = float(np.mean(
            [m.extra.get("comm_wire_bytes", 0.0) for m in hist][SKIP:])
            / 1024.0)
        loss = common.eval_loss(srv)
        common.emit(f"compression/frontier/{name}/makespan",
                    makespan * 1e6,
                    f"loss={loss:.4f} wire_kb={wire_kb:.1f} "
                    f"wire_ratio={srv._wire_ratio:.3f}")


def run() -> None:
    _codec_throughput()
    _frontier()
