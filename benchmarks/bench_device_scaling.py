"""Device-parallel executor scaling (DESIGN.md §8): simulation throughput
vs local device count.

Each cell runs in a SUBPROCESS (the device count is frozen at backend
init): ``--xla_force_host_platform_device_count=N`` with one executor per
virtual device, the device-resident stacked-batch cache, non-blocking
steady-state dispatch, and SPMD gang dispatch (one sharded execution per
block wave) — versus the ``1dev`` cell, which is the pre-placement
single-device path (unpinned executors, per-block host staging and sync).
A ``1dev_devpath`` cell (the full device stack pinned to one device)
separates the cache/pipelining contribution from true device parallelism.

Every cell pins XLA intra-op threading to one thread
(``--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1``):
virtual CPU host devices share the machine, so un-pinned intra-op
threading lets the single-device cell consume every core and the
device-count axis measures nothing.  With it pinned, the axis isolates
exactly what it claims — executor-level device parallelism (on real
accelerators, intra-device parallelism is orthogonal to this axis).

Reported per cell: client local-steps/sec over the timed rounds (compiles
happen in the warmup rounds) plus a bit-level digest of the final params —
the speedup only counts if every cell converges to the *identical* model.
BSP fold order is executor order, independent of wall timing; at this
model size (~2.7k elements/group, below ``psum_min_elements``) the global
fold takes the colocating left-fold, which is trivially bit-identical —
the shard_map/psum branch itself is pinned bit-exact by
``tests/test_device_parallel.py`` and the parity driver's forced-psum
end-to-end case.

Acceptance target (ISSUE 4): the 4-device cell reaches >= 2x the steps/s
of the single-device path at equal round results.  CAVEAT on this
container: the CI host has 2 physical cores, so even perfect 4-device
parallelism cannot exceed 2x, and the measured XLA ceiling is lower — one
sharded gang execution runs its 4 per-device shards at ~90% parallel
efficiency but is bound by the 2 cores (isolated microbenchmark: 1.45x at
4 devices, `/tmp`-style run in tests/device_parity_driver.py workloads).
The recorded numbers (~1.2x end-to-end at 4 devices, bit-exact params)
demonstrate the device axis works; the full multiplier needs >= K cores or
real accelerators, where per-device queues also overlap without ganging.

``BENCH_DEVICE_SCALING_ROUNDS`` / ``BENCH_DEVICE_SCALING_REPS`` override
the timed round and repetition counts.
"""
import json
import os
import subprocess
import sys

from benchmarks import common

ROUNDS = int(os.environ.get("BENCH_DEVICE_SCALING_ROUNDS", "10"))
REPS = int(os.environ.get("BENCH_DEVICE_SCALING_REPS", "3"))
WARMUP = 3
K = 4                     # executors (fixed: only the device count varies)
N_CLIENTS = 128           # every client selected every round (warm caches)
LOCAL_EPOCHS = 1
N_BATCHES = 8
BATCH_SIZE = 128

CHILD = r"""
import os, sys, hashlib, json, time
n_dev = int(sys.argv[1]); rounds = int(sys.argv[2]); warmup = int(sys.argv[3])
devpath = bool(int(sys.argv[4]))
flags = os.environ.get("XLA_FLAGS", "")
os.environ["XLA_FLAGS"] = (
    flags + f" --xla_force_host_platform_device_count={n_dev}"
    " --xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1"
).strip()
sys.path.insert(0, "src"); sys.path.insert(0, ".")
import jax, jax.numpy as jnp, numpy as np, tempfile
from repro.core import ClientStateManager, ParrotServer, SequentialExecutor, \
    make_algorithm
from repro.core.algorithms import ClientData

K, n_clients, E, nb, bs = %(K)d, %(n_clients)d, %(E)d, %(nb)d, %(bs)d
dim, hidden = 32, 64

def loss_fn(params, batch):
    x = batch["x"]
    h = jax.nn.relu(x @ params["w0"] + params["b0"])
    logits = h @ params["w1"] + params["b1"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)

GRAD = jax.jit(jax.value_and_grad(loss_fn))
k1, k2 = jax.random.split(jax.random.PRNGKey(0))
params = {"w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
          "b0": jnp.zeros((hidden,)),
          "w1": jax.random.normal(k2, (hidden, 10)) / np.sqrt(hidden),
          "b1": jnp.zeros((10,))}
rng = np.random.default_rng(0)
# uniform client signature: steady state is one executable per device (and
# one sharded gang executable), reached inside the warmup rounds
data = {c: ClientData(
    batches=[{"x": rng.standard_normal((bs, dim)).astype(np.float32),
              "y": rng.integers(0, 10, bs).astype(np.int32)}
             for _ in range(nb)], n_samples=bs * nb)
    for c in range(n_clients)}
algo = make_algorithm("fedavg", GRAD, 0.05, local_epochs=E)
sm = ClientStateManager(tempfile.mkdtemp(prefix="devscale_"))
devices = jax.devices() if devpath else None
kw = {} if devpath else dict(batch_cache_bytes=0, nonblocking=False)
execs = [SequentialExecutor(k, algo, state_manager=sm, client_block=16,
                            device=None if devices is None
                            else devices[k %% len(devices)], **kw)
         for k in range(K)]
srv = ParrotServer(params=params, algorithm=algo, executors=execs,
                   data_by_client=data, clients_per_round=n_clients,
                   scheduler_policy="uniform", seed=0)
for _ in range(warmup):
    srv.run_round()
jax.block_until_ready(jax.tree.leaves(srv.params))
t0 = time.perf_counter()
for _ in range(rounds):
    srv.run_round()
# non-blocking dispatch leaves device work in flight: the timed span ends
# only when the final params are actually materialised
jax.block_until_ready(jax.tree.leaves(srv.params))
wall = time.perf_counter() - t0
n_steps = rounds * n_clients * E * nb
digest = hashlib.sha256()
for leaf in jax.tree.leaves(srv.params):
    digest.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
print("RESULT" + json.dumps({
    "n_devices": n_dev, "devpath": devpath, "wall_s": wall,
    "steps": n_steps, "steps_per_s": n_steps / wall,
    "digest": digest.hexdigest()}))
"""


def _run_cell(n_dev: int, devpath: bool):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    script = CHILD % {"K": K, "n_clients": N_CLIENTS, "E": LOCAL_EPOCHS,
                      "nb": N_BATCHES, "bs": BATCH_SIZE}
    r = subprocess.run([sys.executable, "-c", script, str(n_dev),
                        str(ROUNDS), str(WARMUP), str(int(devpath))],
                       capture_output=True, text=True, timeout=1800, env=env)
    if r.returncode != 0:
        raise RuntimeError(f"device-scaling cell n_dev={n_dev} failed:\n"
                           + r.stderr[-3000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def run() -> None:
    # interleaved best-of-REPS per cell: the cells are subprocesses on a
    # shared host, and slow co-tenant phases would otherwise land entirely
    # on one cell and fake (or hide) a speedup
    grid = [("1dev", 1, False), ("1dev_devpath", 1, True),
            ("2dev", 2, True), ("4dev", 4, True)]
    cells = {}
    for _ in range(REPS):
        for name, n_dev, devpath in grid:
            c = _run_cell(n_dev, devpath)
            if name not in cells or \
                    c["steps_per_s"] > cells[name]["steps_per_s"]:
                cells[name] = c
    for name, c in cells.items():
        common.emit(f"device_scaling/{name}/steps_per_s",
                    1e6 / max(c["steps_per_s"], 1e-9),
                    f"steps_per_s={c['steps_per_s']:.1f} "
                    f"wall_s={c['wall_s']:.2f} steps={c['steps']}")
    base = cells["1dev"]
    for name in ("1dev_devpath", "2dev", "4dev"):
        c = cells[name]
        speedup = c["steps_per_s"] / max(base["steps_per_s"], 1e-9)
        exact = c["digest"] == base["digest"]
        common.emit(f"device_scaling/{name}/speedup", speedup,
                    f"speedup_x={speedup:.2f} params_bitexact={exact}")
