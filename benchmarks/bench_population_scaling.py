"""Million-client population scaling (DESIGN.md §11): peak RSS and
selection cost vs population size at a FIXED cohort.

Each cell runs in a SUBPROCESS (``ru_maxrss`` is process-wide and
monotonic, so cells must not share a process): build a streamed
``make_classification_population(M)``, run a few training rounds with
``clients_per_round`` held constant, then report the peak RSS and the
amortised ``select_clients`` latency.  With the registry-backed
population, dataset residency is bounded by the fetch cache and client
state by the tier budgets — RSS must stay essentially flat in M (the
registry itself is one int64 array, 8 bytes/client), and selection must
scale with the cohort, not the population.

Reported per cell: peak RSS (MB), selection latency (us/draw), round
wall.  Derived rows pin the ISSUE acceptance bars:

  population_scaling/rss_ratio_100k_over_1k   <= 1.5   (CI-smoked)
  population_scaling/rss_ratio_1m_over_1k     <= 1.5   (full grid runs)
  population_scaling/sel_ratio_1m_over_1k     — O(cohort) selection: the
      per-draw latency may grow only logarithmically (searchsorted), not
      linearly, in M

``BENCH_POPULATION_CLIENTS`` (comma list, default
``1000,10000,100000,1000000``) and ``BENCH_POPULATION_ROUNDS`` override
the grid — CI smoke uses ``1000,100000`` to keep the step short.
"""
import json
import os
import subprocess
import sys

from benchmarks import common

SIZES = [int(x) for x in os.environ.get(
    "BENCH_POPULATION_CLIENTS", "1000,10000,100000,1000000").split(",") if x]
ROUNDS = int(os.environ.get("BENCH_POPULATION_ROUNDS", "3"))
COHORT = 64
SEL_DRAWS = 200

CHILD = r"""
import os, sys, json, time, resource, tempfile
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, "src"); sys.path.insert(0, ".")
import jax, jax.numpy as jnp, numpy as np
from repro.core import ClientStateManager, ParrotServer, SequentialExecutor, \
    TickTimer, make_algorithm
from repro.data import make_classification_population

M, rounds, cohort, sel_draws = (int(sys.argv[1]), int(sys.argv[2]),
                                int(sys.argv[3]), int(sys.argv[4]))
dim, n_classes = 16, 8

def loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)

GRAD = jax.jit(jax.value_and_grad(loss_fn))
params = {"w": jnp.zeros((dim, n_classes)), "b": jnp.zeros((n_classes,))}
pop = make_classification_population(
    M, dim=dim, n_classes=n_classes, mean_samples=20, batch_size=10,
    seed=0, fetch_cache_bytes=32 << 20)
algo = make_algorithm("scaffold", GRAD, 0.05, local_epochs=1)
sm = ClientStateManager(tempfile.mkdtemp(prefix="popscale_"),
                        memory_budget_bytes=16 << 20, shard_clients=64)
execs = [SequentialExecutor(k, algo, state_manager=sm,
                            timer=TickTimer(1.0)) for k in range(4)]
srv = ParrotServer(params=params, algorithm=algo, executors=execs,
                   data_by_client=pop, clients_per_round=cohort, seed=7)
t0 = time.perf_counter()
for _ in range(rounds):
    srv.run_round()
jax.block_until_ready(jax.tree.leaves(srv.params))
round_wall = time.perf_counter() - t0
# amortised selection latency on a fresh rng (post-run, caches warm):
# O(cohort) + a searchsorted in M, never O(M)
rng = np.random.default_rng(123)
t0 = time.perf_counter()
for _ in range(sel_draws):
    srv.population.sample(rng, cohort)
sel_us = (time.perf_counter() - t0) / sel_draws * 1e6
rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print("RESULT" + json.dumps({
    "n_clients": M, "rounds": rounds, "cohort": cohort,
    "rss_mb": rss_kb / 1024.0, "sel_us_per_draw": sel_us,
    "round_wall_s": round_wall,
    "fetch_cache_bytes": pop.cache_bytes,
    "fetches": pop.stats["fetches"], "evictions": pop.stats["evictions"]}))
"""


def _run_cell(m: int):
    r = subprocess.run(
        [sys.executable, "-c", CHILD, str(m), str(ROUNDS), str(COHORT),
         str(SEL_DRAWS)],
        capture_output=True, text=True, timeout=1800,
        env=dict(os.environ, JAX_PLATFORMS="cpu"))
    if r.returncode != 0:
        raise RuntimeError(f"population cell M={m} failed:\n"
                           + r.stderr[-3000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def run() -> None:
    cells = {m: _run_cell(m) for m in SIZES}
    for m, c in sorted(cells.items()):
        common.emit(f"population_scaling/{m}/rss", c["rss_mb"] * 1e3,
                    f"rss_mb={c['rss_mb']:.1f} "
                    f"sel_us={c['sel_us_per_draw']:.1f} "
                    f"round_wall_s={c['round_wall_s']:.2f} "
                    f"fetches={c['fetches']} evictions={c['evictions']}")
        common.emit(f"population_scaling/{m}/select",
                    c["sel_us_per_draw"],
                    f"cohort={c['cohort']} sel_us={c['sel_us_per_draw']:.1f}")
    base = cells.get(min(SIZES))
    for m in SIZES:
        if m == min(SIZES):
            continue
        c = cells[m]
        rss_ratio = c["rss_mb"] / max(base["rss_mb"], 1e-9)
        sel_ratio = c["sel_us_per_draw"] / max(base["sel_us_per_draw"], 1e-9)
        tag = f"{m // 1000}k" if m < 10**6 else f"{m // 10**6}m"
        base_tag = (f"{min(SIZES) // 1000}k" if min(SIZES) < 10**6
                    else f"{min(SIZES) // 10**6}m")
        common.emit(f"population_scaling/rss_ratio_{tag}_over_{base_tag}",
                    rss_ratio,
                    f"rss_ratio={rss_ratio:.3f} bound=1.5 "
                    f"pass={rss_ratio <= 1.5}")
        common.emit(f"population_scaling/sel_ratio_{tag}_over_{base_tag}",
                    sel_ratio, f"sel_ratio={sel_ratio:.2f}")
