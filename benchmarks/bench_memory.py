"""Tables 1 & 3: memory complexity of the simulation schemes, measured.

Maps the paper's GPU-memory accounting onto measurable quantities here:
  - model memory per scheme = live model replicas × s_m
    (SP: 1; SD-Dist: M_p; FA-Dist/Parrot: K)
  - client state memory with/without the state manager (O(s_d·M) vs
    O(s_d·K) working set), measured from the manager itself
  - aggregation memory: O(s_a) partial regardless of clients folded
"""
import tempfile

import jax
import numpy as np

from benchmarks.common import GRAD_FN, build_server, emit, mlp_params
from repro.core import ClientStateManager
from repro.core.aggregation import payload_bytes


def run() -> None:
    params = mlp_params()
    s_m = payload_bytes(params)
    M, M_p, K = 1000, 100, 8

    for scheme, replicas in (("SP", 1), ("SD_dist", M_p),
                             ("FA_dist_or_parrot", K)):
        emit(f"table3_model_memory/{scheme}", replicas * s_m / 1e3,
             f"bytes={replicas * s_m}")

    # client state (SCAFFOLD-sized: one control variate per client)
    s_d = s_m
    with tempfile.TemporaryDirectory() as d:
        budget = K * s_d + 4096
        sm = ClientStateManager(d, memory_budget_bytes=budget)
        state = jax.tree.map(np.asarray, params)
        for c in range(M):
            sm.save(c, state)
        emit("table1_state_mem/with_manager", sm.memory_bytes / 1e3,
             f"budget=O(s_d*K)={budget};disk={sm.disk_bytes()}")
        emit("table1_state_mem/without_manager", M * s_d / 1e3,
             f"O(s_d*M)={M * s_d}")

    # aggregation partial is O(s_a) regardless of clients folded
    srv = build_server(K=4, clients_per_round=40)
    srv.run(1)
    emit("table1_agg_partial_is_O_sa", s_m / 1e3,
         f"s_a_bytes={s_m};independent_of_Mp=True")
