"""Network-simulation benchmark (DESIGN.md §9): simulated makespan over a
{uniform, lognormal} bandwidth population × {none, topk, int8} compressor
grid, plus a diurnal-availability cell.

What it demonstrates (ISSUE 5 acceptance): with comm priced on the virtual
clock, the compressors finally move the simulated makespan — under a
constrained uplink top-k must reduce makespan vs uncompressed at equal
rounds — and a lognormal (heavy-tailed, FedScale-like) population is
slower than a uniform one of the same median because the barrier waits on
the bottleneck link.

``BENCH_NETWORK_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os

import numpy as np

from benchmarks import common
from repro.core import ClientAvailability, NetworkModel
from repro.core.compression import make_compressor
from repro.data import synthesize_capacity_trace

ROUNDS = int(os.environ.get("BENCH_NETWORK_ROUNDS", "10"))
SKIP = max(1, ROUNDS // 5)
N_CLIENTS = 120
CLIENTS_PER_ROUND = 32
K = 4
MEDIAN_KBPS = 40.0          # constrained last-mile uplink: comm-bound rounds

COMPRESSORS = [("none", lambda: None),
               ("topk", lambda: make_compressor("topk", 0.05)),
               ("int8", lambda: make_compressor("int8"))]


def _net(dist: str) -> NetworkModel:
    return NetworkModel.from_trace(synthesize_capacity_trace(
        N_CLIENTS, seed=13, dist=dist, median_uplink_kbps=MEDIAN_KBPS))


def _run(dist: str, comp_name: str, make_comp, availability=None):
    srv = common.build_server(
        n_clients=N_CLIENTS, clients_per_round=CLIENTS_PER_ROUND, K=K,
        scheduler="parrot", warmup_rounds=2, network=_net(dist),
        availability=availability, compressor=make_comp())
    hist = [srv.run_round() for _ in range(ROUNDS)]
    return {
        "makespan_s": float(np.mean([m.makespan for m in hist][SKIP:])),
        "comm_up_s": float(np.mean(
            [m.extra.get("comm_time_up", 0.0) for m in hist][SKIP:])),
        "wire_kb": float(np.mean(
            [m.extra.get("comm_wire_bytes", 0.0) for m in hist][SKIP:])
            / 1024.0),
        "dropped": float(np.sum(
            [m.extra.get("dropped_clients", 0.0) for m in hist])),
    }


def run() -> None:
    results = {}
    for dist in ("uniform", "lognormal"):
        for name, make_comp in COMPRESSORS:
            r = _run(dist, name, make_comp)
            results[(dist, name)] = r
            common.emit(f"network/{dist}/{name}/makespan",
                        r["makespan_s"] * 1e6,
                        f"comm_up_s={r['comm_up_s']:.3f} "
                        f"wire_kb={r['wire_kb']:.1f}")
    for dist in ("uniform", "lognormal"):
        base = results[(dist, "none")]["makespan_s"]
        for name in ("topk", "int8"):
            red = 100.0 * (1.0 - results[(dist, name)]["makespan_s"]
                           / max(base, 1e-12))
            common.emit(f"network/{dist}/{name}/vs_none", red,
                        f"makespan_reduction_pct={red:.1f}")
    # diurnal churn on top of the lognormal population: selection filtering
    # + dropout + idle fast-forward all exercised end-to-end
    av = ClientAvailability.diurnal(N_CLIENTS, period_s=200.0,
                                    duty_mean=0.6, seed=17)
    r = _run("lognormal", "none", lambda: None, availability=av)
    common.emit("network/lognormal/diurnal/makespan", r["makespan_s"] * 1e6,
                f"dropped_total={r['dropped']:.0f}")
