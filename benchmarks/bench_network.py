"""Network-simulation benchmark (DESIGN.md §9): simulated makespan over a
{uniform, lognormal} bandwidth population × {none, topk, int8} compressor
grid, plus a diurnal-availability cell.

What it demonstrates (ISSUE 5 acceptance): with comm priced on the virtual
clock, the compressors finally move the simulated makespan — under a
constrained uplink top-k must reduce makespan vs uncompressed at equal
rounds — and a lognormal (heavy-tailed, FedScale-like) population is
slower than a uniform one of the same median because the barrier waits on
the bottleneck link.

Adaptive control plane (ISSUE 9, DESIGN.md §12): the constrained-uplink
(lognormal) population re-runs semi-sync and async under
``ControlPlane.observer()`` vs an adaptive plane, on ``TickTimer`` spans so
the rows reproduce bit-exactly.  This cell is comm-bound: the oracle prices
comm serially, so the DES engines — whose uploads overlap compute — already
beat it and the observer gap is *negative*; the ``gap_closure`` row then
reports 100 (no positive gap left to close) and the interesting deltas are
makespan and loss, carried in the derived fields.  The async cell uses an
overlap-only plane: measured here, the λ controller raises λ off its
low-staleness EWMA and costs ~18% loss, and queue re-packing reorders folds
for no makespan win — neither earns its keep when comm dominates compute.

``BENCH_NETWORK_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os

import numpy as np

from benchmarks import common
from repro.core import ClientAvailability, ControlPlane, NetworkModel, TickTimer
from repro.core.compression import make_compressor
from repro.data import synthesize_capacity_trace

ROUNDS = int(os.environ.get("BENCH_NETWORK_ROUNDS", "10"))
SKIP = max(1, ROUNDS // 5)
N_CLIENTS = 120
CLIENTS_PER_ROUND = 32
K = 4
MEDIAN_KBPS = 40.0          # constrained last-mile uplink: comm-bound rounds

COMPRESSORS = [("none", lambda: None),
               ("topk", lambda: make_compressor("topk", 0.05)),
               ("int8", lambda: make_compressor("int8"))]

# adaptive grid: engine opts + the control plane that suits a comm-bound
# population (see module docstring for why async drops λ-tuning/re-pack)
ADAPTIVE_CELLS = [
    ("semi_sync", "semi-sync",
     {"deadline_frac": 0.55, "over_select": 1.2, "chunk_size": 4},
     ControlPlane.adaptive),
    ("async", "async",
     {"staleness_lambda": 0.5, "chunk_size": 8},
     lambda: ControlPlane(overlap_comm=True)),
]


def _net(dist: str) -> NetworkModel:
    return NetworkModel.from_trace(synthesize_capacity_trace(
        N_CLIENTS, seed=13, dist=dist, median_uplink_kbps=MEDIAN_KBPS))


def _run(dist: str, comp_name: str, make_comp, availability=None):
    srv = common.build_server(
        n_clients=N_CLIENTS, clients_per_round=CLIENTS_PER_ROUND, K=K,
        scheduler="parrot", warmup_rounds=2, network=_net(dist),
        availability=availability, compressor=make_comp())
    hist = [srv.run_round() for _ in range(ROUNDS)]
    return {
        "makespan_s": float(np.mean([m.makespan for m in hist][SKIP:])),
        "comm_up_s": float(np.mean(
            [m.extra.get("comm_time_up", 0.0) for m in hist][SKIP:])),
        "wire_kb": float(np.mean(
            [m.extra.get("comm_wire_bytes", 0.0) for m in hist][SKIP:])
            / 1024.0),
        "dropped": float(np.sum(
            [m.extra.get("dropped_clients", 0.0) for m in hist])),
    }


def _run_gap(engine, opts, control):
    # deterministic cell (TickTimer spans, real DES comm pricing)
    srv = common.build_server(
        n_clients=N_CLIENTS, clients_per_round=CLIENTS_PER_ROUND, K=K,
        scheduler="parrot", warmup_rounds=2, network=_net("lognormal"),
        round_engine=engine, engine_opts=dict(opts), control=control,
        timer=TickTimer(1.0))
    hist = [srv.run_round() for _ in range(ROUNDS)]
    return {
        "gap_pct": common.gap_to_oracle_pct(hist, skip=SKIP),
        "makespan_s": float(np.mean([m.makespan for m in hist][SKIP:])),
        "loss": common.eval_loss(srv),
    }


def run() -> None:
    results = {}
    for dist in ("uniform", "lognormal"):
        for name, make_comp in COMPRESSORS:
            r = _run(dist, name, make_comp)
            results[(dist, name)] = r
            common.emit(f"network/{dist}/{name}/makespan",
                        r["makespan_s"] * 1e6,
                        f"comm_up_s={r['comm_up_s']:.3f} "
                        f"wire_kb={r['wire_kb']:.1f}")
    for dist in ("uniform", "lognormal"):
        base = results[(dist, "none")]["makespan_s"]
        for name in ("topk", "int8"):
            red = 100.0 * (1.0 - results[(dist, name)]["makespan_s"]
                           / max(base, 1e-12))
            common.emit(f"network/{dist}/{name}/vs_none", red,
                        f"makespan_reduction_pct={red:.1f}")
    # diurnal churn on top of the lognormal population: selection filtering
    # + dropout + idle fast-forward all exercised end-to-end
    av = ClientAvailability.diurnal(N_CLIENTS, period_s=200.0,
                                    duty_mean=0.6, seed=17)
    r = _run("lognormal", "none", lambda: None, availability=av)
    common.emit("network/lognormal/diurnal/makespan", r["makespan_s"] * 1e6,
                f"dropped_total={r['dropped']:.0f}")

    # adaptive control on the constrained-uplink cell (ISSUE 9)
    for name, engine, opts, make_ctrl in ADAPTIVE_CELLS:
        base = _run_gap(engine, opts, ControlPlane.observer())
        common.emit(f"network/{name}/gap_to_oracle", base["gap_pct"],
                    f"gap_to_oracle_pct={base['gap_pct']:.1f} "
                    f"makespan_s={base['makespan_s']:.2f} "
                    f"loss={base['loss']:.4f}")
        r = _run_gap(engine, opts, make_ctrl())
        dloss = 100.0 * (r["loss"] - base["loss"]) / max(base["loss"], 1e-12)
        common.emit(f"network/{name}/adaptive/gap_to_oracle", r["gap_pct"],
                    f"gap_to_oracle_pct={r['gap_pct']:.1f} "
                    f"makespan_s={r['makespan_s']:.2f} "
                    f"loss={r['loss']:.4f} loss_delta_pct={dloss:+.2f}")
        closure = 100.0 * (1.0 - max(r["gap_pct"], 0.0)
                           / max(base["gap_pct"], 1e-12))
        note = ("observer already beats the serial-comm oracle; "
                "no positive gap to close" if base["gap_pct"] <= 0.0 else "")
        common.emit(f"network/{name}/adaptive/gap_closure", closure,
                    f"observer_gap_pct={base['gap_pct']:.1f} "
                    f"adaptive_gap_pct={r['gap_pct']:.1f} "
                    f"closure_pct={closure:.1f} {note}".rstrip())
