"""Table 1 (communication): size O(s_a·K + s_e·M_p) and trips O(K) for
Parrot vs O(s_a·M_p), O(M_p) for flat SD/FA-Dist — measured from the
Communicator's byte/trip accounting, plus the compression multipliers."""
from benchmarks.common import build_server, emit, mlp_params
from repro.core.aggregation import payload_bytes
from repro.core.compression import make_compressor


def _one_round(srv):
    m = srv.run_round()
    return m.comm_bytes, m.comm_trips


def run() -> None:
    s_a = payload_bytes(mlp_params())
    K, M_p = 8, 100

    srv = build_server(K=K, clients_per_round=M_p, n_clients=300)
    bytes_h, trips_h = _one_round(srv)
    emit("table1_comm/parrot_hierarchical", bytes_h / 1e3,
         f"trips={trips_h};expected_trips=2K={2 * K}")

    # flat emulation: every client result shipped individually
    flat_bytes = s_a * M_p + s_a * K   # results + broadcast
    emit("table1_comm/flat_SD_dist_analytic", flat_bytes / 1e3,
         f"trips={2 * M_p};ratio_vs_parrot="
         f"{flat_bytes / max(bytes_h, 1):.2f}x")

    # Mime has a COLLECT (Special Param) -> O(s_e * M_p) irreducible
    srv_m = build_server(K=K, clients_per_round=M_p, n_clients=300,
                         algorithm="mime")
    bytes_m, trips_m = _one_round(srv_m)
    emit("table1_comm/mime_special_params", bytes_m / 1e3,
         f"trips={trips_m};grows_with_Mp=True")

    # compression on the reducible part (top-k EF / int8)
    for kind in ("topk", "int8"):
        srv_c = build_server(K=K, clients_per_round=M_p, n_clients=300,
                             compressor=make_compressor(kind, 0.01))
        bytes_c, trips_c = _one_round(srv_c)
        emit(f"table1_comm/parrot+{kind}", bytes_c / 1e3,
             f"trips={trips_c};ratio_vs_uncompressed="
             f"{bytes_h / max(bytes_c, 1):.2f}x")
