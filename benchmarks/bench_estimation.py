"""Figures 6 / 11: workload-model estimation accuracy; Time-Window vs
all-history under a dynamic (cosine-drift) environment."""
import numpy as np

from benchmarks.common import build_server, emit
from repro.core.executor import dynamic_env, hetero_gpus

ROUNDS = 12


def _mean_err(server, rounds):
    errs = []
    for _ in range(rounds):
        m = server.run_round()
        if np.isfinite(m.estimation_error):
            errs.append(m.estimation_error)
    return float(np.mean(errs[2:])) if len(errs) > 2 else float("nan")


def run() -> None:
    # Fig 6: static heterogeneous devices -> the linear model fits well
    hete = hetero_gpus({k: [0.0, 0.5, 1.0, 3.0][k % 4] for k in range(8)})
    srv = build_server(scheduler="parrot", speed_model=hete)
    err = _mean_err(srv, ROUNDS)
    emit("fig6_estimation_error/hete_static", err * 1e6,
         f"mean_rel_err={err:.3f}")

    # Fig 11: dynamic environment — all-history vs time-window
    dyn = dynamic_env(8, ROUNDS)
    srv_all = build_server(scheduler="parrot", speed_model=dyn, time_window=0)
    srv_win = build_server(scheduler="parrot", speed_model=dyn, time_window=2)
    err_all = _mean_err(srv_all, ROUNDS)
    err_win = _mean_err(srv_win, ROUNDS)
    emit("fig11a_est_error/all_history", err_all * 1e6, f"{err_all:.3f}")
    emit("fig11a_est_error/time_window", err_win * 1e6, f"{err_win:.3f}")

    from benchmarks.common import mean_makespan
    ms_all = mean_makespan(
        build_server(scheduler="parrot", speed_model=dyn, time_window=0),
        ROUNDS)
    ms_win = mean_makespan(
        build_server(scheduler="parrot", speed_model=dyn, time_window=2),
        ROUNDS)
    ms_none = mean_makespan(
        build_server(scheduler="none", speed_model=dyn), ROUNDS)
    emit("fig11b_round_time/all_history", ms_all * 1e6, f"{ms_all:.4f}s")
    emit("fig11b_round_time/time_window", ms_win * 1e6, f"{ms_win:.4f}s")
    emit("fig11b_round_time/unscheduled", ms_none * 1e6, f"{ms_none:.4f}s")
