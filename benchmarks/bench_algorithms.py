"""Figure 4: six FL algorithms trained under Parrot vs the flat
single-process reference — identical trajectories (exactness) and per-round
times with/without scheduling (Fig. 4d)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (GRAD_FN, build_server, emit, mean_makespan,
                               mlp_params)
from repro.core import make_algorithm, run_flat_reference
from repro.core.executor import hetero_gpus
from repro.data import make_classification_clients

ALGOS = ["fedavg", "fedprox", "fednova", "mime", "scaffold", "feddyn"]
HETE = hetero_gpus({k: [0.0, 1.0, 2.0, 4.0][k % 4] for k in range(8)})


def run() -> None:
    data = make_classification_clients(200, dim=32, n_classes=10,
                                       partition="dirichlet",
                                       partition_arg=0.3, mean_samples=60,
                                       batch_size=20, seed=0)
    for name in ALGOS:
        srv = build_server(algorithm=name, K=8, clients_per_round=40)
        srv.run(5)
        flat, _ = run_flat_reference(
            mlp_params(), make_algorithm(name, GRAD_FN, 0.05),
            srv.data_by_client, clients_per_round=40, n_rounds=5, seed=0)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(flat),
                                   jax.tree.leaves(srv.params)))
        emit(f"fig4_equivalence/{name}", diff * 1e6,
             f"max_param_diff={diff:.2e};exact={diff < 1e-4}")

    # Fig 4d: per-round time with vs without scheduling, per algorithm
    for name in ALGOS:
        t_s = mean_makespan(build_server(algorithm=name, speed_model=HETE,
                                         scheduler="parrot",
                                         partition="quantity_skew"), 6)
        t_n = mean_makespan(build_server(algorithm=name, speed_model=HETE,
                                         scheduler="none",
                                         partition="quantity_skew"), 6)
        emit(f"fig4d_round_time/{name}", t_s * 1e6,
             f"sched={t_s:.4f}s;unsched={t_n:.4f}s;"
             f"speedup={t_n / max(t_s, 1e-12):.2f}x")
