"""Shared benchmark plumbing: the standard FL workload (paper §5.1 scaled to
this container), timing helpers, and CSV/JSON emission."""
from __future__ import annotations

import json
import sys
import tempfile
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm)
from repro.core.executor import SpeedModel, dynamic_env, hetero_gpus, homogeneous
from repro.data import make_classification_clients

ROWS: List[str] = []
RECORDS: List[Dict[str, object]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": float(us_per_call),
                    "derived": derived})
    print(row, flush=True)


def write_json(path: str) -> None:
    """Dump every emitted row as machine-readable JSON — the per-PR perf
    trajectory format (``BENCH_*.json``)."""
    with open(path, "w") as f:
        json.dump({"schema": "repro-bench/1", "rows": RECORDS}, f, indent=2)
        f.write("\n")
    print(f"wrote {len(RECORDS)} rows to {path}", flush=True)


def _loss_fn(params, batch):
    x = batch["x"]
    h = jax.nn.relu(x @ params["w0"] + params["b0"])
    logits = h @ params["w1"] + params["b1"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))


def mlp_params(dim=32, hidden=64, classes=10, seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {
        "w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
        "b0": jnp.zeros((hidden,)),
        "w1": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
        "b1": jnp.zeros((classes,)),
    }


def build_server(*, n_clients=200, clients_per_round=40, K=8,
                 algorithm="fedavg", scheduler="parrot", time_window=0,
                 speed_model: SpeedModel = homogeneous, partition="natural",
                 partition_arg=5.0, compressor=None, seed=0, local_epochs=1,
                 warmup_rounds=1, round_engine="bsp",
                 engine_opts=None, network=None,
                 availability=None, faults=None, retry=None,
                 timer=None, control=None, telemetry=None) -> ParrotServer:
    data = make_classification_clients(
        n_clients, dim=32, n_classes=10, partition=partition,
        partition_arg=partition_arg, mean_samples=60, batch_size=20,
        seed=seed)
    algo = make_algorithm(algorithm, GRAD_FN, 0.05, local_epochs=local_epochs)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="bench_state_"))
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=speed_model, timer=timer)
             for k in range(K)]
    return ParrotServer(params=mlp_params(), algorithm=algo, executors=execs,
                        data_by_client=data,
                        clients_per_round=clients_per_round,
                        scheduler_policy=scheduler, time_window=time_window,
                        warmup_rounds=warmup_rounds, compressor=compressor,
                        round_engine=round_engine, engine_opts=engine_opts,
                        network=network, availability=availability,
                        faults=faults, retry=retry, control=control,
                        telemetry=telemetry, seed=seed)


def eval_loss(server: ParrotServer) -> float:
    """Sample-weighted mean loss of the server's params over every client's
    data (the convergence signal the round-mode benchmark tracks)."""
    tot, n = 0.0, 0
    for d in server.data_by_client.values():
        for b in d.batches:
            loss, _ = GRAD_FN(server.params, b)
            tot += float(loss) * len(b["y"])
            n += len(b["y"])
    return tot / max(n, 1)


def mean_makespan(server: ParrotServer, rounds: int, skip: int = 2) -> float:
    ms = [server.run_round().makespan for _ in range(rounds)]
    return float(np.mean(ms[skip:]))


def gap_to_oracle_pct(metrics, skip: int = 0) -> float:
    """Mean % excess of the realized makespan over the hindsight-optimal
    LPT re-pack of the same folded work (``extra["oracle_makespan"]``,
    DESIGN.md §12; requires a non-None ``control=``).  Can go slightly
    negative: the oracle prices comm serially and models compute as
    n·rate, so an overlapped or constant-per-chunk schedule may beat it."""
    gaps = []
    for m in metrics[skip:]:
        oracle = m.extra.get("oracle_makespan", 0.0)
        if oracle > 0.0:
            gaps.append(100.0 * (m.makespan - oracle) / oracle)
    return float(np.mean(gaps)) if gaps else 0.0
