"""Microbenchmarks of the Pallas kernel wrappers (interpret mode on CPU —
timing here validates plumbing, not TPU performance; the TPU-side roofline
for these kernels is in §Roofline)."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    from repro.kernels import ops
    key = jax.random.PRNGKey(0)

    n = 1 << 20
    acc = jnp.zeros((n,), jnp.float32)
    deltas = jax.random.normal(key, (4, n), jnp.bfloat16)
    w = jnp.ones((4,))
    us = _time(lambda: ops.agg_weighted_sum(acc, deltas, w))
    emit("kernel_agg_weighted_sum/1M_x4", us,
         f"GBps={(n * 4 * 2 + n * 8) / us / 1e3:.2f}")

    q = jax.random.normal(key, (1, 512, 4, 64), jnp.bfloat16)
    us = _time(lambda: ops.flash_attention(q, q, q, causal=True))
    emit("kernel_flash_attention/512x4x64", us, "interpret=True")

    x = jax.random.normal(key, (4096, 1024), jnp.bfloat16)
    g = jnp.ones((1024,), jnp.bfloat16)
    us = _time(lambda: ops.rmsnorm(x, g))
    emit("kernel_rmsnorm/4096x1024", us, "interpret=True")

    qs = jax.random.normal(key, (4, 512, 16))
    vs = jax.random.normal(key, (4, 512, 32))
    la = -jax.nn.softplus(jax.random.normal(key, (4, 512)))
    us = _time(lambda: ops.ssm_scan(qs, qs, vs, la, chunk=128))
    emit("kernel_ssm_scan/512x16x32", us, "interpret=True")
