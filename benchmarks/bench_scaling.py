"""Figures 7 / 8: scaling with the number of executors K — round time
(near-linear speedup) and scheduling/estimation overhead (linear in K,
negligible vs the round)."""
import numpy as np

from benchmarks.common import build_server, emit

ROUNDS = 6


def run() -> None:
    times = {}
    for K in (2, 4, 8, 16, 32):
        srv = build_server(K=K, clients_per_round=64, n_clients=256,
                           scheduler="parrot")
        ms, sched, est = [], [], []
        for _ in range(ROUNDS):
            m = srv.run_round()
            ms.append(m.makespan)
            sched.append(m.schedule_time)
            est.append(m.estimate_time)
        times[K] = float(np.mean(ms[2:]))
        emit(f"fig7_round_time/K={K}", times[K] * 1e6,
             f"speedup_vs_K2={times[2] / max(times[K], 1e-12):.2f}x")
        emit(f"fig8_sched_overhead/K={K}",
             float(np.mean(sched[2:])) * 1e6,
             f"est_us={float(np.mean(est[2:])) * 1e6:.1f};"
             f"frac_of_round={float(np.mean(sched[2:])) / max(times[K], 1e-12):.5f}")
