"""Telemetry observability benchmark (DESIGN.md §13).

Two questions:

* **Overhead** — what does ``telemetry=True`` cost in host wall time?
  Interleaved best-of-reps A/B on identical TickTimer runs (same work, same
  jit cache) across all three engines; ``overhead_pct`` is the relative
  wall delta of the summed minima.  The CI smoke step bounds it at 5%.
* **Utilization** — the per-executor busy/comm/idle fractions (the paper's
  "computing utility") each engine achieves under ``dynamic_env``
  heterogeneity with a constrained uniform uplink.  BSP's barrier idles the
  fast lanes; semi-sync's deadline and async's pipeline reclaim them.

Plus a ``trace_valid`` row: the async cell's exported Chrome trace passes
``validate_trace`` (1.0 = no violations).

``BENCH_OBS_ROUNDS`` / ``BENCH_OBS_REPS`` override the round / repetition
counts (CI smoke runs few).
"""
import json
import os
import tempfile
import time

import numpy as np

from benchmarks import common
from repro.core import NetworkModel, TickTimer, validate_trace
from repro.core.executor import dynamic_env

ROUNDS = int(os.environ.get("BENCH_OBS_ROUNDS", "8"))
REPS = int(os.environ.get("BENCH_OBS_REPS", "3"))
SKIP = max(1, ROUNDS // 4)
K = 4
NET = NetworkModel.uniform(uplink_bps=2e5, downlink_bps=1e6, latency_s=0.05)

ENGINES = [
    ("bsp", "bsp", {}),
    ("semi_sync", "semi-sync", {"deadline_frac": 0.7, "over_select": 1.2,
                                "chunk_size": 4}),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 4}),
]


def _build(engine, opts, telemetry):
    return common.build_server(
        n_clients=80, clients_per_round=24, K=K,
        speed_model=dynamic_env(K, ROUNDS), warmup_rounds=1,
        round_engine=engine, engine_opts=opts, network=NET,
        timer=TickTimer(1.0), telemetry=telemetry)


def _wall(engine, opts, telemetry):
    srv = _build(engine, opts, telemetry)
    t0 = time.perf_counter()
    for _ in range(ROUNDS):
        srv.run_round()
    return time.perf_counter() - t0, srv


def run() -> None:
    # -- overhead: interleaved best-of-reps, telemetry off vs on ----------
    walls = {False: {}, True: {}}      # enabled -> engine -> [wall, ...]
    for rep in range(REPS):
        for name, engine, opts in ENGINES:
            for enabled in (False, True):
                w, _ = _wall(engine, opts, True if enabled else None)
                walls[enabled].setdefault(name, []).append(w)
    off = sum(min(ws) for ws in walls[False].values())
    on = sum(min(ws) for ws in walls[True].values())
    overhead = 100.0 * (on - off) / max(off, 1e-12)
    common.emit("observability/overhead_pct", overhead,
                f"wall_off_s={off:.3f} wall_on_s={on:.3f} reps={REPS} "
                f"rounds={ROUNDS}")

    # -- per-engine utilization under dynamic heterogeneity ---------------
    last_srv = None
    for name, engine, opts in ENGINES:
        srv = _build(engine, opts, True)
        metrics = [srv.run_round() for _ in range(ROUNDS)]
        fracs = {"busy_frac": [], "comm_frac": [], "idle_frac": []}
        for m in metrics[SKIP:]:
            for u in m.extra["utilization"].values():
                for key in fracs:
                    fracs[key].append(u[key])
        means = {key: float(np.mean(v)) for key, v in fracs.items()}
        for key in ("busy_frac", "comm_frac", "idle_frac"):
            common.emit(f"observability/{name}/{key}", means[key],
                        " ".join(f"{k2}={v2:.3f}"
                                 for k2, v2 in means.items()))
        if engine == "async":
            last_srv = srv

    # -- exported trace validates -----------------------------------------
    path = os.path.join(tempfile.mkdtemp(prefix="bench_obs_"), "trace.json")
    last_srv.telemetry.tracer.export(path)
    errors = validate_trace(path)
    with open(path) as f:
        n_events = len(json.load(f)["traceEvents"])
    common.emit("observability/trace_valid",
                1.0 if not errors else 0.0,
                f"events={n_events} errors={len(errors)}")
