"""Fault-tolerance cost curves (DESIGN.md §10): virtual makespan and final
eval loss over a fault-rate grid, with quorum-degraded rounds on and off,
for the bsp and semi-sync engines.

Each cell runs the same seeded workload under a ``FaultPlan.random`` chaos
plan whose event rates scale with the grid's ``rate`` knob (dropouts at
``rate``/s; crashes, corruption and slowdowns at fractions of it), over a
lognormal-ish uniform network so retries and blackout pricing bill real
virtual time.  The quorum-off column shows what degraded rounds buy back:
at quorum 0.7 a straggling or retrying tail no longer gates the commit.

Virtual time uses ``TickTimer`` so makespans are deterministic functions of
the schedule, not of host jitter — the same discipline the engine tests use.

``BENCH_FAULTS_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os

import numpy as np

from benchmarks import common
from repro.core import NetworkModel, TickTimer
from repro.core.faults import FaultPlan, RetryPolicy

ROUNDS = int(os.environ.get("BENCH_FAULTS_ROUNDS", "12"))
K = 4
N_CLIENTS = 60
CLIENTS_PER_ROUND = 16
RATES = [0.0, 0.02, 0.05]
QUORUMS = [1.0, 0.7]

ENGINES = [
    ("bsp", "bsp", {}),
    ("semi_sync", "semi-sync", {"deadline_frac": 0.6, "over_select": 1.2,
                                "chunk_size": 4}),
]


def _plan(rate: float) -> FaultPlan:
    if rate <= 0.0:
        return None
    # one plan seed for the whole grid: the rate knob scales event density,
    # not placement luck
    # ~4 virtual s per round under this workload: the horizon tracks the
    # run's actual span so the rate knob means what it says
    return FaultPlan.random(
        seed=9, horizon=float(ROUNDS) * 4.0,
        executors=list(range(K)), clients=list(range(N_CLIENTS)),
        crash_rate=rate * 0.3, restart_delay=6.0,
        dropout_rate=rate, dropout_duration=5.0,
        corrupt_rate=rate * 0.5,
        blackout_rate=rate * 0.2, blackout_duration=1.5,
        slowdown_rate=rate * 0.3, slowdown_duration=8.0,
        slowdown_factor=3.0)


def _tot(srv, key) -> int:
    return int(sum(m.extra.get(key, 0) for m in srv.history))


def _cell(engine: str, opts: dict, rate: float, quorum: float) -> dict:
    srv = common.build_server(
        n_clients=N_CLIENTS, clients_per_round=CLIENTS_PER_ROUND, K=K,
        speed_model=lambda k, r: 0.0, timer=TickTimer(1.0),
        warmup_rounds=2, round_engine=engine,
        engine_opts=dict(opts, quorum_frac=quorum),
        network=NetworkModel.uniform(12e6, 24e6, latency_s=0.03),
        faults=_plan(rate),
        retry=RetryPolicy(timeout_s=8.0, max_retries=2, backoff_s=0.5))
    metrics = [srv.run_round() for _ in range(ROUNDS)]
    return {
        "makespan_s": float(np.mean([m.makespan for m in metrics])),
        "loss": common.eval_loss(srv),
        "retries": _tot(srv, "retries"),
        "dropped": _tot(srv, "dropped_clients"),
        "crashes": _tot(srv, "fault_crashes"),
        "quorum_commits": _tot(srv, "quorum_commits"),
    }


def run() -> None:
    for name, engine, opts in ENGINES:
        by_key = {}
        for rate in RATES:
            for q in QUORUMS:
                r = _cell(engine, opts, rate, q)
                by_key[(rate, q)] = r
                common.emit(
                    f"faults/{name}/rate{rate:g}/q{q:g}/makespan",
                    r["makespan_s"] * 1e6,
                    f"loss={r['loss']:.4f} retries={r['retries']} "
                    f"dropped={r['dropped']} crashes={r['crashes']} "
                    f"quorum_commits={r['quorum_commits']}")
        # what degraded rounds buy at the top fault rate
        top = max(RATES)
        full, deg = by_key[(top, 1.0)], by_key[(top, QUORUMS[-1])]
        red = 100.0 * (1.0 - deg["makespan_s"] / max(full["makespan_s"],
                                                     1e-12))
        dloss = 100.0 * (deg["loss"] - full["loss"]) / max(full["loss"],
                                                           1e-12)
        common.emit(f"faults/{name}/quorum_gain", red,
                    f"makespan_reduction_pct={red:.1f} "
                    f"loss_delta_pct={dloss:+.2f} at_rate={top:g}")
