"""Figures 5 / 9 / 10: per-round time under different schedulers, hardware
heterogeneity profiles and concurrency scales.

The paper's comparison axes mapped to this harness:
  - scheduled (Parrot, Alg. 3) vs unscheduled (FA-Dist arrival order) vs
    uniform split — same workload, same executors;
  - Homog. / Hete. GPU (fixed η_k, paper Appendix A) / real skew;
  - M_p ∈ {20, 100} concurrent clients (Fig. 10).
Round time is the BSP makespan max_k Σ T̂_{m,k} in simulated seconds.
"""
from benchmarks.common import build_server, emit, mean_makespan
from repro.core.executor import hetero_gpus, homogeneous

ROUNDS = 8
HETE = hetero_gpus({0: 0.0, 1: 0.5, 2: 1.0, 3: 3.0,
                    4: 0.0, 5: 0.5, 6: 1.0, 7: 3.0})


def run() -> None:
    for env_name, speed in [("homog", homogeneous), ("hete", HETE)]:
        base = {}
        for policy in ("parrot", "uniform", "none"):
            srv = build_server(scheduler=policy, speed_model=speed,
                               partition="quantity_skew")
            ms = mean_makespan(srv, ROUNDS)
            base[policy] = ms
            emit(f"fig5_round_time/{env_name}/{policy}", ms * 1e6,
                 f"makespan_s={ms:.4f}")
        emit(f"fig9_speedup_vs_unsched/{env_name}",
             base["parrot"] * 1e6,
             f"x{base['none'] / max(base['parrot'], 1e-12):.2f}_faster")

    for mp in (20, 100):
        srv_s = build_server(clients_per_round=mp, n_clients=max(200, mp * 2),
                             scheduler="parrot", speed_model=HETE,
                             partition="quantity_skew")
        srv_n = build_server(clients_per_round=mp, n_clients=max(200, mp * 2),
                             scheduler="none", speed_model=HETE,
                             partition="quantity_skew")
        ms_s = mean_makespan(srv_s, ROUNDS)
        ms_n = mean_makespan(srv_n, ROUNDS)
        emit(f"fig10_concurrency/Mp={mp}", ms_s * 1e6,
             f"sched={ms_s:.4f}s_unsched={ms_n:.4f}s")
