"""Figures 5 / 9 / 10: per-round time under different schedulers, hardware
heterogeneity profiles and concurrency scales.

The paper's comparison axes mapped to this harness:
  - scheduled (Parrot, Alg. 3) vs unscheduled (FA-Dist arrival order) vs
    uniform split — same workload, same executors;
  - Homog. / Hete. GPU (fixed η_k, paper Appendix A) / real skew;
  - M_p ∈ {20, 100} concurrent clients (Fig. 10).
Round time is the BSP makespan max_k Σ T̂_{m,k} in simulated seconds.

Oracle-gap grid (ISSUE 9, DESIGN.md §12): the same scheduled-vs-unscheduled
axis re-measured as distance from the hindsight-optimal LPT re-pack of the
work each round actually folded (``gap_to_oracle_pct``), for the two DES
engines on the fixed heterogeneous-GPU profile.  ``TickTimer`` spans make
the rows bit-reproducible.  For semi-sync the gap is positive — deadline
slack plus lane imbalance — and ``ControlPlane.adaptive()`` (deadline
tuning + deadline-aware work stealing + comm overlap) closes most of it;
the ``gap_closure`` row is the CI smoke's acceptance signal.  Async's
pipeline already sits below the serial oracle (negative gap); its adaptive
cell drops the λ controller, which on this *static* profile turns the low
staleness EWMA into a large discount swing that costs convergence for no
makespan win, and keeps the re-pack/overlap levers.

``BENCH_SCHED_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os

from benchmarks.common import (build_server, emit, eval_loss,
                               gap_to_oracle_pct, mean_makespan)
from repro.core import ControlPlane, TickTimer
from repro.core.executor import hetero_gpus, homogeneous

ROUNDS = int(os.environ.get("BENCH_SCHED_ROUNDS", "8"))
SKIP = max(1, ROUNDS // 4)
HETE = hetero_gpus({0: 0.0, 1: 0.5, 2: 1.0, 3: 3.0,
                    4: 0.0, 5: 0.5, 6: 1.0, 7: 3.0})

ENGINES = [
    ("semi_sync", "semi-sync",
     {"deadline_frac": 0.55, "over_select": 1.2, "chunk_size": 2},
     ControlPlane.adaptive),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 8},
     lambda: ControlPlane(rebalance=True, overlap_comm=True,
                          gang_waves=True, window_fit=True)),
]


def _run_gap(engine, opts, policy, control):
    srv = build_server(scheduler=policy, speed_model=HETE,
                       partition="quantity_skew", round_engine=engine,
                       clients_per_round=64, engine_opts=dict(opts),
                       control=control, timer=TickTimer(1.0),
                       warmup_rounds=2)
    hist = [srv.run_round() for _ in range(ROUNDS)]
    return {"gap_pct": gap_to_oracle_pct(hist, skip=SKIP),
            "loss": eval_loss(srv)}


def run() -> None:
    for env_name, speed in [("homog", homogeneous), ("hete", HETE)]:
        base = {}
        for policy in ("parrot", "uniform", "none"):
            srv = build_server(scheduler=policy, speed_model=speed,
                               partition="quantity_skew")
            ms = mean_makespan(srv, ROUNDS)
            base[policy] = ms
            emit(f"fig5_round_time/{env_name}/{policy}", ms * 1e6,
                 f"makespan_s={ms:.4f}")
        emit(f"fig9_speedup_vs_unsched/{env_name}",
             base["parrot"] * 1e6,
             f"x{base['none'] / max(base['parrot'], 1e-12):.2f}_faster")

    for mp in (20, 100):
        srv_s = build_server(clients_per_round=mp, n_clients=max(200, mp * 2),
                             scheduler="parrot", speed_model=HETE,
                             partition="quantity_skew")
        srv_n = build_server(clients_per_round=mp, n_clients=max(200, mp * 2),
                             scheduler="none", speed_model=HETE,
                             partition="quantity_skew")
        ms_s = mean_makespan(srv_s, ROUNDS)
        ms_n = mean_makespan(srv_n, ROUNDS)
        emit(f"fig10_concurrency/Mp={mp}", ms_s * 1e6,
             f"sched={ms_s:.4f}s_unsched={ms_n:.4f}s")

    # oracle-gap grid (ISSUE 9): how close each policy/engine/control cell
    # sits to the hindsight-optimal schedule of its own folded work
    for name, engine, opts, make_ctrl in ENGINES:
        for policy in ("none", "parrot"):
            r = _run_gap(engine, opts, policy, ControlPlane.observer())
            label = "unsched" if policy == "none" else policy
            emit(f"scheduling/{name}/{label}/gap_to_oracle", r["gap_pct"],
                 f"gap_to_oracle_pct={r['gap_pct']:.1f} "
                 f"loss={r['loss']:.4f}")
            if policy == "parrot":
                base = r
        r = _run_gap(engine, opts, "parrot", make_ctrl())
        dloss = 100.0 * (r["loss"] - base["loss"]) / max(base["loss"], 1e-12)
        emit(f"scheduling/{name}/parrot/adaptive/gap_to_oracle", r["gap_pct"],
             f"gap_to_oracle_pct={r['gap_pct']:.1f} "
             f"loss={r['loss']:.4f} loss_delta_pct={dloss:+.2f}")
        closure = 100.0 * (1.0 - max(r["gap_pct"], 0.0)
                           / max(base["gap_pct"], 1e-12))
        emit(f"scheduling/{name}/parrot/adaptive/gap_closure", closure,
             f"observer_gap_pct={base['gap_pct']:.1f} "
             f"adaptive_gap_pct={r['gap_pct']:.1f} "
             f"closure_pct={closure:.1f}")
