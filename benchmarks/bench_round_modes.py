"""Round-engine mode comparison (DESIGN.md §3): simulated makespan and wall
time for bsp / semi-sync / async under ``dynamic_env`` heterogeneity
(K=4 executors, 64 clients per round), plus final eval loss so throughput
wins can't hide convergence regressions.

Acceptance targets (ISSUE 3): async mean makespan >= 25% below bsp while
its final-round eval loss stays within 5% of the BSP run's.

``BENCH_ROUND_MODES_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os
import time

import numpy as np

from benchmarks import common
from repro.core.executor import dynamic_env

ROUNDS = int(os.environ.get("BENCH_ROUND_MODES_ROUNDS", "16"))
SKIP = max(2, ROUNDS // 5)          # estimator warm-up rounds to discard
K = 4
CLIENTS_PER_ROUND = 64

MODES = [
    ("bsp", "bsp", {}),
    ("semi_sync", "semi-sync", {"deadline_frac": 0.55, "over_select": 1.2,
                                "chunk_size": 4}),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 8}),
]


def _run_mode(engine, opts):
    srv = common.build_server(
        n_clients=160, clients_per_round=CLIENTS_PER_ROUND, K=K,
        speed_model=dynamic_env(K, ROUNDS), warmup_rounds=2,
        round_engine=engine, engine_opts=opts)
    t0 = time.perf_counter()
    metrics = [srv.run_round() for _ in range(ROUNDS)]
    wall = time.perf_counter() - t0
    makespans = [m.makespan for m in metrics][SKIP:]
    return {
        "makespan_s": float(np.mean(makespans)),
        "wall_s": wall,
        "loss": common.eval_loss(srv),
        "trips": int(np.mean([m.comm_trips for m in metrics])),
    }


def run() -> None:
    results = {}
    for name, engine, opts in MODES:
        r = _run_mode(engine, opts)
        results[name] = r
        common.emit(f"round_modes/{name}/makespan", r["makespan_s"] * 1e6,
                    f"loss={r['loss']:.4f} wall_s={r['wall_s']:.2f} "
                    f"trips={r['trips']}")
    bsp = results["bsp"]
    for name in ("semi_sync", "async"):
        r = results[name]
        red = 100.0 * (1.0 - r["makespan_s"] / max(bsp["makespan_s"], 1e-12))
        # signed: negative = converged *better* than BSP at equal rounds
        dloss = 100.0 * (r["loss"] - bsp["loss"]) / max(bsp["loss"], 1e-12)
        common.emit(f"round_modes/{name}/vs_bsp", red,
                    f"makespan_reduction_pct={red:.1f} "
                    f"loss_delta_pct={dloss:+.2f}")
