"""Round-engine mode comparison (DESIGN.md §3): simulated makespan and wall
time for bsp / semi-sync / async under ``dynamic_env`` heterogeneity
(K=4 executors, 64 clients per round), plus final eval loss so throughput
wins can't hide convergence regressions.

Acceptance targets (ISSUE 3): async mean makespan >= 25% below bsp while
its final-round eval loss stays within 5% of the BSP run's.

Adaptive control plane (ISSUE 9, DESIGN.md §12): a second, deterministic
grid (``TickTimer`` spans, so the rows reproduce bit-exactly run to run)
compares ``ControlPlane.observer()`` — behaviour-identical to no control,
oracle tracking on — against ``ControlPlane.adaptive()`` for semi-sync and
async.  ``gap_to_oracle`` rows report how far each cell sits from the
hindsight-optimal LPT schedule of the work it actually folded, and
``gap_closure`` rows how much of the observer's gap the adaptive
controllers recover (the PR's acceptance metric).

``BENCH_ROUND_MODES_ROUNDS`` overrides the round count (CI smoke runs few).
"""
import os
import time

import numpy as np

from benchmarks import common
from repro.core import ControlPlane, TickTimer
from repro.core.executor import dynamic_env

ROUNDS = int(os.environ.get("BENCH_ROUND_MODES_ROUNDS", "16"))
SKIP = max(2, ROUNDS // 5)          # estimator warm-up rounds to discard
K = 4
CLIENTS_PER_ROUND = 64

MODES = [
    ("bsp", "bsp", {}),
    ("semi_sync", "semi-sync", {"deadline_frac": 0.55, "over_select": 1.2,
                                "chunk_size": 4}),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 8}),
]

# the deterministic oracle-gap grid gets its own opts: chunk 2 keeps the
# deadline cut fine-grained, the 0.75 static frac is the same landing
# quantile the adaptive cell's controller targets (so observer vs adaptive
# compares control, not landing volume), and target_ratio 0.75 sits just
# below what stealing achieves under this cell's dynamic heterogeneity —
# the 1/over_select default assumes no straggler shave at all
GAP_MODES = [
    ("bsp", "bsp", {}, None),
    ("semi_sync", "semi-sync",
     {"deadline_frac": 0.75, "over_select": 1.2, "chunk_size": 2},
     lambda: ControlPlane.adaptive(target_ratio=0.75)),
    ("async", "async", {"staleness_lambda": 0.5, "chunk_size": 8},
     ControlPlane.adaptive),
]


def _build(engine, opts, control=None, timer=None):
    return common.build_server(
        n_clients=160, clients_per_round=CLIENTS_PER_ROUND, K=K,
        speed_model=dynamic_env(K, ROUNDS), warmup_rounds=2,
        round_engine=engine, engine_opts=opts, control=control, timer=timer)


def _run_mode(engine, opts):
    srv = _build(engine, opts)
    t0 = time.perf_counter()
    metrics = [srv.run_round() for _ in range(ROUNDS)]
    wall = time.perf_counter() - t0
    makespans = [m.makespan for m in metrics][SKIP:]
    return {
        "makespan_s": float(np.mean(makespans)),
        "wall_s": wall,
        "loss": common.eval_loss(srv),
        "trips": int(np.mean([m.comm_trips for m in metrics])),
    }


def _run_gap(engine, opts, control):
    # deterministic cell: TickTimer spans make the gap metric reproducible
    # (the wall-clock cells above keep the real timer for continuity)
    srv = _build(engine, opts, control=control, timer=TickTimer(1.0))
    metrics = [srv.run_round() for _ in range(ROUNDS)]
    return {
        "gap_pct": common.gap_to_oracle_pct(metrics, skip=SKIP),
        "loss": common.eval_loss(srv),
    }


def run() -> None:
    results = {}
    for name, engine, opts in MODES:
        r = _run_mode(engine, opts)
        results[name] = r
        common.emit(f"round_modes/{name}/makespan", r["makespan_s"] * 1e6,
                    f"loss={r['loss']:.4f} wall_s={r['wall_s']:.2f} "
                    f"trips={r['trips']}")
    bsp = results["bsp"]
    for name in ("semi_sync", "async"):
        r = results[name]
        red = 100.0 * (1.0 - r["makespan_s"] / max(bsp["makespan_s"], 1e-12))
        # signed: negative = converged *better* than BSP at equal rounds
        dloss = 100.0 * (r["loss"] - bsp["loss"]) / max(bsp["loss"], 1e-12)
        common.emit(f"round_modes/{name}/vs_bsp", red,
                    f"makespan_reduction_pct={red:.1f} "
                    f"loss_delta_pct={dloss:+.2f}")

    # adaptive control plane vs the observer baseline (ISSUE 9): the
    # gap_closure row is the acceptance metric on this cell
    for name, engine, opts, make_ctrl in GAP_MODES:
        base = _run_gap(engine, opts, ControlPlane.observer())
        common.emit(f"round_modes/{name}/gap_to_oracle", base["gap_pct"],
                    f"gap_to_oracle_pct={base['gap_pct']:.1f} "
                    f"loss={base['loss']:.4f}")
        if name == "bsp":
            continue                 # no adaptive lever moves comm-free bsp
        r = _run_gap(engine, opts, make_ctrl())
        dloss = 100.0 * (r["loss"] - base["loss"]) / max(base["loss"], 1e-12)
        common.emit(f"round_modes/{name}/adaptive/gap_to_oracle",
                    r["gap_pct"],
                    f"gap_to_oracle_pct={r['gap_pct']:.1f} "
                    f"loss={r['loss']:.4f} loss_delta_pct={dloss:+.2f}")
        closure = 100.0 * (1.0 - max(r["gap_pct"], 0.0)
                           / max(base["gap_pct"], 1e-12))
        common.emit(f"round_modes/{name}/adaptive/gap_closure", closure,
                    f"observer_gap_pct={base['gap_pct']:.1f} "
                    f"adaptive_gap_pct={r['gap_pct']:.1f} "
                    f"closure_pct={closure:.1f}")
