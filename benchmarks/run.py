"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Mapping to the paper:

  bench_scheduling  — Figs. 5, 9, 10 (round time: scheduled vs not, hetero)
  bench_estimation  — Figs. 6, 11 (workload-model error; time-window)
  bench_scaling     — Figs. 7, 8 (speedup in K; scheduling overhead)
  bench_memory      — Tables 1, 3 (memory per scheme; state manager)
  bench_comm        — Table 1 (comm size/trips; hierarchical vs flat)
  bench_algorithms  — Fig. 4 (six algorithms: exactness + round times)
  bench_kernels     — Pallas wrapper micro-timings (plumbing check)
  roofline          — §Roofline terms from the dry-run artifacts
"""
import sys

sys.path.insert(0, "src")


def main() -> None:
    import importlib
    mods = ["bench_scheduling", "bench_estimation", "bench_scaling",
            "bench_memory", "bench_comm", "bench_algorithms",
            "bench_kernels", "roofline"]
    only = sys.argv[1:] or None
    print("name,us_per_call,derived")
    for m in mods:
        if only and m not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{m}")
        mod.run()


if __name__ == "__main__":
    main()
