"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
dumps the rows as machine-readable JSON (the per-PR ``BENCH_*.json`` perf
trajectory format).  ``--only NAME[,NAME...]`` (or legacy positional names)
restricts the run to specific modules, e.g.::

    python benchmarks/run.py --only bench_aggregation --json BENCH_agg.json

Mapping to the paper:

  bench_scheduling  — Figs. 5, 9, 10 (round time: scheduled vs not, hetero)
  bench_estimation  — Figs. 6, 11 (workload-model error; time-window)
  bench_scaling     — Figs. 7, 8 (speedup in K; scheduling overhead)
  bench_memory      — Tables 1, 3 (memory per scheme; state manager)
  bench_comm        — Table 1 (comm size/trips; hierarchical vs flat)
  bench_algorithms  — Fig. 4 (six algorithms: exactness + round times)
  bench_aggregation — flat-buffer batched C=B fold: GB/s + dispatches/client
                      vs the legacy per-leaf C=1 path
  bench_client_training — compiled client engine: eager vs jit-scan vs
                      jit-scan+vmap client-steps/sec at B in {1,4,16}
  bench_round_modes — event-driven round engines: bsp vs semi-sync vs async
                      makespan / wall / loss under dynamic heterogeneity
  bench_network     — trace-driven network simulation: makespan over
                      {uniform, lognormal} bandwidth x {none, topk, int8}
                      compressor grid + diurnal availability
  bench_compression — compiled codec throughput (eager vs one-dispatch
                      MB/s) + {none, topk, int8, powersgd-r4/r8} frontier
                      under the constrained uplink
  bench_device_scaling — device-parallel executors: steps/s at 1/2/4 virtual
                      devices (subprocess cells) + params bit-parity
  bench_fault_tolerance — makespan / final-loss over a fault-rate grid,
                      quorum-degraded rounds on vs off (alias: faults)
  bench_population_scaling — streamed-population memory axis: peak RSS and
                      selection latency at 1k..1M clients, fixed cohort
                      (alias: population)
  bench_observability — telemetry on/off wall overhead + per-engine
                      busy/comm/idle utilization + trace validation
                      (alias: obs)
  bench_kernels     — Pallas wrapper micro-timings (plumbing check)
  roofline          — §Roofline terms from the dry-run artifacts
"""
import argparse
import os
import sys

# make both `repro` (src/) and the `benchmarks` package importable no matter
# whether this runs as `python benchmarks/run.py` or `python -m benchmarks.run`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

MODS = ["bench_scheduling", "bench_estimation", "bench_scaling",
        "bench_memory", "bench_comm", "bench_algorithms",
        "bench_aggregation", "bench_client_training", "bench_round_modes",
        "bench_network", "bench_compression", "bench_device_scaling",
        "bench_fault_tolerance", "bench_population_scaling",
        "bench_observability", "bench_kernels", "roofline"]

# convenience aliases on top of the bench_ prefix rule
ALIASES = {"faults": "bench_fault_tolerance",
           "population": "bench_population_scaling",
           "obs": "bench_observability"}


def main(argv=None) -> None:
    import importlib

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--only", action="append", default=None,
                   metavar="NAME[,NAME]",
                   help="run only these benchmark modules")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write the rows as JSON (BENCH_*.json)")
    p.add_argument("names", nargs="*",
                   help="legacy positional module filter")
    args = p.parse_args(argv)

    only = set(args.names)
    for grp in (args.only or []):
        only.update(x for x in grp.split(",") if x)
    if args.only and not only:
        p.error("--only given but no module names resolved")
    # accept short names too: "round_modes" == "bench_round_modes"
    only = {ALIASES.get(m, m) for m in only}
    only = {m if m in MODS else f"bench_{m}" for m in only}
    unknown = only - set(MODS)
    if unknown:
        p.error(f"unknown benchmark module(s): {sorted(unknown)}; "
                f"choose from {MODS}")
    if args.json:
        d = os.path.dirname(args.json) or "."
        if not os.path.isdir(d) or not os.access(d, os.W_OK):
            p.error(f"--json: directory not writable: {d}")

    print("name,us_per_call,derived")
    for m in MODS:
        if only and m not in only:
            continue
        mod = importlib.import_module(f"benchmarks.{m}")
        mod.run()

    if args.json:
        from benchmarks import common
        common.write_json(args.json)


if __name__ == "__main__":
    main()
