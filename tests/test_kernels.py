"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True on CPU — the kernel body itself executes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,hd", [(2, 256, 4, 64), (1, 128, 2, 128),
                                      (2, 256, 3, 96), (1, 512, 1, 192)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True)
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 64)) for kk in ks)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=1e-3)


@pytest.mark.parametrize("blk", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(blk):
    """Block-shape sweep: tiling must not change the math."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 64)) for kk in ks)
    out = ops.flash_attention(q, k, v, causal=True, blk_q=blk[0], blk_k=blk[1])
    exp = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5,
                               rtol=1e-3)


def test_flash_matches_model_chunked_attention():
    """Kernel == the model's chunked-jnp path (same algorithm, two impls)."""
    from repro.models.attention import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q, k, v = (jax.random.normal(kk, (2, 256, 4, 64)) for kk in ks)
    a = ops.flash_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=1e-3)


# ---------------------------------------------------------------------------
# aggregation fold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1000, 65536, 100001])
@pytest.mark.parametrize("C", [1, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_agg_weighted_sum(n, C, dtype):
    key = jax.random.PRNGKey(0)
    acc = jax.random.normal(key, (n,), jnp.float32)
    deltas = jax.random.normal(jax.random.fold_in(key, 1), (C, n), dtype)
    w = jnp.linspace(0.5, 2.0, C)
    out = ops.agg_weighted_sum(acc, deltas, w)
    exp = ref.agg_weighted_sum_ref(acc, deltas, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_agg_fold_matches_plain():
    key = jax.random.PRNGKey(1)
    acc = jnp.zeros((317, 13), jnp.float32)
    delta = jax.random.normal(key, (317, 13), jnp.bfloat16)
    out = ops.agg_fold(acc, delta, 2.5)
    np.testing.assert_allclose(np.asarray(out),
                               2.5 * np.asarray(delta, np.float32),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,chunk", [(256, 64), (256, 128), (512, 256)])
@pytest.mark.parametrize("N,P", [(16, 32), (8, 64)])
def test_ssm_scan(S, chunk, N, P):
    BH = 3
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (BH, S, N))
    k = jax.random.normal(jax.random.fold_in(key, 1), (BH, S, N)) * 0.1
    v = jax.random.normal(jax.random.fold_in(key, 2), (BH, S, P))
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (BH, S)))
    y, h = ops.ssm_scan(q, k, v, la, chunk=chunk)
    ye, he = ref.ssm_scan_ref(q, k, v, la, jnp.zeros((BH, N, P)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=2e-4,
                               rtol=1e-3)


def test_ssm_kernel_matches_model_chunked_scan():
    """Kernel == models.ssm.chunked_linear_scan (shared SSD algorithm)."""
    from repro.models.ssm import chunked_linear_scan
    B, S, H, N, P = 2, 256, 2, 8, 16
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, S, H, N))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, N)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3), (B, S, H)))
    y_model, h_model = chunked_linear_scan(q, k, v, la,
                                           jnp.zeros((B, H, N, P)), 64)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, N)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    laf = la.transpose(0, 2, 1).reshape(B * H, S)
    y_kern, h_kern = ops.ssm_scan(qf, kf, vf, laf, chunk=64)
    y_kern = y_kern.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kern),
                               atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("T,d", [(100, 64), (1000, 896), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(T, d, dtype):
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, d), dtype)
    g = jax.random.normal(jax.random.fold_in(key, 1), (d,), dtype)
    out = ops.rmsnorm(x, g)
    exp = ref.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


def test_rmsnorm_matches_model_layer():
    from repro.models import layers
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    g = jnp.full((32,), 1.3)
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, g)),
        np.asarray(layers.rmsnorm({"g": g}, x)), atol=1e-5, rtol=1e-5)
