"""Streamed population axis (DESIGN.md §11): registry-backed populations,
O(cohort) rng-identical selection, the hierarchical fold tree, and
streamed-vs-eager engine parity.

The selection anchor: ``ClientPopulation.sample`` draws positional indices
via ``rng.choice(pool_len, size, replace=False)`` and maps them through the
sorted registry — numpy's Generator consumes the bit stream identically to
``rng.choice(pool_list, ...)``, so cohorts must match the legacy
implementation (frozen below) id-for-id, in order, across sequential draws.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientStateManager, LocalAggregator, ParrotServer,
                        SequentialExecutor, TickTimer, make_algorithm)
from repro.core.aggregation import global_aggregate, tree_reduce_partials
from repro.core.population import (EagerPopulation, LazyPopulation,
                                   as_population)
from repro.data import (make_classification_clients,
                        make_classification_population)

from test_flat_aggregation import OPS, _assert_bit_exact, _int_results


# ---------------------------------------------------------------------------
# legacy selection (frozen pre-population implementation — the rng pin)
# ---------------------------------------------------------------------------

def _legacy_select(rng, ids, k, exclude=None, avail=None):
    if exclude:
        pool = sorted(set(ids) - set(exclude))
    else:
        pool = sorted(ids)
    if avail is not None:
        pool = [c for c in pool if avail(c)]
    size = min(k, len(pool))
    if size <= 0:
        return []
    return [int(c) for c in rng.choice(pool, size=size, replace=False)]


def _sparse_ids(seed=0, n=200):
    rng = np.random.default_rng(seed)
    return sorted(int(c) for c in
                  rng.choice(10_000, size=n, replace=False))


# ---------------------------------------------------------------------------
# populations: mapping interface + fetch cache
# ---------------------------------------------------------------------------

def _lazy(n=30, cache=1 << 20, sizes=None):
    sizes = [10 + (c % 7) for c in range(n)] if sizes is None else sizes
    calls = []

    def factory(c):
        calls.append(c)
        from repro.core.algorithms import ClientData
        x = np.full((4, 2), float(c), np.float32)
        return ClientData(batches=[{"x": x}], n_samples=sizes[c])

    return LazyPopulation(sizes, factory, fetch_cache_bytes=cache), calls


def test_eager_population_is_mapping_compatible():
    data = make_classification_clients(12, dim=4, n_classes=3,
                                       mean_samples=10, batch_size=5)
    pop = as_population(data)
    assert isinstance(pop, EagerPopulation)
    assert as_population(pop) is pop
    assert len(pop) == 12 and sorted(pop) == sorted(data)
    assert 3 in pop and 99 not in pop
    assert pop[3] is data[3]
    assert pop.n_samples(3) == data[3].n_samples
    assert len(list(pop.values())) == 12          # Mapping mixin

    # the cached sorted registry survives repeated calls and tracks
    # membership changes
    ids = pop.ids_array()
    assert pop.ids_array() is ids
    data[100] = data[3]
    assert 100 in pop and pop.ids_array()[-1] == 100


def test_lazy_population_registry_without_materialization():
    pop, calls = _lazy(50)
    assert len(pop) == 50
    assert pop.n_samples(13) == 10 + 13 % 7
    assert 49 in pop and 50 not in pop
    with pytest.raises(KeyError):
        pop[50]
    assert calls == []            # registry reads never touch the factory
    d = pop[7]
    assert d.n_samples == pop.n_samples(7) and calls == [7]
    assert pop[7] is d            # cached: stable identity, no refetch
    assert calls == [7]


def test_lazy_population_fetch_cache_is_bounded():
    pop, calls = _lazy(30, cache=100)      # one client's batch is 32 bytes
    for c in range(30):
        pop[c]
    assert pop.cache_bytes <= 100
    assert pop.stats["evictions"] > 0
    # evicted client re-fetches deterministically
    first = np.asarray(pop[0].batches[0]["x"]).copy()
    assert calls.count(0) >= 2
    np.testing.assert_array_equal(first, np.full((4, 2), 0.0, np.float32))


def test_streamed_generator_matches_materialized_twin():
    pop = make_classification_population(15, dim=4, n_classes=3,
                                         mean_samples=12, batch_size=5,
                                         seed=3)
    twin = pop.materialize()
    assert sorted(twin) == list(range(15))
    for c in (0, 7, 14):
        assert pop.n_samples(c) == twin[c].n_samples
        for a, b in zip(pop[c].batches, twin[c].batches):
            np.testing.assert_array_equal(a["x"], b["x"])
            np.testing.assert_array_equal(a["y"], b["y"])
    # access order must not matter: a fresh population read backwards
    pop2 = make_classification_population(15, dim=4, n_classes=3,
                                          mean_samples=12, batch_size=5,
                                          seed=3)
    for c in reversed(range(15)):
        np.testing.assert_array_equal(pop2[c].batches[0]["x"],
                                      twin[c].batches[0]["x"])


# ---------------------------------------------------------------------------
# O(cohort) selection: rng-identical to the legacy implementation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ids_kind", ["dense", "sparse"])
def test_sample_matches_legacy_no_filters(ids_kind):
    ids = list(range(100)) if ids_kind == "dense" else _sparse_ids()
    pop = EagerPopulation({c: None for c in ids})
    r1, r2 = np.random.default_rng(7), np.random.default_rng(7)
    for k in (1, 5, 17, len(ids), len(ids) + 10):
        got = pop.sample(r1, k)
        want = _legacy_select(r2, ids, k)
        assert got == want
    # sequential draws stay in lockstep (identical rng consumption)
    assert r1.bit_generator.state == r2.bit_generator.state


@pytest.mark.parametrize("ids_kind", ["dense", "sparse"])
def test_sample_matches_legacy_with_exclude(ids_kind):
    ids = list(range(100)) if ids_kind == "dense" else _sparse_ids(1)
    pop = EagerPopulation({c: None for c in ids})
    rng = np.random.default_rng(11)
    r1, r2 = np.random.default_rng(5), np.random.default_rng(5)
    for trial in range(25):
        n_ex = int(rng.integers(0, 40))
        # excludes may include ids not in the population (in-flight clients
        # of a shrunk population) and duplicates
        exclude = [int(c) for c in rng.choice(
            np.asarray(ids + [77777, 88888]), size=n_ex)] if n_ex else None
        k = int(rng.integers(1, 30))
        got = pop.sample(r1, k, exclude=exclude)
        want = _legacy_select(r2, ids, k, exclude=exclude)
        assert got == want, f"trial {trial}: {got} != {want}"
    assert r1.bit_generator.state == r2.bit_generator.state


def test_sample_matches_legacy_with_filters():
    ids = _sparse_ids(2)
    pop = EagerPopulation({c: None for c in ids})
    avail = lambda c: (c % 3) != 0
    r1, r2 = np.random.default_rng(3), np.random.default_rng(3)
    for k in (1, 9, 40):
        got = pop.sample(r1, k, exclude=[ids[0], ids[5]], filters=[avail])
        want = _legacy_select(r2, ids, k, exclude=[ids[0], ids[5]],
                              avail=avail)
        assert got == want
    assert r1.bit_generator.state == r2.bit_generator.state


def test_lazy_and_eager_sample_identically():
    pop, calls = _lazy(120)
    eager = EagerPopulation({c: None for c in range(120)})
    r1, r2 = np.random.default_rng(42), np.random.default_rng(42)
    for _ in range(10):
        assert pop.sample(r1, 13, exclude=[5, 6]) == \
            eager.sample(r2, 13, exclude=[5, 6])
    assert calls == []            # selection never materialises clients


def test_server_selection_pins_legacy_cohorts():
    """ParrotServer.select_clients end-to-end vs the frozen implementation
    (same seed, same sequence of calls — the satellite's rng pin)."""
    data = make_classification_clients(40, dim=4, n_classes=3,
                                       mean_samples=10, batch_size=5, seed=1)
    algo = make_algorithm("fedavg", _grad_fn(), 0.05, local_epochs=1)
    srv = ParrotServer(params=_params0(), algorithm=algo,
                       executors=[SequentialExecutor(0, algo)],
                       data_by_client=data, clients_per_round=10, seed=7)
    ref = np.random.default_rng(7)
    ids = sorted(data)
    for exclude in (None, [3, 4, 5], [0], None):
        tasks = srv.select_clients(exclude=exclude)
        want = _legacy_select(ref, ids, 10, exclude=exclude)
        assert [t.client for t in tasks] == want
        assert all(t.n_samples == data[t.client].n_samples for t in tasks)


# ---------------------------------------------------------------------------
# hierarchical fold tree
# ---------------------------------------------------------------------------

def _partials(K, n_results=11, seed=0):
    results = _int_results(n_results, seed=seed)
    aggs = [LocalAggregator(OPS) for _ in range(K)]
    for i, r in enumerate(results):
        aggs[i % K].fold(r)
    return [a.partial() for a in aggs]


@pytest.mark.parametrize("K", [1, 2, 3, 5])
@pytest.mark.parametrize("fan_in", [2, 3])
def test_tree_fold_bit_identical_to_flat(K, fan_in):
    """The fan-in tree must reproduce the flat left-fold exactly on the
    integer payloads (every partial sum exactly representable, so any
    grouping must yield identical bits) — COLLECT order included."""
    flat = global_aggregate(_partials(K), OPS)
    tree = global_aggregate(
        tree_reduce_partials(_partials(K), fan_in), OPS)
    _assert_bit_exact(flat["delta"], tree["delta"])
    _assert_bit_exact(flat["tau"], tree["tau"])
    _assert_bit_exact(flat["count"], tree["count"])
    assert [w for w, _ in flat["trace"]] == [w for w, _ in tree["trace"]]
    for (_, a), (_, b) in zip(flat["trace"], tree["trace"]):
        _assert_bit_exact(a, b)


def test_tree_fold_narrow_list_is_passthrough():
    parts = _partials(3)
    assert tree_reduce_partials(parts, 8) is not parts  # copied list
    assert tree_reduce_partials(parts, 8) == parts      # same objects


def test_tree_fold_reduces_width():
    parts = _partials(13, n_results=26)
    level = tree_reduce_partials(parts, 4)
    assert len(level) <= 4
    _assert_bit_exact(global_aggregate(parts, OPS)["delta"],
                      global_aggregate(level, OPS)["delta"])


def test_server_global_fold_wide_k_routes_through_tree():
    data = make_classification_clients(8, dim=4, n_classes=3,
                                       mean_samples=10, batch_size=5)
    algo = make_algorithm("fedavg", _grad_fn(), 0.05, local_epochs=1)
    srv = ParrotServer(params=_params0(), algorithm=algo,
                       executors=[SequentialExecutor(0, algo)],
                       data_by_client=data, clients_per_round=4,
                       fold_fan_in=3, seed=0)
    parts = _partials(7, n_results=21)
    ops = algo.ops()
    _assert_bit_exact(global_aggregate(parts, ops)["delta"],
                      srv.global_fold(parts)["delta"])


# ---------------------------------------------------------------------------
# streamed vs eager engine parity (all three engines)
# ---------------------------------------------------------------------------

def _grad_fn():
    def _loss(params, batch):
        logits = batch["x"] @ params["w"] + params["b"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    return jax.jit(jax.value_and_grad(_loss))


def _params0():
    return {"w": jnp.zeros((6, 3)), "b": jnp.zeros((3,))}


def _run(engine, data_or_pop, rounds=3):
    algo = make_algorithm("scaffold", _grad_fn(), 0.05, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="pop_"),
                            memory_budget_bytes=1 << 14, shard_clients=8)
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                timer=TickTimer(1.0))
             for k in range(3)]
    srv = ParrotServer(params=_params0(), algorithm=algo, executors=execs,
                       data_by_client=data_or_pop, clients_per_round=8,
                       round_engine=engine, seed=7)
    hist = [srv.run_round() for _ in range(rounds)]
    return srv.params, [m.makespan for m in hist], hist


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_streamed_run_bit_exact_with_eager(engine):
    """A registry-backed streamed population must replay the eager run
    params-bit-exactly under every engine (selection, scheduling, folds and
    virtual time all identical) even with a tiny fetch cache forcing
    evictions mid-round."""
    def pop():
        return make_classification_population(
            20, dim=6, n_classes=3, mean_samples=12, batch_size=5, seed=2,
            fetch_cache_bytes=4 << 10)

    eager_params, eager_ms, _ = _run(engine, pop().materialize())
    lazy_params, lazy_ms, _ = _run(engine, pop())
    for a, b in zip(jax.tree.leaves(eager_params),
                    jax.tree.leaves(lazy_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert eager_ms == lazy_ms


# ---------------------------------------------------------------------------
# state-manager stats surfacing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_round_metrics_surface_state_manager_stats(engine):
    data = make_classification_clients(12, dim=6, n_classes=3,
                                       mean_samples=10, batch_size=5, seed=4)
    _, _, hist = _run(engine, data, rounds=2)
    for m in hist:
        sm = m.extra.get("state_manager")
        assert sm is not None
        for key in ("hits", "misses", "spills", "loads", "prefetched",
                    "mem_bytes", "shard_ram_bytes", "disk_bytes"):
            assert key in sm
    # round 2 re-selects known clients: the cache must report activity
    r2 = hist[1].extra["state_manager"]
    assert r2["hits"] + r2["misses"] > 0


def test_stateless_runs_omit_state_manager_extra():
    data = make_classification_clients(10, dim=6, n_classes=3,
                                       mean_samples=10, batch_size=5)
    algo = make_algorithm("fedavg", _grad_fn(), 0.05, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="pop_"))
    srv = ParrotServer(params=_params0(), algorithm=algo,
                       executors=[SequentialExecutor(0, algo,
                                                     state_manager=sm)],
                       data_by_client=data, clients_per_round=4, seed=0)
    m = srv.run_round()
    assert "state_manager" not in m.extra
