"""Device-parallel executors (DESIGN.md §8): placement, device-keyed caches,
device-resident wire path, and K-device vs single-device bit-exactness.

The parity matrix needs K > 1 real (virtual host) devices, and the device
count is frozen at backend init — so those scenarios run in a subprocess
that forces ``--xla_force_host_platform_device_count=4``
(``device_parity_driver.py``).  Everything else runs in-process and adapts
to however many devices this process has (1 in the plain tier-1 job, 4 in
the CI multi-device job).
"""
import json
import os
import re
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientStateManager, DevicePlacement, TickTimer,
                        make_algorithm)
from repro.core.aggregation import LocalAggregator, Op, global_aggregate
from repro.core.clock import VirtualClock
from repro.core.client_step import engine_for
from repro.core.flat import FlatLayout, flat_sums
from repro.core.placement import colocate
from repro.comm.local import LocalComm

HERE = os.path.dirname(__file__)


# ---------------------------------------------------------------------------
# placement unit tests (device-count agnostic)
# ---------------------------------------------------------------------------

def test_placement_round_robin_and_release():
    devs = jax.devices()
    pl = DevicePlacement(range(2 * len(devs) + 1))
    for k in range(2 * len(devs) + 1):
        assert pl.device(k) is devs[k % len(devs)]
    assert pl.server_device is devs[0]
    pl.release(0)
    assert 0 not in pl.executors()
    # mesh covers the distinct live devices, in pin order
    assert [d.id for d in pl.mesh().devices.flat] == \
        [d.id for d in pl.devices()]


def test_placement_from_pins_preserves_map():
    devs = jax.devices()
    pins = {7: devs[0], 3: devs[-1]}
    pl = DevicePlacement.from_pins(pins)
    assert pl.device(7) is devs[0] and pl.device(3) is devs[-1]
    assert pl.executors() == [3, 7]


def test_fail_device_repins_or_raises():
    devs = jax.devices()
    pl = DevicePlacement(range(4))
    if len(devs) == 1:
        with pytest.raises(RuntimeError):
            pl.fail_device(devs[0])
        return
    moved = pl.fail_device(devs[0])
    assert moved                      # executors lived there
    live_ids = {d.id for d in devs[1:]}
    for k in pl.executors():
        assert pl.device(k).id in live_ids


@pytest.mark.parametrize("psum_min", [0, None])
def test_global_fold_matches_host_aggregate(psum_min):
    """Placement fold == plain global_aggregate, bitwise, on however many
    devices this process has.  ``psum_min=0`` forces the shard_map/psum
    branch whenever each partial owns its own device (multi-device runs —
    the 4-virtual-device CI job), so the sharded reduction itself is
    pinned, not just the colocating fallback the small default threshold
    selects at test sizes."""
    devs = jax.devices()
    ops = {"delta": Op.WEIGHTED_AVG, "count": Op.SUM}
    payload = {"delta": {"w": np.arange(12, dtype=np.float32)},
               "count": np.float32(1.0)}
    layout = FlatLayout.build(ops, payload)
    rng = np.random.default_rng(0)
    K = max(2, len(devs))
    parts = []
    for i in range(K):
        buf = {"weighted": rng.standard_normal(12).astype(np.float32) * 11,
               "unit": rng.standard_normal(1).astype(np.float32)}
        parts.append({"sums": flat_sums(
            {g: jax.device_put(jnp.asarray(b), devs[i % len(devs)])
             for g, b in buf.items()}),
            "layout": layout, "weights": {"delta": 2.0 + i},
            "counts": {"delta": 2, "count": 1}, "collected": {},
            "n_clients": 2})
    pl = DevicePlacement(range(K))
    if psum_min is not None:
        pl.psum_min_elements = psum_min
    folded = pl.global_fold(parts, ops)
    host_parts = [dict(p, sums=flat_sums(
        {g: np.asarray(b) for g, b in p["sums"]["buffers"].items()}))
        for p in parts]
    ref = global_aggregate(host_parts, ops)
    np.testing.assert_array_equal(np.asarray(folded["delta"]["w"]),
                                  np.asarray(ref["delta"]["w"]))
    np.testing.assert_array_equal(np.asarray(folded["count"]),
                                  np.asarray(ref["count"]))
    # the fold lands on the server device
    assert list(folded["delta"]["w"].sharding.device_set) == [pl.server_device]


def test_colocate_moves_only_when_needed():
    devs = jax.devices()
    a = jax.device_put(jnp.ones(3), devs[0])
    assert colocate(a, a) is a
    b = jax.device_put(jnp.ones(3), devs[-1])
    moved = colocate(b, a)
    assert list(moved.sharding.device_set) == [devs[0]]


# ---------------------------------------------------------------------------
# device-keyed caches
# ---------------------------------------------------------------------------

def _grad_fn():
    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)
    return jax.jit(jax.value_and_grad(loss))


def _client_data(n_batches=2, seed=0):
    from repro.core.algorithms import ClientData
    rng = np.random.default_rng(seed)
    bs = [{"x": rng.standard_normal((4, 3)).astype(np.float32),
           "y": rng.standard_normal((4,)).astype(np.float32)}
          for _ in range(n_batches)]
    return ClientData(batches=bs, n_samples=4 * n_batches)


def test_engine_for_keys_on_device():
    algo = make_algorithm("fedavg", _grad_fn(), 0.1)
    devs = jax.devices()
    default = engine_for(algo)
    assert engine_for(algo) is default            # stable for None
    pinned = engine_for(algo, devs[0])
    assert pinned is not default                  # device-keyed
    assert engine_for(algo, devs[0]) is pinned    # stable per device
    if len(devs) > 1:
        assert engine_for(algo, devs[1]) is not pinned
    assert pinned.device is devs[0]


def test_pinned_engine_outputs_resident():
    devs = jax.devices()
    dev = devs[-1]
    algo = make_algorithm("fedavg", _grad_fn(), 0.1)
    eng = engine_for(algo, dev)
    params = {"w": np.zeros(3, dtype=np.float32)}
    payload = algo.broadcast_payload(params, algo.server_init(params))
    res, _ = eng.run_client(payload, _client_data(), None)
    for leaf in jax.tree.leaves(res.payload):
        assert list(leaf.sharding.device_set) == [dev]


def test_flatten_device_commit():
    ops = {"delta": Op.WEIGHTED_AVG}
    payload = {"delta": {"w": np.ones((4, 4), np.float32)}}
    layout = FlatLayout.build(ops, payload)
    dev = jax.devices()[-1]
    bufs = layout.flatten(payload, device=dev)
    assert list(bufs["weighted"].sharding.device_set) == [dev]
    # same layout, other placements: no cross-wiring, values identical
    host = layout.flatten(payload)
    np.testing.assert_array_equal(np.asarray(bufs["weighted"]),
                                  np.asarray(host["weighted"]))
    assert list(layout.zeros(dev)["weighted"].sharding.device_set) == [dev]


def test_local_aggregator_device_resident_partial():
    dev = jax.devices()[-1]
    ops = {"delta": Op.WEIGHTED_AVG}
    agg = LocalAggregator(ops, device=dev)
    from repro.core.aggregation import ClientResult
    agg.fold(ClientResult({"delta": {"w": np.ones(5, np.float32)}}, ops, 2.0))
    part = agg.partial()
    buf = part["sums"]["buffers"]["weighted"]
    assert list(buf.sharding.device_set) == [dev]


# ---------------------------------------------------------------------------
# stacked-batch device cache
# ---------------------------------------------------------------------------

def _executor(**kw):
    from repro.core.executor import SequentialExecutor
    algo = make_algorithm("fedavg", _grad_fn(), 0.1)
    return SequentialExecutor(0, algo, **kw)


def test_batch_cache_hit_and_identity():
    ex = _executor(device=jax.devices()[-1])
    data = _client_data()
    s1, m1 = ex._prep_batches(1, data)
    s2, m2 = ex._prep_batches(1, data)
    assert s1 is s2 and m1 is m2                  # served from cache
    for leaf in jax.tree.leaves(s1):
        assert list(leaf.sharding.device_set) == [jax.devices()[-1]]


def test_batch_cache_lru_eviction_respects_budget():
    data = {i: _client_data(seed=i) for i in range(8)}
    one = _executor()
    s, m = one._prep_batches(0, data[0])
    per_client = sum(int(x.nbytes) for x in jax.tree.leaves(s)) + m.nbytes
    ex = _executor(batch_cache_bytes=3 * per_client)
    for i in range(8):
        ex._prep_batches(i, data[i])
    assert len(ex._batch_cache) == 3
    assert set(ex._batch_cache) == {5, 6, 7}      # LRU kept the newest
    assert ex._batch_cache_used <= ex.batch_cache_bytes
    # re-touch oldest survivor, insert one more: 5 was just used, 6 evicts
    ex._prep_batches(5, data[5])
    ex._prep_batches(0, data[0])
    assert set(ex._batch_cache) == {7, 5, 0}


def test_batch_cache_invalidates_on_swapped_dataset():
    ex = _executor()
    d1, d2 = _client_data(seed=1), _client_data(seed=2)
    s1, _ = ex._prep_batches(1, d1)
    s2, _ = ex._prep_batches(1, d2)               # same client, new data
    assert s1 is not s2
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(s2)[0][0]),
                                  d2.batches[0]["x"])


def test_batch_cache_disabled_with_zero_budget():
    ex = _executor(batch_cache_bytes=0)
    data = _client_data()
    ex._prep_batches(1, data)
    assert len(ex._batch_cache) == 0


def test_set_device_drops_device_caches_keeps_costs():
    devs = jax.devices()
    ex = _executor(device=devs[0])
    ex._prep_batches(1, _client_data())
    ex._block_cost[("sig", 4)] = 0.5
    ex.set_device(devs[-1] if len(devs) > 1 else None)
    assert not ex._batch_cache and ex._payload_cache._key is None
    assert ex._block_cost == {("sig", 4): 0.5}


# ---------------------------------------------------------------------------
# device-aware state manager
# ---------------------------------------------------------------------------

def test_state_manager_device_load_and_keep_device():
    dev = jax.devices()[-1]
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d)
        st = {"c": np.arange(6, dtype=np.float32)}
        sm.save(0, st)
        out = sm.load_many([0, 1], device=dev)
        assert out[1] is None
        assert list(out[0]["c"].sharding.device_set) == [dev]
        np.testing.assert_array_equal(np.asarray(out[0]["c"]), st["c"])
        # keep_device save keeps the jax array; spill still round-trips
        dev_state = {"c": jax.device_put(jnp.arange(3.0), dev)}
        sm.save_many({2: dev_state}, keep_device=True)
        assert sm.load(2)["c"] is dev_state["c"]
        sm2 = ClientStateManager(d, memory_budget_bytes=1)  # spill everything
        sm2.save_many({3: dev_state, 4: dev_state}, keep_device=True)
        np.testing.assert_array_equal(np.asarray(sm2.load(3)["c"]),
                                      np.arange(3.0, dtype=np.float32))


# ---------------------------------------------------------------------------
# device-resident wire path (no host round-trip, no copy)
# ---------------------------------------------------------------------------

def test_local_comm_ships_device_buffers_by_reference():
    dev = jax.devices()[-1]
    buf = jax.device_put(jnp.arange(8.0), dev)
    partial = {"sums": flat_sums({"weighted": buf}), "layout": None,
               "weights": {}, "counts": {}, "collected": {}, "n_clients": 1}
    comm = LocalComm()
    comm.executor_send(3, partial, tag="partial")
    got = comm.poll(3, tag="partial")
    assert got is partial                               # zero-copy
    assert got["sums"]["buffers"]["weighted"] is buf    # still resident
    assert list(buf.sharding.device_set) == [dev]
    assert comm.stats.bytes_sent > 0                    # accounted anyway


def test_collective_comm_ships_device_buffers_by_reference():
    from repro.comm.collective import CollectiveComm
    dev = jax.devices()[-1]
    buf = jax.device_put(jnp.arange(8.0), dev)
    partial = {"sums": flat_sums({"weighted": buf}), "collected": {}}
    comm = CollectiveComm()
    comm.executor_send(1, partial, tag="partial")
    got = comm.poll(1, tag="partial")
    assert got is partial
    assert got["sums"]["buffers"]["weighted"] is buf


# ---------------------------------------------------------------------------
# clock serialisation (async checkpoint plumbing)
# ---------------------------------------------------------------------------

def test_virtual_clock_state_roundtrip_preserves_order_and_seq():
    c = VirtualClock()
    c.push(2.0, "b", "late")
    c.push(1.0, "a", "early")
    c.push(1.0, "a2", "tie")        # same time: seq breaks the tie
    c.pop()                          # consume "early"; now=1.0, seq=3
    r = VirtualClock.from_state_dict(c.state_dict())
    assert r.now == c.now and r._seq == c._seq
    ev = r.push(1.5, "new")
    assert ev.seq == 3               # numbering continues, not restarts
    kinds = [r.pop().kind for _ in range(len(r))]
    assert kinds == ["a2", "new", "b"]


# ---------------------------------------------------------------------------
# K-device parity matrix (subprocess with 4 forced host devices)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_four_device_parity_matrix():
    env = dict(os.environ)
    flags = re.sub(r"--xla_force_host_platform_device_count=\d+", "",
                   env.get("XLA_FLAGS", ""))
    env["XLA_FLAGS"] = (flags + " --xla_force_host_platform_device_count=4"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, os.path.join(HERE, "device_parity_driver.py")],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["n_devices"] == 4
    failures = {k: v for k, v in out.items() if v is False}
    assert not failures, f"parity failures: {failures}"
