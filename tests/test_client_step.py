"""Compiled client-training engine ⟷ eager reference equivalence.

The acceptance bar for ``core/client_step.py``: the jit-scanned step form
must reproduce the eager ``client_update`` (all six algorithms, including
mask-padded non-power-of-two batch counts), the vmapped block form must
reproduce the single-client form row by row, and a blocked end-to-end
ParrotServer round (B>1) must match both B=1 and ``run_flat_reference`` —
with SCAFFOLD/FedDyn state round-tripping through the state manager.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientData, ClientStateManager, LocalAggregator, Op,
                        ParrotServer, SequentialExecutor, engine_for,
                        make_algorithm, run_flat_reference)
from repro.core.client_step import batch_signature, stack_batches
from repro.data import make_classification_clients

ALGOS = ["fedavg", "fedprox", "fednova", "mime", "scaffold", "feddyn"]


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


def _client(n_batches, bs=10, seed=0):
    rng = np.random.default_rng(seed)
    batches = [{"x": rng.normal(size=(bs, 8)).astype(np.float32),
                "y": rng.integers(0, 4, size=(bs,)).astype(np.int32)}
               for _ in range(n_batches)]
    return ClientData(batches=batches, n_samples=n_batches * bs)


def _max_diff(a, b):
    leaves_a, leaves_b = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(leaves_a) == len(leaves_b)
    return max(float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                     - jnp.asarray(y, jnp.float32))))
               for x, y in zip(leaves_a, leaves_b))


def _setup(name, local_epochs=2):
    algo = make_algorithm(name, GRAD_FN, 0.1, local_epochs=local_epochs)
    server_state = algo.server_init(PARAMS0)
    payload = algo.broadcast_payload(PARAMS0, server_state)
    state = algo.client_init_state(PARAMS0) if algo.stateful else None
    return algo, payload, state


# ---------------------------------------------------------------------------
# compiled scan vs eager client_update
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALGOS)
@pytest.mark.parametrize("n_batches", [1, 3, 4])   # 3: mask-padded bucket
def test_compiled_scan_matches_eager(name, n_batches):
    algo, payload, state = _setup(name)
    data = _client(n_batches, seed=n_batches)
    res_e, state_e = algo.client_update(payload, data, state)
    res_c, state_c = engine_for(algo).run_client(payload, data, state)
    assert set(res_c.payload) == set(res_e.payload)
    assert res_c.weight == res_e.weight
    for entry in res_e.payload:
        assert _max_diff(res_e.payload[entry], res_c.payload[entry]) < 1e-6
    if algo.stateful:
        assert _max_diff(state_e, state_c) < 1e-6
    else:
        assert state_c is None


@pytest.mark.parametrize("name", ["fedavg", "scaffold"])
def test_ragged_batches_fall_back_to_eager(name):
    algo, payload, state = _setup(name)
    data = ClientData(batches=[
        {"x": np.zeros((10, 8), np.float32), "y": np.zeros((10,), np.int32)},
        {"x": np.zeros((7, 8), np.float32), "y": np.zeros((7,), np.int32)},
    ], n_samples=17)
    assert batch_signature(data) is None
    assert stack_batches(data) is None
    res_e, _ = algo.client_update(payload, data, state)
    res_c, _ = engine_for(algo).run_client(payload, data, state)
    for entry in res_e.payload:
        assert _max_diff(res_e.payload[entry], res_c.payload[entry]) == 0.0


@pytest.mark.parametrize("name", ["fedavg", "fednova", "mime", "scaffold"])
def test_compiled_scan_handles_bf16_params(name):
    """The f32 step mask must not promote a bf16 carry (scan's carry-type
    invariant) — and results must stay close to the eager reference."""
    params = {"w": jnp.zeros((8, 4), jnp.bfloat16),
              "b": jnp.zeros((4,), jnp.bfloat16)}
    algo = make_algorithm(name, GRAD_FN, 0.1, local_epochs=1)
    payload = algo.broadcast_payload(params, algo.server_init(params))
    state = algo.client_init_state(params) if algo.stateful else None
    data = _client(3, seed=5)
    res_e, _ = algo.client_update(payload, data, state)
    res_c, _ = engine_for(algo).run_client(payload, data, state)
    for entry in res_e.payload:
        for le, lc in zip(jax.tree.leaves(res_e.payload[entry]),
                          jax.tree.leaves(res_c.payload[entry])):
            assert lc.dtype == le.dtype
        assert _max_diff(res_e.payload[entry], res_c.payload[entry]) < 1e-2


def test_bf16_mime_survives_multiple_rounds():
    """server_update must not promote the broadcast momentum to f32 — the
    round-2 compiled scan would hit a carry-dtype mismatch."""
    params = {"w": jnp.zeros((8, 4), jnp.bfloat16),
              "b": jnp.zeros((4,), jnp.bfloat16)}
    data = {c: _client(3, seed=30 + c) for c in range(8)}
    algo = make_algorithm("mime", GRAD_FN, 0.1, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm, client_block=4)
             for k in range(2)]
    srv = ParrotServer(params=params, algorithm=algo, executors=execs,
                       data_by_client=data, clients_per_round=6, seed=7)
    srv.run(3)
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(srv.server_state["momentum"]))


def test_signature_buckets_batch_counts():
    # 3 and 4 batches share the bucket-4 signature; 5 does not
    assert batch_signature(_client(3)) == batch_signature(_client(4))
    assert batch_signature(_client(3)) != batch_signature(_client(5))


# ---------------------------------------------------------------------------
# vmapped block vs single-client scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ALGOS)
def test_vmapped_block_matches_single(name):
    algo, payload, _ = _setup(name)
    # 5 clients forces block padding to the bucket of 8; mixed 3/4-batch
    # clients share a bucket through mask padding
    datas = [_client(3 + (i % 2), seed=10 + i) for i in range(5)]
    states = [algo.client_init_state(PARAMS0) for _ in datas] \
        if algo.stateful else None
    eng = engine_for(algo)
    stacked, new_states = eng.run_block(payload, datas, states)
    for i, data in enumerate(datas):
        res_1, state_1 = eng.run_client(
            payload, data, states[i] if states else None)
        row = {k: jax.tree.map(lambda x: x[i], v) for k, v in stacked.items()}
        for entry in res_1.payload:
            assert _max_diff(res_1.payload[entry], row[entry]) < 1e-6
        if algo.stateful:
            assert _max_diff(state_1, new_states[i]) < 1e-6


@pytest.mark.parametrize("use_kernel", [False, True])
def test_fold_block_matches_per_client_folds(use_kernel):
    """LocalAggregator.fold_block == B eager folds (same contraction)."""
    algo, payload, _ = _setup("mime")   # WEIGHTED_AVG delta + COLLECT grads
    datas = [_client(4, seed=20 + i) for i in range(4)]
    eng = engine_for(algo)
    ops = algo.ops()
    agg_a = LocalAggregator(ops, use_kernel=use_kernel)
    stacked, _ = eng.run_block(payload, datas)
    weights = [float(d.n_samples) for d in datas]
    agg_a.fold_block(stacked, weights)
    agg_b = LocalAggregator(ops, use_kernel=use_kernel)
    for d in datas:
        res, _ = eng.run_client(payload, d)
        agg_b.fold(res)
    pa, pb = agg_a.partial(), agg_b.partial()
    assert pa["n_clients"] == pb["n_clients"] == 4
    assert pa["weights"] == pb["weights"]
    assert pa["counts"] == pb["counts"]
    for g in pb["sums"]["buffers"]:
        assert _max_diff(pa["sums"]["buffers"][g],
                         pb["sums"]["buffers"][g]) < 1e-6
    # COLLECT extraction from the vmapped output: per-client (w, pytree)
    assert len(pa["collected"]["full_grad"]) == 4
    for (wa, ga), (wb, gb) in zip(pa["collected"]["full_grad"],
                                  pb["collected"]["full_grad"]):
        assert wa == wb
        assert _max_diff(ga, gb) < 1e-6


# ---------------------------------------------------------------------------
# end-to-end: blocked rounds vs B=1 vs flat reference
# ---------------------------------------------------------------------------

def _run_server(name, data, client_block, budget=1 << 30, use_kernel=False):
    algo = make_algorithm(name, GRAD_FN, 0.1, local_epochs=2)
    sm = ClientStateManager(tempfile.mkdtemp(),
                            memory_budget_bytes=budget)
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                client_block=client_block,
                                use_agg_kernel=use_kernel)
             for k in range(4)]
    srv = ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                       data_by_client=data, clients_per_round=10, seed=7)
    srv.run(3)
    return srv


@pytest.mark.parametrize("name", ALGOS)
def test_blocked_round_matches_flat_reference(name):
    data = make_classification_clients(40, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10, seed=1)
    flat, _ = run_flat_reference(
        PARAMS0, make_algorithm(name, GRAD_FN, 0.1, local_epochs=2),
        data, clients_per_round=10, n_rounds=3, seed=7)
    srv_b1 = _run_server(name, data, client_block=1)
    srv_b16 = _run_server(name, data, client_block=16)
    assert _max_diff(flat, srv_b1.params) < 1e-5
    assert _max_diff(flat, srv_b16.params) < 1e-5
    assert _max_diff(srv_b1.params, srv_b16.params) < 1e-6


@pytest.mark.parametrize("name", ["scaffold", "feddyn"])
def test_blocked_stateful_state_roundtrip_through_manager(name):
    """Blocked runs must load/save the SAME per-client states the eager
    path does — even when a tiny budget spills every state to disk."""
    data = make_classification_clients(30, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10, seed=2)
    srv_spill = _run_server(name, data, client_block=16, budget=1024)
    srv_eager = _run_server(name, data, client_block=1, budget=1 << 30)
    assert _max_diff(srv_spill.params, srv_eager.params) < 1e-5
    sm = next(iter(srv_spill.executors.values())).state_manager
    assert sm.stats["spills"] > 0 and sm.stats["loads"] > 0
    # states landed per client, not per block
    assert len(sm.known_clients()) > 0


def test_blocked_round_with_agg_kernel():
    data = make_classification_clients(30, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10, seed=3)
    srv_k = _run_server("fedavg", data, client_block=8, use_kernel=True)
    srv_j = _run_server("fedavg", data, client_block=8, use_kernel=False)
    assert _max_diff(srv_k.params, srv_j.params) < 1e-6


def test_engine_dispatch_counts_drop_with_blocking():
    """One compiled dispatch per block, not per client."""
    algo, payload, _ = _setup("fedavg")
    datas = [_client(4, seed=40 + i) for i in range(8)]
    eng = engine_for(algo)
    before = eng.n_dispatches
    eng.run_block(payload, datas)
    assert eng.n_dispatches == before + 1
    for d in datas:
        eng.run_client(payload, d)
    assert eng.n_dispatches == before + 1 + len(datas)
