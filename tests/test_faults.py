"""Fault-injection & recovery tests (DESIGN.md §10).

Two anchors:

* **Neutrality** — ``faults=None`` takes the pre-fault code paths verbatim,
  and an EMPTY plan must behave identically (params AND makespan history):
  the injector may be consulted, but consulting it must not move a float.
* **Determinism under chaos** — a seeded :class:`FaultPlan` drives crashes,
  restarts, dropouts, corruption, blackouts and slowdowns through all three
  engines, and two runs of the same plan produce bit-identical params
  (digest equality) without livelock.

Around the anchors: the injector's pure query logic (blackout-paused
transfers, timeout/backoff pricing, retry budgets, one-shot consumption),
quorum-degraded commits, chunk timeout accounting, checkpoint corruption
detection, the ``ExecutorFailure`` pickle contract, and the
``fail_at=(-1, i)`` wildcard's run_queue/gang-dispatch consistency.
"""
import math
import os
import pickle
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointManager, params_digest,
                                      restore_latest)
from repro.core import (ClientStateManager, NetworkModel, ParrotServer,
                        SequentialExecutor, TickTimer, make_algorithm)
from repro.core.executor import ExecutorFailure
from repro.core.faults import (BLACKOUT, CORRUPT, CRASH, DROPOUT, RESTART,
                               SLOWDOWN, FaultEvent, FaultInjector,
                               FaultPlan, RetryPolicy)
from repro.core.scheduler import ClientTask, WorkloadModel
from repro.data import make_classification_clients


# ---------------------------------------------------------------------------
# plan / injector unit tests (no jax compute)
# ---------------------------------------------------------------------------

def test_plan_validates_and_sorts():
    with pytest.raises(ValueError):
        FaultEvent(time=0.0, kind="meteor")
    with pytest.raises(ValueError):
        FaultPlan([FaultEvent(time=1.0, kind=CRASH)])        # no executor
    with pytest.raises(ValueError):
        FaultPlan([FaultEvent(time=1.0, kind=DROPOUT)])      # no client
    plan = FaultPlan([FaultEvent(time=5.0, kind=RESTART, executor=1),
                      FaultEvent(time=1.0, kind=CRASH, executor=1),
                      FaultEvent(time=1.0, kind=CRASH, executor=0)])
    assert [(e.time, e.kind, e.executor) for e in plan] == [
        (1.0, CRASH, 0), (1.0, CRASH, 1), (5.0, RESTART, 1)]


def test_random_plan_is_seed_deterministic_and_spares():
    kw = dict(horizon=100.0, executors=[0, 1, 2, 3], clients=list(range(20)),
              crash_rate=0.05, restart_delay=4.0, dropout_rate=0.05,
              corrupt_rate=0.03, blackout_rate=0.02, slowdown_rate=0.02,
              spare=2)
    a, b = FaultPlan.random(seed=11, **kw), FaultPlan.random(seed=11, **kw)
    assert a.events == b.events
    assert FaultPlan.random(seed=12, **kw).events != a.events
    # the first `spare` executors (sorted) are never crashed, and every
    # crash is paired with a restart for the same executor
    crashed = [e.executor for e in a.of_kind(CRASH)]
    assert all(k >= 2 for k in crashed)
    assert sorted(crashed) == sorted(e.executor for e in a.of_kind(RESTART))


def test_crash_restart_one_shot_lifecycle():
    fi = FaultInjector(FaultPlan([
        FaultEvent(time=2.0, kind=CRASH, executor=1),
        FaultEvent(time=6.0, kind=RESTART, executor=1)]))
    assert fi.crash_due(1, 1.9) is None
    assert fi.crash_due(1, 2.5) == 2.0
    assert fi.crash_in(1, 0.0, 5.0) == (0, 2.0)
    assert fi.fire_crash(1, 2.5) is True
    assert fi.crash_due(1, 2.5) is None          # consumed
    assert fi.fire_crash(1, 99.0) is False
    assert fi.restarts_due(5.0) == []
    assert fi.restarts_due(6.0) == [1]
    assert fi.restarts_due(6.0) == []            # consumed


def test_injector_state_roundtrips():
    fi = FaultInjector(FaultPlan([
        FaultEvent(time=1.0, kind=CORRUPT, executor=0)]),
        RetryPolicy(max_retries=1))
    assert fi.take_corrupt(0, 2.0) is True
    assert fi.take_corrupt(0, 2.0) is False      # one-shot
    retry, give_up = fi.charge_retry([7, 7])
    assert retry == [7] and give_up == [7]       # budget of 1
    blob = pickle.loads(pickle.dumps(fi.state_dict()))
    fj = FaultInjector(fi.plan, fi.retry)
    fj.load_state_dict(blob)
    assert fj.take_corrupt(0, 2.0) is False      # fired state survived
    assert fj.charge_retry([7]) == ([], [7])     # budget state survived
    fj.clear_retries([7])
    assert fj.charge_retry([7]) == ([7], [])


def test_dropout_windows_and_split():
    fi = FaultInjector(FaultPlan([
        FaultEvent(time=10.0, kind=DROPOUT, client=3, duration=5.0)]))
    assert not fi.client_down(3, 9.9)
    assert fi.client_down(3, 10.0) and fi.client_down(3, 14.9)
    assert not fi.client_down(3, 15.0)
    tasks = [ClientTask(3, 10), ClientTask(4, 10)]
    up, down = fi.split_up(tasks, 8.0, 1.0)      # window opens after span
    assert [t.client for t in up] == [3, 4] and down == []
    up, down = fi.split_up(tasks, 8.0, 3.0)      # window opens inside span
    assert [t.client for t in up] == [4]
    assert [t.client for t in down] == [3]
    assert fi.upload_lost([3], 9.0, 11.0)        # opens mid-flight
    assert not fi.upload_lost([3], 16.0, 20.0)


def test_blackout_pauses_transfers():
    fi = FaultInjector(FaultPlan([
        FaultEvent(time=4.0, kind=BLACKOUT, duration=2.0),
        FaultEvent(time=8.0, kind=BLACKOUT, duration=1.0, executor=1)]))
    assert fi.xfer_end(0.0, 3.0) == 3.0          # finishes before window
    assert fi.xfer_end(0.0, 5.0) == 7.0          # pauses through [4, 6)
    assert fi.xfer_end(4.5, 0.0) == 6.0          # link down at start
    # executor-local window only pauses that executor's transfers
    assert fi.xfer_end(7.5, 1.0, executor=1) == 9.5
    assert fi.xfer_end(7.5, 1.0, executor=0) == 8.5


def test_price_upload_timeout_backoff_and_give_up():
    fi = FaultInjector(
        FaultPlan([FaultEvent(time=0.0, kind=BLACKOUT, duration=100.0)]),
        RetryPolicy(timeout_s=2.0, max_retries=2, backoff_s=1.0,
                    backoff_mult=2.0))
    from repro.core.faults import FaultCounters
    c = FaultCounters()
    # the link is dark for 100s: every attempt times out -> payload lost
    assert fi.price_upload(0.0, 1.0, None, [5], 10, c) is None
    assert c.timeouts == 3 and c.retries == 2
    # no blackout: first attempt lands at t + duration
    fj = FaultInjector(FaultPlan(()), RetryPolicy(timeout_s=2.0))
    assert fj.price_upload(5.0, 1.5, None, [5], 10) == 6.5


def test_slowdown_scales_models_and_composes():
    fi = FaultInjector(FaultPlan([
        FaultEvent(time=0.0, kind=SLOWDOWN, executor=0, duration=10.0,
                   factor=2.0),
        FaultEvent(time=5.0, kind=SLOWDOWN, executor=0, duration=10.0,
                   factor=3.0)]))
    assert fi.slowdown(0, 2.0) == 2.0
    assert fi.slowdown(0, 7.0) == 6.0            # windows compound
    assert fi.slowdown(1, 7.0) == 1.0
    m = WorkloadModel(t_sample=0.5, b=1.0)
    sm = fi.scaled_model(m, 0, 7.0)
    assert sm.t_sample == 3.0 and sm.b == 6.0
    assert fi.scaled_model(m, 0, 50.0) is m      # outside: same object
    assert fi.scaled_model(None, 0, 7.0) is None


def test_executor_failure_pickle_roundtrip():
    err = ExecutorFailure(2, 5, 7, device="cpu:0", chunk=(6, 9), vtime=12.5)
    back = pickle.loads(pickle.dumps(err))
    assert isinstance(back, ExecutorFailure)
    assert (back.executor, back.rnd, back.task_index) == (2, 5, 7)
    assert back.device == "cpu:0"
    assert back.chunk == (6, 9)
    assert back.vtime == 12.5
    assert "device=cpu:0" in str(back) and "chunk=[6,9)" in str(back)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
ENGINES = ["bsp", "semi-sync", "async"]


@pytest.fixture(scope="module")
def data():
    return make_classification_clients(30, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=1)


def _build(data, engine, faults=None, retry=None, opts=None, network=None,
           ckpt_dir=None, fail_at=None, K=3, **kw):
    algo = make_algorithm("fedavg", grad_fn=GRAD_FN, lr=0.1, local_steps=2)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = []
    for k in range(K):
        e = SequentialExecutor(k, algo, state_manager=sm,
                               speed_model=lambda kk, r: 0.0,
                               timer=TickTimer(1.0))
        if fail_at and k == fail_at[0]:
            e.fail_at = fail_at[1]
        execs.append(e)
    cm = (CheckpointManager(ckpt_dir, every_rounds=1, keep=10)
          if ckpt_dir else None)
    if opts is None:
        opts = {} if engine == "bsp" else {"chunk_size": 2}
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=8, seed=7,
                        round_engine=engine, engine_opts=opts,
                        faults=faults, retry=retry, network=network,
                        checkpoint_manager=cm, **kw)


def _chaos_plan():
    return FaultPlan.random(seed=3, horizon=80.0, executors=[0, 1, 2],
                            clients=list(range(30)),
                            crash_rate=0.05, restart_delay=5.0,
                            dropout_rate=0.1, dropout_duration=4.0,
                            corrupt_rate=0.05,
                            blackout_rate=0.03, blackout_duration=1.0,
                            slowdown_rate=0.03, slowdown_duration=6.0)


def _tot(srv, key):
    return sum(m.extra.get(key, 0.0) for m in srv.history)


@pytest.mark.parametrize("engine", ENGINES)
def test_empty_plan_is_bit_exact_with_none(data, engine):
    """An empty FaultPlan (injector active, nothing scheduled) must leave
    params AND makespans identical to faults=None — consulting the
    injector may not move a single float."""
    a = _build(data, engine)
    a.run(5)
    b = _build(data, engine, faults=FaultPlan(()),
               retry=RetryPolicy(timeout_s=math.inf))
    b.run(5)
    assert params_digest(a.params) == params_digest(b.params)
    assert [m.makespan for m in a.history] == \
        [m.makespan for m in b.history]


@pytest.mark.parametrize("engine", ENGINES)
def test_chaos_soak_deterministic_no_livelock(data, engine):
    """20 rounds under a dense seeded chaos plan (all six fault kinds, a
    network model so the retry/blackout pricing paths run): two runs agree
    bit-for-bit on params, the run terminates (no livelock), and the
    unified metrics schema is present every round."""
    plan = _chaos_plan()
    net = NetworkModel.uniform(8e6, 16e6, latency_s=0.05)
    digests, servers = [], []
    for _ in range(2):
        srv = _build(data, engine, faults=plan,
                     retry=RetryPolicy(timeout_s=3.0, max_retries=2,
                                       backoff_s=0.5), network=net)
        srv.run(20)
        digests.append(params_digest(srv.params))
        servers.append(srv)
    assert digests[0] == digests[1]
    srv = servers[0]
    assert len(srv.history) == 20
    for m in srv.history:         # unified failure/dropout metrics schema
        assert "retries" in m.extra
        assert "corrupt_payloads" in m.extra
        assert "dropped_clients" in m.extra
        assert m.failures >= 0
    # the plan actually exercised the machinery
    assert _tot(srv, "fault_crashes") >= 1
    assert _tot(srv, "fault_restarts") >= 1
    assert _tot(srv, "corrupt_payloads") >= 1
    assert _tot(srv, "retries") >= 1


def test_chunk_timeout_retries_then_drops(data):
    """A blackout longer than every retry's timeout+backoff forces the
    timeout/backoff path: attempts are re-priced and counted, and the
    payload is eventually lost (clients dropped from the round)."""
    plan = FaultPlan([FaultEvent(time=0.0, kind=BLACKOUT, duration=500.0)])
    srv = _build(data, "bsp", faults=plan,
                 retry=RetryPolicy(timeout_s=1.0, max_retries=2,
                                   backoff_s=0.5),
                 network=NetworkModel.uniform(8e6, 16e6, latency_s=0.05))
    srv.run(2)
    assert _tot(srv, "chunk_timeouts") >= 3      # every attempt timed out
    assert _tot(srv, "retries") >= 2
    assert _tot(srv, "dropped_clients") >= 1     # payloads lost for good


@pytest.mark.parametrize("engine,opts", [
    ("bsp", {"quorum_frac": 0.5}),
    ("semi-sync", {"chunk_size": 2, "quorum_frac": 0.5})])
def test_quorum_commits_degraded_rounds(data, engine, opts):
    plan = _chaos_plan()
    srv = _build(data, engine, faults=plan, retry=RetryPolicy(),
                 opts=opts,
                 network=NetworkModel.uniform(8e6, 16e6, latency_s=0.05))
    srv.run(15)
    assert _tot(srv, "quorum_commits") >= 1
    # deterministic under the quorum too
    srv2 = _build(data, engine, faults=plan, retry=RetryPolicy(),
                  opts=opts,
                  network=NetworkModel.uniform(8e6, 16e6, latency_s=0.05))
    srv2.run(15)
    assert params_digest(srv.params) == params_digest(srv2.params)


def test_quorum_frac_validated():
    from repro.core.engine import BSPEngine, SemiSyncEngine
    with pytest.raises(ValueError):
        BSPEngine(quorum_frac=0.0)
    with pytest.raises(ValueError):
        SemiSyncEngine(chunk_size=2, quorum_frac=1.5)


def test_wildcard_fail_at_consistent_run_queue_vs_gang(data):
    """``fail_at=(-1, i)`` (fail in EVERY round at task i) must behave
    identically whether the round takes the gang-dispatch path or the
    serial run_queue path: the executor is ineligible for the gang (its
    compiled fast path would skip the failure hook) and raises from the
    eager path instead — the BSP failure handling then re-runs its queue.
    """
    probe = SequentialExecutor(0, make_algorithm(
        "fedavg", grad_fn=GRAD_FN, lr=0.1), fail_at=(-1, 2))
    assert probe.fail_pending(0) and probe.fail_pending(17)
    probe.fail_at = (3, 2)
    assert probe.fail_pending(3) and not probe.fail_pending(4)

    a = _build(data, "bsp", fail_at=(1, (-1, 0)), gang_dispatch=True)
    ma = a.run(2)
    b = _build(data, "bsp", fail_at=(1, (-1, 0)), gang_dispatch=False)
    mb = b.run(2)
    # the wildcard fired in round 0 under both dispatch modes, the failed
    # executor was dropped, and the surviving params agree bit-for-bit
    assert ma[0].failures == 1 and mb[0].failures == 1
    assert 1 not in a.executors and 1 not in b.executors
    assert params_digest(a.params) == params_digest(b.params)
    assert [m.makespan for m in ma] == [m.makespan for m in mb]


def test_executor_failure_carries_context(data):
    algo = make_algorithm("fedavg", grad_fn=GRAD_FN, lr=0.1, local_steps=2)
    ex = SequentialExecutor(0, algo,
                            state_manager=ClientStateManager(
                                tempfile.mkdtemp()),
                            speed_model=lambda kk, r: 0.0,
                            timer=TickTimer(1.0), fail_at=(0, 1))
    payload = algo.broadcast_payload(PARAMS0, algo.server_init(PARAMS0))
    tasks = [ClientTask(c, data[c].n_samples) for c in (0, 1, 2)]
    with pytest.raises(ExecutorFailure) as ei:
        ex.run_queue(0, tasks, payload, data)
    err = ei.value
    assert err.executor == 0 and err.rnd == 0 and err.task_index == 1
    assert err.chunk is not None and err.vtime is not None
    back = pickle.loads(pickle.dumps(err))
    assert (back.executor, back.rnd, back.task_index, back.chunk,
            back.vtime) == (err.executor, err.rnd, err.task_index,
                            err.chunk, err.vtime)


# ---------------------------------------------------------------------------
# checkpoint integrity
# ---------------------------------------------------------------------------

def test_restore_rejects_corrupt_blob_and_walks_back(data):
    d = tempfile.mkdtemp()
    srv = _build(data, "bsp", ckpt_dir=d)
    srv.run(3)
    want_round2 = params_digest(srv.params)
    # corrupt the newest checkpoint's blob (bit rot): flip payload bytes
    # while keeping the manifest intact
    steps = sorted(s for s in os.listdir(d) if s.startswith("step_"))
    newest = os.path.join(d, steps[-1])
    blob_path = os.path.join(newest, "server.pkl")
    with open(blob_path, "rb") as f:
        blob = pickle.load(f)
    blob["params"] = jax.tree.map(lambda x: np.asarray(x) + 1.0,
                                  blob["params"])
    with open(blob_path, "wb") as f:
        pickle.dump(blob, f)
    # direct restore refuses, leaving the server untouched
    fresh = _build(data, "bsp")
    before = params_digest(fresh.params)
    with pytest.raises(ValueError, match="integrity"):
        CheckpointManager(d).restore(fresh, newest)
    assert params_digest(fresh.params) == before
    # restore_latest walks back to the newest VALID checkpoint (round 2)
    got = restore_latest(fresh, d)
    assert got == 2
    assert fresh.round == 2
    # ...and replaying the final round reproduces the uninterrupted params
    fresh.run_round()
    assert params_digest(fresh.params) == want_round2
