"""Sharding-rule tests: every (arch × shape) cell's specs must be valid
(divisible) on the production meshes.  Uses AbstractMesh — no device init,
so this runs in the normal 1-device test process."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import ALL_SHAPES
from repro.configs.registry import ARCHS, cell_is_runnable
from repro.launch.inputs import params_abstract
from repro.models import transformer
from repro.sharding import specs as shard_specs

def _abstract_mesh(sizes, names):
    """AbstractMesh across jax versions: >=0.5 takes (axis_sizes,
    axis_names); 0.4.x takes a single ((name, size), ...) shape tuple."""
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, sizes)))


MESHES = {
    "pod": _abstract_mesh((16, 16), ("data", "model")),
    "multipod": _abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _check_spec(spec: P, shape, mesh, where):
    for i, axes in enumerate(spec):
        if axes is None:
            continue
        n = shard_specs.axis_size(
            mesh, axes if isinstance(axes, (tuple, list)) else (axes,))
        assert shape[i] % n == 0, \
            f"{where}: dim {i} of {shape} not divisible by {n} ({spec})"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divisible(mesh_name, arch):
    mesh = MESHES[mesh_name]
    p_sds = params_abstract(ARCHS[arch])
    tied = "lm_head" not in p_sds

    def check(path, leaf):
        spec = shard_specs.param_spec(path, leaf.shape, mesh,
                                      tied_embeddings=tied)
        _check_spec(spec, leaf.shape, mesh, shard_specs._path_str(path))

    jax.tree_util.tree_map_with_path(check, p_sds)


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_cache_and_batch_specs_divisible(mesh_name, arch):
    mesh = MESHES[mesh_name]
    cfg = ARCHS[arch]
    for shape in ALL_SHAPES:
        ok, _ = cell_is_runnable(cfg, shape)
        if not ok:
            continue
        bspec = shard_specs.batch_spec((shape.global_batch, shape.seq_len),
                                       mesh, seq_axis=1)
        _check_spec(bspec, (shape.global_batch, shape.seq_len), mesh,
                    f"{arch}/{shape.name}/batch")
        if shape.kind in ("decode", "long_decode"):
            caches = jax.eval_shape(
                lambda: transformer.stack_cache(
                    cfg, shape.global_batch, shape.seq_len,
                    jnp.dtype(cfg.dtype)))

            def check(path, leaf):
                spec = shard_specs.cache_spec(path, leaf.shape, mesh)
                _check_spec(spec, leaf.shape, mesh,
                            f"{arch}/{shape.name}/" +
                            shard_specs._path_str(path))

            jax.tree_util.tree_map_with_path(check, caches)


def test_big_params_are_actually_sharded():
    """Every >32 MB parameter must shard over at least one axis (ZeRO):
    otherwise grok cannot fit."""
    mesh = MESHES["pod"]
    for arch in ("grok-1-314b", "qwen2.5-14b", "llama4-scout-17b-a16e"):
        p_sds = params_abstract(ARCHS[arch])

        def check(path, leaf):
            nbytes = leaf.size * leaf.dtype.itemsize
            if nbytes < (32 << 20):
                return
            spec = shard_specs.param_spec(path, leaf.shape, mesh)
            assert any(a is not None for a in spec), \
                f"{arch}:{shard_specs._path_str(path)} {leaf.shape} unsharded"

        jax.tree_util.tree_map_with_path(check, p_sds)


def test_activation_policy_head_fallback():
    """Non-divisible head counts fall back to sequence-TP."""
    mesh = MESHES["pod"]
    pol = shard_specs.ActivationPolicy(mesh)
    # qwen2: 14 heads, S=4096 -> heads replicated, seq over model
    spec = pol.spec("heads", (256, 4096, 14, 64))
    assert spec[2] is None and spec[1] == "model"
    # qwen2.5: 40 heads? 40 % 16 != 0 -> fallback too
    spec = pol.spec("heads", (256, 4096, 40, 128))
    assert spec[1] == "model"
    # grok: 48 heads % 16 == 0 -> head TP
    spec = pol.spec("heads", (256, 4096, 48, 128))
    assert spec[2] == "model"
