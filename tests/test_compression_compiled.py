"""Compiled codec equivalence suite (DESIGN.md §7): the one-dispatch group
codecs match their eager references bit for bit (tie rule: largest
|x+residual|, exact ties to the LOWER index, indices ascending), the fused
decompress-into-fold equals densify-then-add, PowerSGD round-trip error
shrinks with rank, and codec dispatches stay O(groups) per partial."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (Op, merge_partials, scale_partial,
                                    wire_bytes)
from repro.core.compression import (CompressedTensor, Int8Compressor,
                                    PowerSGDCompressor, TopKCompressor,
                                    _wire_bytes, codec_dispatch_count,
                                    densify_buffer, fold_buffer_into,
                                    make_compressor,
                                    reset_codec_dispatch_count, scale_buffer)
from repro.core.flat import FlatLayout, flat_sums, is_compressed_buffer
from repro.kernels import ops as kops
from repro.kernels import topk_compress as tkc

RNG = np.random.default_rng(7)

# "skip" sits between the targeted "delta" and "aux" spans so every plan
# exercises comp -> raw -> comp segment interleaving; "cnt" lives in the
# unit group (SUM) to give compress a second group buffer
OPS = {"delta": Op.WEIGHTED_AVG, "skip": Op.WEIGHTED_AVG,
       "aux": Op.WEIGHTED_AVG, "cnt": Op.SUM}


def _payload(seed=0):
    r = np.random.default_rng(seed)
    return {"delta": {"w": jnp.asarray(r.normal(size=(40, 7)), jnp.float32),
                      "b": jnp.asarray(r.normal(size=(7,)), jnp.float32)},
            "skip": jnp.asarray(r.normal(size=(33,)), jnp.float32),
            "aux": jnp.asarray(r.normal(size=(55,)), jnp.float32),
            "cnt": jnp.asarray(r.normal(size=(5,)), jnp.float32)}


LAYOUT = FlatLayout.build(OPS, _payload())


def _partial(seed=0):
    bufs = LAYOUT.flatten(_payload(seed))
    return {"sums": flat_sums(dict(bufs)), "layout": LAYOUT,
            "weights": {k: 1.0 for k in OPS},
            "counts": {k: 1 for k in OPS},
            "collected": {}, "n_clients": 1}


def _np(x):
    return np.asarray(x)


# ---------------------------------------------------------------------------
# fused kernel
# ---------------------------------------------------------------------------

def test_pallas_kernel_matches_reference():
    r = np.random.default_rng(3)
    x = jnp.asarray(r.normal(size=(300,)), jnp.float32)
    res = jnp.asarray(r.normal(size=(300,)), jnp.float32)
    for k in (1, 7, 64, 300):
        i1, v1, n1 = tkc.topk_with_residual_reference(x, res, k)
        i2, v2, n2 = tkc.topk_with_residual_pallas(x, res, k,
                                                   interpret=True)
        assert np.array_equal(_np(i1), _np(i2))
        assert np.array_equal(_np(v1), _np(v2))
        assert np.array_equal(_np(n1), _np(n2))


def test_fused_topk_wrapper_single_dispatch_semantics():
    r = np.random.default_rng(4)
    x = jnp.asarray(r.normal(size=(128,)), jnp.float32)
    res = jnp.zeros((128,), jnp.float32)
    idx, vals, new_res = kops.fused_topk(x, res, k=16)
    # idx ascending, residual zeroed exactly at idx, untouched elsewhere
    assert np.all(np.diff(_np(idx)) > 0)
    assert np.array_equal(_np(vals), _np(x)[_np(idx)])
    assert np.all(_np(new_res)[_np(idx)] == 0.0)
    mask = np.ones(128, bool)
    mask[_np(idx)] = False
    assert np.array_equal(_np(new_res)[mask], _np(x)[mask])


def test_topk_tie_semantics_lower_index_wins():
    """Documented tie rule: equal |value| -> the LOWER index is selected
    (lax.top_k stability; the eager reference uses a stable argsort)."""
    x = jnp.asarray([2.0, -2.0, 2.0, 1.0], jnp.float32)
    idx, vals, _ = tkc.topk_with_residual_reference(x, jnp.zeros(4), 2)
    assert list(_np(idx)) == [0, 1]
    assert list(_np(vals)) == [2.0, -2.0]
    # eager compressor agrees
    c = TopKCompressor(fraction=0.5, compiled=False)
    ct = c._compress_array(np.asarray(x), "t")
    assert list(ct.data["idx"]) == [0, 1]
    assert list(ct.data["vals"]) == [2.0, -2.0]


# ---------------------------------------------------------------------------
# compiled vs eager group codecs
# ---------------------------------------------------------------------------

def test_compiled_topk_matches_eager_bit_for_bit():
    """Three rounds of residual accrual: the one-dispatch group codec and
    the per-span eager reference must emit identical wire bytes (indices,
    values, raw segments) AND identical decoded buffers every round."""
    eager = TopKCompressor(0.25, entries=("delta", "aux"), compiled=False)
    comp = make_compressor("topk", 0.25, entries=("delta", "aux"))
    assert comp.compiled
    for rnd in range(3):
        pe = eager.compress_partial(_partial(rnd), key="exec0")
        pc = comp.compress_partial(_partial(rnd), key="exec0")
        assert pe["_wire_bytes"] == pc["_wire_bytes"]
        for g, be in pe["sums"]["buffers"].items():
            bc = pc["sums"]["buffers"][g]
            if not is_compressed_buffer(be):
                assert np.array_equal(_np(be), _np(bc))
                continue
            for (ke, xe), (kc, xc) in zip(be["segments"], bc["segments"]):
                assert ke == kc
                if ke == "raw":
                    assert np.array_equal(_np(xe), _np(xc))
                else:
                    assert np.array_equal(_np(xe.data["idx"]),
                                          _np(xc.data["idx"]))
                    assert np.array_equal(_np(xe.data["vals"]),
                                          _np(xc.data["vals"]))
        de = eager.decompress_partial(pe)["sums"]["buffers"]
        dc = comp.decompress_partial(pc)["sums"]["buffers"]
        for g in de:
            dcb = densify_buffer(dc[g]) if is_compressed_buffer(dc[g]) \
                else dc[g]
            assert np.array_equal(_np(de[g]), _np(dcb))


def test_compiled_int8_matches_eager_bit_for_bit():
    eager = Int8Compressor(entries=("delta", "aux"), compiled=False)
    comp = make_compressor("int8", entries=("delta", "aux"))
    pe = eager.compress_partial(_partial(5))
    pc = comp.compress_partial(_partial(5))
    assert pe["_wire_bytes"] == pc["_wire_bytes"]
    de = eager.decompress_partial(pe)["sums"]["buffers"]["weighted"]
    dc = densify_buffer(
        comp.decompress_partial(pc)["sums"]["buffers"]["weighted"])
    assert np.array_equal(_np(de), _np(dc))


def test_compiled_decompress_is_lazy():
    comp = make_compressor("topk", 0.25)
    wire = comp.compress_partial(_partial(1), key="e")
    out = comp.decompress_partial(wire)
    assert is_compressed_buffer(out["sums"]["buffers"]["weighted"])


# ---------------------------------------------------------------------------
# fused decompress-into-fold / scale
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["topk", "int8", "powersgd"])
def test_fold_buffer_into_matches_densify_add(kind):
    comp = make_compressor(kind, 0.25, rank=3)
    buf = comp.compress_partial(_partial(2), key="e")["sums"]["buffers"][
        "weighted"]
    acc = jnp.asarray(RNG.normal(size=(int(buf["size"]),)), jnp.float32)
    got = _np(fold_buffer_into(acc, buf))
    want = _np(acc + densify_buffer(buf))
    if kind == "topk":
        # scatter-add has no multiply: bitwise equal to densify-then-add
        assert np.array_equal(got, want)
    else:
        # int8/powersgd decode multiplies inside the fold jit; XLA may
        # contract the mul+add into an FMA (single rounding), so agreement
        # is to the ulp, not the bit
        assert np.allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("kind", ["topk", "int8", "powersgd"])
def test_scale_buffer_matches_dense_scale(kind):
    comp = make_compressor(kind, 0.25, rank=3)
    buf = comp.compress_partial(_partial(3), key="e")["sums"]["buffers"][
        "weighted"]
    got = densify_buffer(scale_buffer(buf, 0.25))
    assert np.allclose(_np(got), 0.25 * _np(densify_buffer(buf)),
                       rtol=1e-6, atol=1e-7)


def test_merge_and_scale_partial_consume_compressed_wire():
    """The async fold path end-to-end on compressed partials: gamma-scale,
    merge-into-None (densify) and merge-into-acc (fused fold) agree with
    the dense reference."""
    comp = make_compressor("topk", 0.25)
    w1 = comp.compress_partial(_partial(10), key="e0")
    w2 = comp.compress_partial(_partial(11), key="e1")
    dense1 = {g: (densify_buffer(b) if is_compressed_buffer(b) else b)
              for g, b in w1["sums"]["buffers"].items()}
    dense2 = {g: (densify_buffer(b) if is_compressed_buffer(b) else b)
              for g, b in w2["sums"]["buffers"].items()}
    acc = merge_partials(None, scale_partial(w1, 0.5))
    acc = merge_partials(acc, w2)
    for g in dense1:
        want = 0.5 * _np(dense1[g]) + _np(dense2[g])
        assert np.allclose(_np(acc["sums"]["buffers"][g]), want,
                           rtol=1e-6, atol=1e-6)
    assert acc["n_clients"] == 2


# ---------------------------------------------------------------------------
# dispatch accounting: O(groups), not O(segments)
# ---------------------------------------------------------------------------

def test_codec_dispatches_are_per_group_not_per_segment():
    comp = make_compressor("topk", 0.25, entries=("delta", "aux", "cnt"))
    comp.compress_partial(_partial(0), key="warm")   # compile outside count
    reset_codec_dispatch_count()
    wire = comp.compress_partial(_partial(1), key="warm")
    # 3 targeted entries across 2 groups (weighted: delta+aux; unit: cnt)
    # -> exactly 2 compress dispatches
    assert codec_dispatch_count() == 2
    reset_codec_dispatch_count()
    for b in wire["sums"]["buffers"].values():
        if is_compressed_buffer(b):
            densify_buffer(b)
    assert codec_dispatch_count() == 2               # one decode per group
    reset_codec_dispatch_count()
    acc = merge_partials(None, wire)                 # densify per group
    merge_partials(acc, comp.compress_partial(_partial(2), key="warm2"))
    # 2 densify + 2 compress + 2 fused folds
    assert codec_dispatch_count() == 6


# ---------------------------------------------------------------------------
# PowerSGD
# ---------------------------------------------------------------------------

def test_powersgd_roundtrip_error_decreases_with_rank():
    bufs = LAYOUT.flatten(_payload(42))
    target = _np(bufs["weighted"])
    errs = []
    for r in (1, 4, 16):
        comp = make_compressor("powersgd", rank=r)
        wire = comp.compress_partial(_partial(42), key="e")
        dense = _np(densify_buffer(wire["sums"]["buffers"]["weighted"]))
        errs.append(float(np.linalg.norm(dense - target)))
    assert errs[1] < errs[0]
    assert errs[2] < errs[1]


def test_powersgd_warm_start_converges_on_fixed_matrix():
    """Warm-start semantics: with the residual zeroed each round (isolating
    the subspace iteration), re-compressing the SAME matrix must shrink the
    approximation error monotonically — Q tracks the top singular
    subspace."""
    comp = make_compressor("powersgd", rank=2)
    target = _np(LAYOUT.flatten(_payload(9))["weighted"])
    errs = []
    for _ in range(5):
        for k in comp._state:
            comp._state[k]["res"] = np.zeros_like(
                np.asarray(comp._state[k]["res"]))
        wire = comp.compress_partial(_partial(9), key="e")
        dense = _np(densify_buffer(wire["sums"]["buffers"]["weighted"]))
        errs.append(float(np.linalg.norm(dense - target)))
    assert all(b <= a for a, b in zip(errs, errs[1:]))
    assert errs[-1] < errs[0]
    # state is per (sender, group, entry)
    assert any(k.startswith("e/") for k in comp._state)


def test_powersgd_error_feedback_is_unbiased_in_the_long_run():
    """Error feedback: each round's decode approximates x + residual, so the
    RUNNING AVERAGE of decodes telescopes to x - res_T/T — the averaged
    error must fall well below the single-shot error."""
    comp = make_compressor("powersgd", rank=2)
    target = _np(LAYOUT.flatten(_payload(9))["weighted"])
    acc, errs = None, []
    for t in range(1, 13):
        wire = comp.compress_partial(_partial(9), key="e")
        dense = _np(densify_buffer(wire["sums"]["buffers"]["weighted"]))
        acc = dense if acc is None else acc + dense
        errs.append(float(np.linalg.norm(acc / t - target)))
    assert errs[-1] < 0.5 * errs[0]


def test_powersgd_wire_is_p_plus_q_bytes():
    comp = make_compressor("powersgd", rank=4)
    wire = comp.compress_partial(_partial(6), key="e")
    buf = wire["sums"]["buffers"]["weighted"]
    seg = [x for k, x in buf["segments"] if k == "comp"]
    assert len(seg) == 1 and seg[0].kind == "powersgd"
    p, q = seg[0].data["p"], seg[0].data["q"]
    raw = sum(int(np.prod(np.shape(x))) * 4
              for k, x in buf["segments"] if k == "raw")
    n_unit = int(LAYOUT.group_sizes["unit"]) * 4
    assert wire["_wire_bytes"] == int(p.nbytes) + int(q.nbytes) + raw + n_unit
    # low-rank actually compresses the targeted span
    span = LAYOUT.spans["delta"]
    assert int(p.nbytes) + int(q.nbytes) < span.size * 4


# ---------------------------------------------------------------------------
# make_compressor signature (satellite regression)
# ---------------------------------------------------------------------------

def test_make_compressor_passes_entries_and_rank_through():
    c = make_compressor("topk", 0.1, entries=("delta", "delta_c"))
    assert c.fraction == 0.1 and c.entries == ("delta", "delta_c")
    c = make_compressor("int8", entries=("delta", "delta_c"))
    assert c.entries == ("delta", "delta_c")
    c = make_compressor("powersgd", rank=7, entries=("delta", "delta_c"))
    assert c.rank == 7 and c.entries == ("delta", "delta_c")
    assert make_compressor("powersgd", 8).rank == 8    # arg doubles as rank
    assert make_compressor("none") is None
    legacy = make_compressor("topk", 0.1, compiled=False)
    assert not legacy.compiled


def test_extra_entries_compress_scaffold_style_payload():
    """SCAFFOLD-style payloads carry a second reducible entry (the control
    variate delta); entries= must compress BOTH spans."""
    ops = {"delta": Op.WEIGHTED_AVG, "delta_c": Op.AVG}
    payload = {"delta": jnp.asarray(RNG.normal(size=(64,)), jnp.float32),
               "delta_c": jnp.asarray(RNG.normal(size=(64,)), jnp.float32)}
    layout = FlatLayout.build(ops, payload)
    partial = {"sums": flat_sums(dict(layout.flatten(payload))),
               "layout": layout, "weights": {"delta": 1.0, "delta_c": 1.0},
               "counts": {k: 1 for k in ops}, "collected": {},
               "n_clients": 1}
    both = make_compressor("topk", 0.1, entries=("delta", "delta_c"))
    only = make_compressor("topk", 0.1)
    wb = both.compress_partial(partial, key="e")["_wire_bytes"]
    wo = only.compress_partial(partial, key="e")["_wire_bytes"]
    assert wb < wo                      # the second span got compressed too


# ---------------------------------------------------------------------------
# wire accounting hardening (satellite)
# ---------------------------------------------------------------------------

def test_wire_bytes_flat_tolerates_plain_buffers():
    sums = flat_sums({"weighted": [1.0, 2.0, 3.0], "unit": 2.5})
    # python list/scalar buffers bill at the fp32 default, like nested
    assert _wire_bytes(sums) == 3 * 4 + 4


def test_wire_bytes_of_compressed_partial_counts_compressed_sums():
    comp = make_compressor("topk", 0.1)
    wire = comp.compress_partial(_partial(8), key="e")
    dense = wire_bytes(_partial(8))
    assert 0 < wire_bytes(wire) < dense


# ---------------------------------------------------------------------------
# end-to-end through the engines
# ---------------------------------------------------------------------------

def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))


def _server(compressor, engine="bsp", seed=7):
    import tempfile
    from repro.core import (ClientStateManager, LinkProfile, NetworkModel,
                            ParrotServer, SequentialExecutor, TickTimer,
                            make_algorithm)
    from repro.data import make_classification_clients
    data = make_classification_clients(16, dim=8, n_classes=4,
                                       mean_samples=20, batch_size=10,
                                       seed=1)
    algo = make_algorithm("fedavg", GRAD_FN, 0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    # deterministic virtual timing: schedules must match across the eager
    # and compiled runs for the bit-exactness comparison to be meaningful
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0)) for k in range(3)]
    opts = {"chunk_size": 2} if engine != "bsp" else None
    # a uniform (deterministic) network so the achieved wire ratio is
    # actually measured at the ship sites (comm-free runs never price it)
    net = NetworkModel({c: LinkProfile(4e4, 8e4, 0.05) for c in range(16)})
    return ParrotServer(params={"w": jnp.zeros((8, 4)),
                                "b": jnp.zeros((4,))},
                        algorithm=algo, executors=execs, data_by_client=data,
                        clients_per_round=6, seed=seed, round_engine=engine,
                        engine_opts=opts, compressor=compressor, network=net)


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_engines_eager_vs_compiled_topk_bit_exact(engine):
    """Full server runs under eager vs compiled top-k land on identical
    params: same wire bytes per round (bit-equal segments) and a fold path
    whose arithmetic matches the eager decompress-then-add exactly (the
    top-k fold is a scatter-add of the same values in the same order)."""
    a = _server(TopKCompressor(0.25, compiled=False), engine)
    b = _server(make_compressor("topk", 0.25), engine)
    for _ in range(3):
        a.run_round()
        b.run_round()
    for x, y in zip(jax.tree.leaves(a.params), jax.tree.leaves(b.params)):
        assert np.array_equal(_np(x), _np(y))
    assert a._wire_ratio == b._wire_ratio


def test_engine_runs_under_powersgd():
    # rank 2: P+Q = 2*(cols*r) = 24 floats < the 36-float weighted group —
    # at rank 4 the low-rank factors would EXPAND this tiny model's wire
    srv = _server(make_compressor("powersgd", rank=2), "async")
    for _ in range(3):
        srv.run_round()
    assert all(np.isfinite(_np(l)).all()
               for l in jax.tree.leaves(srv.params))
    assert 0.0 < srv._wire_ratio < 1.0


def test_server_accepts_compressor_string():
    srv = _server("topk", "bsp")
    assert isinstance(srv.compressor, TopKCompressor)
    assert srv.compressor.compiled
    srv.run_round()


# ---------------------------------------------------------------------------
# pytree plumbing
# ---------------------------------------------------------------------------

def test_compressed_tensor_is_a_pytree_node():
    ct = CompressedTensor("topk", (4,), "float32",
                          {"idx": np.asarray([0, 2], np.int32),
                           "vals": np.asarray([1.0, -1.0], np.float32)})
    leaves = jax.tree.leaves(ct)
    assert len(leaves) == 2
    back = jax.tree.map(lambda x: x, ct)
    assert isinstance(back, CompressedTensor) and back.kind == "topk"
    assert sum(x.nbytes for x in leaves) == ct.nbytes
