"""Virtual-time telemetry (DESIGN.md §13).

Anchors:
  * ``telemetry=None`` (the default) and ``telemetry=True`` are bit-exact
    (params AND makespan history) on all three engines — emission only
    reads values the engines already computed;
  * exported traces validate: finite monotone times, per-lane nesting of
    busy/server spans, non-negative wire bytes; upload-span wire bytes
    reconcile with the round accounting's ``comm_wire_bytes``;
  * per-executor busy/comm/idle fractions sum to 1 and land in
    ``metrics.extra["utilization"]``;
  * traces are deterministic across two identical seeded-chaos runs, and a
    mid-run kill + ``auto_resume=True`` reproduces the uninterrupted
    run's trace (tracer + registry ride the checkpoint blob) — the
    process-local ``host/`` namespace is excluded from both equalities.

Plus unit coverage of the registry (counters/gauges/histograms,
``ingest_extra`` schema routing), the tracer's Chrome-trace export, and
``validate_trace``'s violation detection.
"""
import json
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, params_digest
from repro.core import (ClientStateManager, ControlPlane, DeadlineController,
                        FaultPlan, LinkProfile, MetricsRegistry, NetworkModel,
                        ParrotServer, RetryPolicy, SequentialExecutor,
                        Telemetry, TickTimer, Tracer, make_algorithm,
                        validate_trace)
from repro.core.telemetry import Histogram
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}

ENGINES = [("bsp", None),
           ("semi-sync", {"chunk_size": 2, "deadline_frac": 0.7}),
           ("async", {"chunk_size": 2})]

#: heterogeneous links so comm fractions are non-trivial
_NET = NetworkModel({c: LinkProfile(100.0 + 10.0 * c, 50.0, 0.2)
                     for c in range(40)})


def _data(n=40, seed=1):
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _make_server(data, K=4, clients_per_round=10, **kw):
    algo = make_algorithm("fedavg", GRAD_FN, lr=0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0))
             for k in range(K)]
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data,
                        clients_per_round=clients_per_round, seed=7, **kw)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _no_host(snap):
    """Registry snapshot minus the process-local ``host/`` namespace."""
    return {sec: {k: v for k, v in d.items() if not k.startswith("host/")}
            for sec, d in snap.items() if sec != "last_extra"}


# ---------------------------------------------------------------------------
# registry units
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    reg.counter("total/x").inc()
    reg.counter("total/x").inc(2.5)
    reg.gauge("round/y").set(7.0)
    assert reg.value("total/x") == pytest.approx(3.5)
    assert reg.value("round/y") == 7.0
    assert reg.value("missing") is None


def test_histogram_buckets_and_stats():
    h = Histogram(bounds=(1.0, 5.0))
    for v in (0.5, 0.5, 3.0, 10.0):
        h.observe(v)
    assert h.count == 4
    assert h.counts == [2, 1, 1]    # <= 1.0, (1, 5], > 5
    assert h.mean == pytest.approx(3.5)
    assert h.vmin == 0.5 and h.vmax == 10.0
    rt = Histogram.from_state_dict(h.state_dict())
    assert rt.state_dict() == h.state_dict()


def test_histogram_empty_mean():
    assert Histogram().mean == 0.0


def test_ingest_extra_routes_by_schema():
    reg = MetricsRegistry()
    reg.ingest_extra({"retries": 2, "deadline_frac": 0.7,
                      "carried_tasks": 3, "comm_wire_bytes": 100,
                      "unknown_key": 5, "flag": True,
                      "nested": {"a": 1.0}})
    # schema counters accumulate under total/, gauges overwrite round/
    assert reg.value("total/retries") == 2
    assert reg.value("round/deadline_frac") == pytest.approx(0.7)
    assert reg.value("round/carried_tasks") == 3
    assert reg.value("total/comm_wire_bytes") == 100
    assert reg.value("total/unknown_key") == 5      # unknown -> counter
    assert reg.value("total/flag") is None          # bools skipped
    assert reg.value("total/nested/a") == 1.0       # flattened
    reg.ingest_extra({"retries": 3, "deadline_frac": 0.8})
    assert reg.value("total/retries") == 5
    assert reg.value("round/deadline_frac") == pytest.approx(0.8)
    assert reg.extra_last("deadline_frac") == pytest.approx(0.8)
    assert reg.extra_total("retries") == 5
    assert reg.extra_last("absent", -1.0) == -1.0


def test_registry_snapshot_and_state_roundtrip():
    reg = MetricsRegistry()
    reg.counter("total/a").inc(2)
    reg.counter("host/wall").inc(9)
    reg.gauge("round/b").set(1.5)
    reg.histogram("hist/h").observe(3.0)
    snap = reg.snapshot(exclude=("host/",))
    assert "total/a" in snap["counters"]
    assert "host/wall" not in snap["counters"]
    fresh = MetricsRegistry()
    fresh.load_state_dict(reg.state_dict())
    assert fresh.value("total/a") == 2
    assert fresh.value("host/wall") == 9
    assert fresh.value("round/b") == 1.5
    assert fresh.histogram("hist/h").count == 1


# ---------------------------------------------------------------------------
# tracer + export + validation units
# ---------------------------------------------------------------------------

def test_tracer_export_chrome_schema(tmp_path):
    tr = Tracer()
    tr.span("exec:0", "chunk", 0.0, 2.0, cat="busy", args={"round": 1})
    tr.span("exec:0:up", "upload", 2.0, 3.0, cat="comm",
            args={"wire_bytes": 10})
    tr.instant("server", "fold", 3.0, cat="server")
    path = str(tmp_path / "trace.json")
    tr.export(path)
    with open(path) as f:
        doc = json.load(f)
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # one thread_name metadata record per lane
    assert {m["args"]["name"] for m in by_ph["M"]} == \
        {"exec:0", "exec:0:up", "server"}
    x, = by_ph["X"]
    assert x["ts"] == 0.0 and x["dur"] == pytest.approx(2e6)   # µs
    b, = by_ph["b"]
    e, = by_ph["e"]
    assert b["id"] == e["id"] and b["ts"] < e["ts"]            # async pair
    i, = by_ph["i"]
    assert i["ts"] == pytest.approx(3e6) and i["s"] == "t"


def test_validate_trace_accepts_all_sources(tmp_path):
    tr = Tracer()
    tr.span("exec:0", "chunk", 0.0, 1.0)
    tr.span("exec:0", "chunk", 2.0, 3.0)          # disjoint: fine
    tr.span("server", "round", 0.0, 3.0, cat="server")
    tr.span("server", "fold", 1.0, 2.0, cat="server")   # nested: fine
    path = str(tmp_path / "t.json")
    tr.export(path)
    for src in (tr, tr.state_dict(), tr.to_chrome(), path):
        assert validate_trace(src) == []


def test_validate_trace_flags_violations():
    bad_t = Tracer()
    bad_t.span("exec:0", "chunk", 2.0, 1.0)            # t1 < t0
    assert any("t1" in e or "end" in e for e in validate_trace(bad_t))

    neg = Tracer()
    neg.span("exec:0", "chunk", -1.0, 1.0)             # negative time
    assert validate_trace(neg)

    overlap = Tracer()
    overlap.span("exec:0", "chunk", 0.0, 2.0, cat="busy")
    overlap.span("exec:0", "chunk", 1.0, 3.0, cat="busy")  # partial overlap
    assert any("nest" in e or "overlap" in e for e in validate_trace(overlap))

    wire = Tracer()
    wire.span("exec:0:up", "upload", 0.0, 1.0, cat="comm",
              args={"wire_bytes": -5})
    assert any("wire_bytes" in e for e in validate_trace(wire))


def test_tracer_state_roundtrip():
    tr = Tracer()
    tr.span("exec:0", "chunk", 0.0, 1.0, args={"round": 0})
    tr.instant("server", "fold", 1.0, cat="server")
    fresh = Tracer()
    fresh.load_state_dict(tr.state_dict())
    assert fresh.spans == tr.spans
    assert fresh.instants == tr.instants
    assert fresh.lanes() == tr.lanes()


def test_utilization_empty_window():
    tele = Telemetry()
    u = tele.utilization(5.0, 5.0, executors=(0,))
    assert u[0] == {"busy_frac": 0.0, "comm_frac": 0.0, "idle_frac": 1.0}


# ---------------------------------------------------------------------------
# telemetry=None ≡ telemetry=True (bit-exact), all three engines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,opts", ENGINES)
def test_enabled_is_bit_identical_to_none(engine, opts):
    a = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     telemetry=None)
    b = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     telemetry=True)
    ha = [a.run_round() for _ in range(4)]
    hb = [b.run_round() for _ in range(4)]
    _params_equal(a.params, b.params)
    assert [m.makespan for m in ha] == [m.makespan for m in hb]
    assert b.telemetry.tracer.spans          # it actually recorded
    assert all("utilization" not in m.extra for m in ha)


@pytest.mark.parametrize("engine,opts", ENGINES)
def test_enabled_is_bit_identical_under_network(engine, opts):
    a = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     network=_NET, telemetry=None)
    b = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     network=_NET, telemetry=True)
    ha = [a.run_round() for _ in range(4)]
    hb = [b.run_round() for _ in range(4)]
    _params_equal(a.params, b.params)
    assert [m.makespan for m in ha] == [m.makespan for m in hb]


# ---------------------------------------------------------------------------
# trace schema + accounting reconciliation on real heterogeneous runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,opts", ENGINES)
def test_trace_validates_on_heterogeneous_run(engine, opts, tmp_path):
    srv = _make_server(_data(), round_engine=engine, engine_opts=opts,
                       network=_NET, telemetry=True)
    for _ in range(3):
        srv.run_round()
    assert validate_trace(srv.telemetry.tracer) == []
    path = str(tmp_path / f"{engine}.json")
    srv.telemetry.tracer.export(path)
    assert validate_trace(path) == []


@pytest.mark.parametrize("engine,opts", ENGINES)
def test_wire_bytes_reconcile_with_comm_accounting(engine, opts):
    srv = _make_server(_data(), round_engine=engine, engine_opts=opts,
                       network=_NET, telemetry=True)
    hist = [srv.run_round() for _ in range(4)]
    span_bytes = sum(s[5].get("wire_bytes", 0)
                     for s in srv.telemetry.tracer.spans
                     if s[0].endswith(":up"))
    extra_bytes = sum(m.extra.get("comm_wire_bytes", 0) for m in hist)
    if engine == "async":
        # tail dispatches bill their upload into the NEXT window's extra;
        # the spans see every upload as it happens
        assert extra_bytes <= span_bytes
        assert span_bytes > 0
    else:
        assert span_bytes == extra_bytes > 0


@pytest.mark.parametrize("engine,opts", ENGINES)
def test_utilization_sums_to_one(engine, opts):
    srv = _make_server(_data(), round_engine=engine, engine_opts=opts,
                       network=_NET, telemetry=True)
    m = srv.run_round()
    util = m.extra["utilization"]
    assert set(util) == set(srv.executors)
    for k, u in util.items():
        assert 0.0 <= u["busy_frac"] <= 1.0
        assert 0.0 <= u["comm_frac"] <= 1.0
        assert 0.0 <= u["idle_frac"] <= 1.0
        total = u["busy_frac"] + u["comm_frac"] + u["idle_frac"]
        assert total == pytest.approx(1.0, abs=1e-9)
        assert srv.telemetry.registry.value(
            f"util/exec{k}/busy_frac") == pytest.approx(u["busy_frac"])


def test_round_gauges_and_counters_populate():
    srv = _make_server(_data(), telemetry=True)
    srv.run_round()
    srv.run_round()
    reg = srv.telemetry.registry
    assert reg.value("total/rounds") == 2
    assert reg.value("round/makespan") == srv.history[-1].makespan
    assert reg.value("round/n_clients") == 10
    assert reg.value("total/virtual_time") == pytest.approx(
        sum(m.makespan for m in srv.history))
    assert reg.value("host/wall_s") > 0
    assert reg.value("host/round_wall_s") > 0
    assert reg.value("host/compiles") is not None


def test_async_histograms_populate():
    srv = _make_server(_data(), round_engine="async",
                       engine_opts={"chunk_size": 2}, network=_NET,
                       telemetry=True)
    for _ in range(4):
        srv.run_round()
    reg = srv.telemetry.registry
    assert reg.histogram("hist/staleness").count > 0
    assert reg.histogram("hist/queue_depth").count > 0
    assert reg.histogram("hist/upload_delay").count > 0


def test_control_notes_land_on_control_lane():
    ctrl = ControlPlane(deadline=DeadlineController(target_ratio=0.5,
                                                    alpha=1.0))
    srv = _make_server(_data(), round_engine="semi-sync",
                       engine_opts={"chunk_size": 2, "deadline_frac": 0.9},
                       control=ctrl, telemetry=True)
    for _ in range(4):
        srv.run_round()
    tr = srv.telemetry.tracer
    notes = [i for i in tr.instants if i[0] == "control"]
    assert notes and all(i[3] == "control" for i in notes)
    assert srv.telemetry.registry.value("control/deadline_frac") is not None


def test_compiles_reported_per_executor():
    srv = _make_server(_data(), telemetry=True)
    srv.run_round()
    reg = srv.telemetry.registry
    vals = [reg.value(f"host/exec{k}/compiles") for k in srv.executors]
    assert all(v is None or v >= 0 for v in vals)


# ---------------------------------------------------------------------------
# determinism under chaos + kill/resume trace equality (§10 × §13)
# ---------------------------------------------------------------------------

_KILL_AFTER = {"bsp": 4, "semi-sync": 10, "async": 9}


def _fault_build(engine, ckpt_dir):
    data = _data(n=30)
    algo = make_algorithm("fedavg", grad_fn=GRAD_FN, lr=0.1, local_steps=2)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="teleckpt_"))
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0)) for k in range(3)]
    plan = FaultPlan.random(seed=3, horizon=80.0, executors=[0, 1, 2],
                            clients=list(range(30)),
                            crash_rate=0.05, restart_delay=5.0,
                            dropout_rate=0.1, dropout_duration=4.0,
                            corrupt_rate=0.05,
                            slowdown_rate=0.03, slowdown_duration=6.0)
    opts = {"chunk_size": 2} if engine != "bsp" else None
    return ParrotServer(params=PARAMS0, algorithm=algo,
                        executors=execs, data_by_client=data,
                        clients_per_round=8, seed=7, round_engine=engine,
                        engine_opts=opts, faults=plan,
                        retry=RetryPolicy(max_retries=2), telemetry=True,
                        checkpoint_manager=CheckpointManager(
                            ckpt_dir, every_rounds=1, keep=10))


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_trace_deterministic_under_chaos(engine, tmp_path):
    a = _fault_build(engine, str(tmp_path / "a"))
    b = _fault_build(engine, str(tmp_path / "b"))
    a.run(6)
    b.run(6)
    assert params_digest(a.params) == params_digest(b.params)
    assert a.telemetry.tracer.state_dict() == b.telemetry.tracer.state_dict()
    assert _no_host(a.telemetry.registry.snapshot()) == \
        _no_host(b.telemetry.registry.snapshot())
    assert validate_trace(a.telemetry.tracer) == []


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_kill_then_auto_resume_reproduces_trace(engine, tmp_path):
    N = 8
    ref = _fault_build(engine, str(tmp_path / "ref"))
    ref.run(N)
    want_params = params_digest(ref.params)
    want_trace = ref.telemetry.tracer.state_dict()
    want_reg = _no_host(ref.telemetry.registry.snapshot())

    d = str(tmp_path / "ck")
    victim = _fault_build(engine, d)
    ex0 = victim.executors[0]
    real, calls = ex0.run_queue, [0]

    def dying(*a, **kw):
        calls[0] += 1
        if calls[0] >= _KILL_AFTER[engine]:
            raise KeyboardInterrupt
        return real(*a, **kw)

    ex0.run_queue = dying
    with pytest.raises(KeyboardInterrupt):
        victim.run(N)
    assert 1 <= victim.round < N

    # fresh server, fresh tracer: the blob's telemetry state must replace
    # everything (including construction-time fault-plan spans) so the
    # resumed trace equals the uninterrupted run's
    resumed = _fault_build(engine, d)
    resumed.run(N, auto_resume=True)
    assert resumed.round == N
    assert params_digest(resumed.params) == want_params
    assert resumed.telemetry.tracer.state_dict() == want_trace
    got_reg = _no_host(resumed.telemetry.registry.snapshot())
    if engine == "async":
        # pre-existing documented gap (engine.py AsyncEngine.state_dict):
        # the first resumed round's comm_bytes metric omits comm stats not
        # carried in the blob — accounting only, params/trace unaffected —
        # and the registry faithfully accumulates that per-round metric
        for d_ in (got_reg["counters"], want_reg["counters"]):
            d_.pop("total/comm_bytes", None)
    assert got_reg == want_reg


def test_fault_plan_spans_on_faults_lane():
    srv = _fault_build("bsp", tempfile.mkdtemp(prefix="teleplan_"))
    lanes = {s[0] for s in srv.telemetry.tracer.spans}
    assert "faults" in lanes                 # plan windows traced at build
    cats = {s[4] for s in srv.telemetry.tracer.spans if s[0] == "faults"}
    assert cats == {"fault"}
