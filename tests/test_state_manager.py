"""Client state manager (§3.4): tiering, spill, restore, rebalance."""
import os
import tempfile

import numpy as np
import pytest

from repro.core.state_manager import ClientStateManager, owner_host


def _state(i, size=100):
    rng = np.random.default_rng(i)
    return {"c": rng.normal(size=(size,)).astype(np.float32),
            "step": np.int32(i)}


def test_save_load_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d)
        for i in range(10):
            sm.save(i, _state(i))
        for i in range(10):
            st = sm.load(i)
            np.testing.assert_array_equal(st["c"], _state(i)["c"])


def test_batched_save_load_many_roundtrip():
    """save_many/load_many (the compiled-engine block path) must behave
    exactly like per-client save/load, order included, with spill."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=1024)  # forces spill
        sm.save_many({i: _state(i) for i in range(12)})
        out = sm.load_many([7, 3, 11, 0])
        for client, st in zip([7, 3, 11, 0], out):
            np.testing.assert_array_equal(st["c"], _state(client)["c"])
        assert sm.load_many([99], default="missing") == ["missing"]
        assert sm.stats["spills"] > 0


def test_memory_budget_enforced_with_spill():
    with tempfile.TemporaryDirectory() as d:
        budget = 5 * 420  # ~5 states
        sm = ClientStateManager(d, memory_budget_bytes=budget)
        for i in range(50):
            sm.save(i, _state(i))
        assert sm.memory_bytes <= budget
        assert sm.stats["spills"] >= 40
        assert sm.disk_bytes() > 0
        # spilled states still load correctly (from disk)
        st = sm.load(0)
        np.testing.assert_array_equal(st["c"], _state(0)["c"])
        assert sm.stats["loads"] >= 1


def test_lru_keeps_hot_clients_in_memory():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=3 * 420)
        for i in range(3):
            sm.save(i, _state(i))
        sm.load(0)                  # touch 0 -> most recent
        sm.save(3, _state(3))       # evicts LRU (1)
        sm.load(0)
        assert sm.stats["hits"] >= 2


def test_missing_client_returns_default():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d)
        assert sm.load(999) is None
        assert sm.load(999, default={"x": 1}) == {"x": 1}


def test_checkpoint_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        sm = ClientStateManager(d + "/a", memory_budget_bytes=2 * 420)
        for i in range(8):
            sm.save(i, _state(i))
        sm.checkpoint(ck)
        sm2 = ClientStateManager(d + "/b")
        n = sm2.restore(ck)
        assert n == 8
        for i in range(8):
            np.testing.assert_array_equal(sm2.load(i)["c"], _state(i)["c"])


def test_owner_host_is_deterministic_partition():
    owners = [owner_host(c, 4) for c in range(1000)]
    assert set(owners) <= set(range(4))
    counts = np.bincount(owners, minlength=4)
    assert counts.min() > 150        # roughly balanced
    assert owners == [owner_host(c, 4) for c in range(1000)]


def test_rebalance_moves_states_to_new_owners():
    with tempfile.TemporaryDirectory() as d:
        mgrs = {h: ClientStateManager(f"{d}/h{h}", host=h, n_hosts=2)
                for h in range(2)}
        # write each state to its 2-host owner
        for c in range(40):
            mgrs[owner_host(c, 2)].save(c, _state(c))
        # grow to 4 hosts
        for h in (2, 3):
            mgrs[h] = ClientStateManager(f"{d}/h{h}", host=h, n_hosts=4)
        moved = 0
        for h in (0, 1):
            moved += mgrs[h].rebalance(4, mgrs)
        assert moved > 0
        for c in range(40):
            st = mgrs[owner_host(c, 4)].load(c)
            assert st is not None
            np.testing.assert_array_equal(st["c"], _state(c)["c"])


# ---------------------------------------------------------------------------
# sharded tiers (DESIGN.md §11): clean evictions, digest skip, prefetch,
# budget-independent reads
# ---------------------------------------------------------------------------

def test_clean_evictions_never_touch_disk():
    """A tier-0 entry whose value already lives in a lower tier is dropped
    on eviction without any disk write or re-serialisation."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=3 * 420)
        for i in range(12):
            sm.save(i, _state(i))           # dirty spills -> staged/flushed
        for i in range(12):                 # promote all through tier 0
            sm.load(i)
        writes_before = sm.stats["disk_writes"]
        spills_before = sm.stats["spills"]
        for i in range(12):                 # re-walk: clean evictions only
            sm.load(i)
        assert sm.stats["spills"] > spills_before
        assert sm.stats["disk_writes"] == writes_before


def test_digest_skip_on_identical_resave():
    """Re-saving byte-identical state then evicting must not rewrite the
    shard (skipped_rewrites counts it; disk_writes stays flat)."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=2 * 420,
                                shard_clients=4, shard_cache_bytes=1)
        for i in range(8):
            sm.save(i, _state(i))
        sm.checkpoint(os.path.join(d, "ck"))  # flush: all 8 now on disk
        writes_before = sm.stats["disk_writes"]
        skips_before = sm.stats["skipped_rewrites"]
        for i in range(8):
            sm.save(i, _state(i))           # same value, marked dirty again
        for i in range(100, 104):
            sm.save(i, _state(i))           # push the identical ones out
        assert sm.stats["skipped_rewrites"] >= skips_before + 8
        # the only new writes may come from the genuinely-new clients
        assert sm.stats["disk_writes"] <= writes_before + 2
        # and a changed value is still persisted
        sm.save(0, _state(999))
        np.testing.assert_array_equal(sm.load(0)["c"], _state(999)["c"])


def test_prefetch_avoids_disk_loads():
    """prefetch() stages whole shards into host RAM; the following
    load_many serves from the shard tier with zero new disk reads."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=2 * 420,
                                shard_clients=4)
        sm.save_many({i: _state(i) for i in range(16)})
        sm.checkpoint(os.path.join(d, "ck"))   # flush -> everything on disk
        # fresh manager over the same spill dir: cold tiers
        sm2 = ClientStateManager(d, memory_budget_bytes=2 * 420,
                                 shard_clients=4, shard_cache_bytes=1 << 20)
        sm2.restore(os.path.join(d, "ck"))
        cohort = [2, 5, 9, 14]
        staged = sm2.prefetch(cohort)
        assert staged > 0
        disk_loads = sm2.stats["disk_loads"]
        out = sm2.load_many(cohort)
        for c, st in zip(cohort, out):
            np.testing.assert_array_equal(st["c"], _state(c)["c"])
        assert sm2.stats["disk_loads"] == disk_loads  # no double-loads
        assert sm2.stats["prefetched"] == staged


def test_reads_identical_across_memory_budgets():
    """The same save/load_many sequence must return bit-identical states
    whether the budget forces heavy spilling or none at all — with and
    without prefetch in the loop."""
    def run(budget, use_prefetch):
        d = tempfile.mkdtemp(prefix="smb_")
        sm = ClientStateManager(d, memory_budget_bytes=budget,
                                shard_clients=4)
        rng = np.random.default_rng(0)
        out = []
        for step in range(6):
            cohort = sorted(int(c) for c in
                            rng.choice(24, size=8, replace=False))
            if use_prefetch:
                sm.prefetch(cohort)
            loaded = sm.load_many(cohort, default=None)
            sm.save_many({c: _state(c * 31 + step) for c in cohort})
            out.append([(st["c"].tobytes(), int(st["step"]))
                        for st in loaded if st is not None])
        return out

    baseline = run(1 << 30, use_prefetch=False)
    for budget in (420, 3 * 420, 10 * 420):
        for pf in (False, True):
            assert run(budget, pf) == baseline


def test_shard_files_bounded_by_shard_count():
    """No per-client inodes: M clients across shards of S produce at most
    ceil(M/S) shard files."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=420,
                                shard_clients=16)
        sm.save_many({i: _state(i) for i in range(100)})
        sm.checkpoint(os.path.join(d, "ck"))
        files = [f for f in os.listdir(d) if f.endswith(".pkl")]
        assert 0 < len(files) <= -(-100 // 16)
