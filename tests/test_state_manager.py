"""Client state manager (§3.4): tiering, spill, restore, rebalance."""
import os
import tempfile

import numpy as np
import pytest

from repro.core.state_manager import ClientStateManager, owner_host


def _state(i, size=100):
    rng = np.random.default_rng(i)
    return {"c": rng.normal(size=(size,)).astype(np.float32),
            "step": np.int32(i)}


def test_save_load_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d)
        for i in range(10):
            sm.save(i, _state(i))
        for i in range(10):
            st = sm.load(i)
            np.testing.assert_array_equal(st["c"], _state(i)["c"])


def test_batched_save_load_many_roundtrip():
    """save_many/load_many (the compiled-engine block path) must behave
    exactly like per-client save/load, order included, with spill."""
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=1024)  # forces spill
        sm.save_many({i: _state(i) for i in range(12)})
        out = sm.load_many([7, 3, 11, 0])
        for client, st in zip([7, 3, 11, 0], out):
            np.testing.assert_array_equal(st["c"], _state(client)["c"])
        assert sm.load_many([99], default="missing") == ["missing"]
        assert sm.stats["spills"] > 0


def test_memory_budget_enforced_with_spill():
    with tempfile.TemporaryDirectory() as d:
        budget = 5 * 420  # ~5 states
        sm = ClientStateManager(d, memory_budget_bytes=budget)
        for i in range(50):
            sm.save(i, _state(i))
        assert sm.memory_bytes <= budget
        assert sm.stats["spills"] >= 40
        assert sm.disk_bytes() > 0
        # spilled states still load correctly (from disk)
        st = sm.load(0)
        np.testing.assert_array_equal(st["c"], _state(0)["c"])
        assert sm.stats["loads"] >= 1


def test_lru_keeps_hot_clients_in_memory():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d, memory_budget_bytes=3 * 420)
        for i in range(3):
            sm.save(i, _state(i))
        sm.load(0)                  # touch 0 -> most recent
        sm.save(3, _state(3))       # evicts LRU (1)
        sm.load(0)
        assert sm.stats["hits"] >= 2


def test_missing_client_returns_default():
    with tempfile.TemporaryDirectory() as d:
        sm = ClientStateManager(d)
        assert sm.load(999) is None
        assert sm.load(999, default={"x": 1}) == {"x": 1}


def test_checkpoint_restore_roundtrip():
    with tempfile.TemporaryDirectory() as d, \
            tempfile.TemporaryDirectory() as ck:
        sm = ClientStateManager(d + "/a", memory_budget_bytes=2 * 420)
        for i in range(8):
            sm.save(i, _state(i))
        sm.checkpoint(ck)
        sm2 = ClientStateManager(d + "/b")
        n = sm2.restore(ck)
        assert n == 8
        for i in range(8):
            np.testing.assert_array_equal(sm2.load(i)["c"], _state(i)["c"])


def test_owner_host_is_deterministic_partition():
    owners = [owner_host(c, 4) for c in range(1000)]
    assert set(owners) <= set(range(4))
    counts = np.bincount(owners, minlength=4)
    assert counts.min() > 150        # roughly balanced
    assert owners == [owner_host(c, 4) for c in range(1000)]


def test_rebalance_moves_states_to_new_owners():
    with tempfile.TemporaryDirectory() as d:
        mgrs = {h: ClientStateManager(f"{d}/h{h}", host=h, n_hosts=2)
                for h in range(2)}
        # write each state to its 2-host owner
        for c in range(40):
            mgrs[owner_host(c, 2)].save(c, _state(c))
        # grow to 4 hosts
        for h in (2, 3):
            mgrs[h] = ClientStateManager(f"{d}/h{h}", host=h, n_hosts=4)
        moved = 0
        for h in (0, 1):
            moved += mgrs[h].rebalance(4, mgrs)
        assert moved > 0
        for c in range(40):
            st = mgrs[owner_host(c, 4)].load(c)
            assert st is not None
            np.testing.assert_array_equal(st["c"], _state(c)["c"])
