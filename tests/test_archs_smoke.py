"""Per-architecture smoke tests: every assigned arch instantiates a REDUCED
same-family config and runs one forward/train step on CPU — shapes + no NaNs
— plus prefill/decode-vs-full-forward consistency (the serving invariants).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS
from repro.models import lm

ARCH_NAMES = sorted(ARCHS)


def _inputs(cfg, key, B, S):
    if cfg.input_kind == "embeddings":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_smoke(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    B, S = 2, 64
    batch = {"inputs": _inputs(cfg, key, B, S),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    step = jax.jit(lm.make_train_step(cfg, 0.05))
    new_params, metrics = step(params, batch)
    assert jnp.isfinite(metrics["loss"])
    # params actually changed and shapes preserved
    changed = False
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)):
        assert a.shape == b.shape and a.dtype == b.dtype
        changed |= bool(jnp.any(a != b))
    assert changed
    for leaf in jax.tree.leaves(new_params):
        assert not jnp.any(jnp.isnan(leaf.astype(jnp.float32)))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_output_shape(name):
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(1)
    params = lm.init_params(key, cfg)
    B, S = 2, 32
    h, _, aux = lm.forward(params, _inputs(cfg, key, B, S), cfg)
    assert h.shape == (B, S, cfg.d_model)
    logits = lm._head(params, h, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    """Prefill last-logit == full forward at S-1; decode logit == forward
    at S.  Exercises ring-buffer caches and recurrent decode states."""
    cfg = ARCHS[name].reduced()
    key = jax.random.PRNGKey(2)
    params = lm.init_params(key, cfg)
    B, S = 2, 32
    full_in = _inputs(cfg, key, B, S + 1)
    prefill = jax.jit(lm.make_prefill_step(cfg, B, S, cache_len=S + 1))
    logits_p, caches = prefill(params, full_in[:, :S])
    decode = jax.jit(lm.make_decode_step(cfg))
    logits_d, _ = decode(params, full_in[:, S:S + 1], caches, jnp.int32(S))
    h, _, _ = lm.forward(params, full_in, cfg)
    full = lm._head(params, h, cfg)
    tol = 2e-4
    assert float(jnp.max(jnp.abs(logits_p[:, 0] - full[:, S - 1]))) < tol
    assert float(jnp.max(jnp.abs(logits_d[:, 0] - full[:, S]))) < tol


@pytest.mark.parametrize("name", ["hymba-1.5b", "xlstm-125m"])
def test_recurrent_long_decode_state_is_constant_size(name):
    """long_500k applicability: decode state must not grow with context."""
    from repro.models import transformer
    cfg = ARCHS[name].reduced()
    c_small = transformer.stack_cache(cfg, 1, 64, jnp.float32)
    c_large = transformer.stack_cache(cfg, 1, 4096, jnp.float32)
    b_small = sum(x.size for x in jax.tree.leaves(c_small))
    b_large = sum(x.size for x in jax.tree.leaves(c_large))
    if cfg.sliding_window:
        assert b_large <= b_small * (cfg.sliding_window / 64) + 4096 * 2
    else:
        assert b_small == b_large  # fully recurrent: identical state


def test_full_configs_match_assignment():
    """The registry carries the exact assigned hyperparameters."""
    spec = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "qwen2-0.5b": (24, 896, 14, 2, 4864, 151936),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen2.5-14b": (48, 5120, 40, 8, 13824, 152064),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
    }
    for name, (L, d, H, KV, f, V) in spec.items():
        cfg = ARCHS[name]
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, KV, f, V), name
    assert ARCHS["grok-1-314b"].moe.n_experts == 8
    assert ARCHS["grok-1-314b"].moe.top_k == 2
    assert ARCHS["llama4-scout-17b-a16e"].moe.n_experts == 16
    assert ARCHS["llama4-scout-17b-a16e"].moe.top_k == 1
    assert ARCHS["hymba-1.5b"].ssm.d_state == 16
    assert ARCHS["qwen2-0.5b"].qkv_bias and ARCHS["qwen2.5-14b"].qkv_bias


def test_moe_param_count_grok():
    """grok-1 is the '314B' config: census must land in that ballpark."""
    n = ARCHS["grok-1-314b"].n_params()
    assert 2.8e11 < n < 3.4e11, n
    na = ARCHS["grok-1-314b"].n_active_params()
    assert na < n / 2.5


def test_microbatch_equivalence():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    key = jax.random.PRNGKey(3)
    params = lm.init_params(key, cfg)
    batch = {"inputs": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}
    p1, m1 = jax.jit(lm.make_train_step(cfg, 0.05, micro_batches=1))(params, batch)
    p2, m2 = jax.jit(lm.make_train_step(cfg, 0.05, micro_batches=4))(params, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                     - b.astype(jnp.float32)))) < 1e-5
