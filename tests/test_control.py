"""Adaptive control plane (DESIGN.md §12).

Anchors:
  * ``control=None`` and ``ControlPlane.observer()`` are bit-identical
    (params AND makespan history) for all three engines — the observer only
    adds oracle tracking;
  * controller determinism — same seed + same chaos plan ⇒ identical
    λ / deadline trajectories and params digests across two runs, and
    across a mid-round kill + ``auto_resume=True``;
  * comm/compute overlap changes pricing only: params stay bit-identical,
    simulated makespans never increase;
  * window-fit selection prices a client's span + comm against its
    remaining availability window.

Plus unit coverage of the λ / deadline controllers, the hindsight-optimal
``oracle_makespan`` LPT bound, and ``rebalance_queues``.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, params_digest
from repro.core import (AsyncLambdaController, ClientAvailability,
                        ClientStateManager, ControlPlane, DeadlineController,
                        FaultPlan, LinkProfile, NetworkModel, ParrotServer,
                        RetryPolicy, SequentialExecutor, TickTimer,
                        make_algorithm, oracle_makespan, rebalance_queues)
from repro.core.scheduler import ClientTask
from repro.core.workload import WorkloadModel
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}

ENGINES = [("bsp", None),
           ("semi-sync", {"chunk_size": 2, "deadline_frac": 0.7}),
           ("async", {"chunk_size": 2})]


def _data(n=40, seed=1):
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _make_server(data, K=4, clients_per_round=10, **kw):
    algo = make_algorithm("fedavg", GRAD_FN, lr=0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0))
             for k in range(K)]
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data,
                        clients_per_round=clients_per_round, seed=7, **kw)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# controller units
# ---------------------------------------------------------------------------

def test_lambda_controller_targets_gamma():
    c = AsyncLambdaController(target_gamma=0.6)
    assert c.current(1.23) == 1.23              # fallback until first update
    lam = c.update(2.0)                          # first EWMA == observation
    # γ = 1/(1+λ·s̄) == 0.6  ⇔  λ = (1/0.6 − 1)/2
    assert lam == pytest.approx((1.0 / 0.6 - 1.0) / 2.0)
    assert c.current(1.23) == lam
    assert 1.0 / (1.0 + lam * 2.0) == pytest.approx(0.6)


def test_lambda_controller_clips():
    c = AsyncLambdaController(target_gamma=0.6, lam_min=0.05, lam_max=4.0)
    assert c.update(1e9) == 0.05                 # huge staleness → floor
    c2 = AsyncLambdaController(target_gamma=0.6, lam_min=0.05, lam_max=4.0)
    assert c2.update(0.0) == 4.0                 # zero staleness → ceiling


def test_lambda_controller_rejects_bad_gamma():
    with pytest.raises(ValueError):
        AsyncLambdaController(target_gamma=1.0)
    with pytest.raises(ValueError):
        AsyncLambdaController(target_gamma=0.0)


def test_deadline_controller_tightens_and_loosens():
    c = DeadlineController(target_ratio=0.5, gain=0.6, alpha=1.0)
    # everyone landed (ratio 1.0 > target) → deadline tightens below start
    tight = c.update(10, 10, fallback_frac=0.8, default_target=0.5)
    assert tight < 0.8
    # nobody landed (ratio 0.0 < target) → loosens back up
    loose = c.update(0, 10, fallback_frac=0.8, default_target=0.5)
    assert loose > tight
    # frac stays inside the clip box whatever the history
    for _ in range(50):
        v = c.update(10, 10, fallback_frac=0.8, default_target=0.5)
    assert v == pytest.approx(c.frac_min)
    # selected == 0 is a no-op observation
    assert c.update(0, 0, fallback_frac=0.8, default_target=0.5) == v


def test_deadline_controller_default_target():
    # target_ratio=None defers to the engine-supplied default (1/over_select)
    c = DeadlineController(target_ratio=None, alpha=1.0)
    v = c.update(5, 10, fallback_frac=0.8, default_target=0.5)
    assert v == pytest.approx(0.8)               # on-target ⇒ unchanged


def test_controller_state_round_trips():
    a = AsyncLambdaController()
    a.update(3.0)
    b = AsyncLambdaController()
    b.load_state_dict(a.state_dict())
    assert b.current(0.0) == a.current(0.0) and b._ewma == a._ewma

    d = DeadlineController(target_ratio=0.5)
    d.update(7, 10, 0.8, 0.5)
    e = DeadlineController(target_ratio=0.5)
    e.load_state_dict(d.state_dict())
    assert e.current(0.0) == d.current(0.0) and e._ewma == d._ewma


def test_control_plane_state_round_trips():
    cp = ControlPlane.adaptive()
    cp.async_lambda.update(2.0)
    cp.deadline.update(6, 10, 0.8, 0.5)
    fresh = ControlPlane.adaptive()
    fresh.load_state_dict(cp.state_dict())
    assert fresh.async_lambda.current(0.0) == cp.async_lambda.current(0.0)
    assert fresh.deadline.current(0.0) == cp.deadline.current(0.0)
    # observer state is all-None and load is a no-op on both sides
    obs = ControlPlane.observer()
    assert obs.state_dict() == {"async_lambda": None, "deadline": None}
    obs.load_state_dict(cp.state_dict())
    fresh.load_state_dict(None)


# ---------------------------------------------------------------------------
# oracle makespan (hindsight-optimal LPT bound)
# ---------------------------------------------------------------------------

def test_oracle_empty_and_single():
    assert oracle_makespan([], [0, 1]) == 0.0
    assert oracle_makespan([(10.0, 5.0, 0, 0.0)], []) == 0.0
    # one job: realized rate t/n, so the oracle replays it exactly (+comm)
    assert oracle_makespan([(10.0, 5.0, 0, 0.0)], [0]) == pytest.approx(5.0)
    assert oracle_makespan([(10.0, 5.0, 0, 2.5)], [0]) == pytest.approx(7.5)


def test_oracle_balances_over_realized_rates():
    # ex0 realized 1 s/sample, ex1 realized 2 s/sample; two 10-sample jobs
    jobs = [(10.0, 10.0, 0, 0.0), (10.0, 20.0, 1, 0.0)]
    assert oracle_makespan(jobs, [0, 1]) == pytest.approx(20.0)
    # four jobs that all ran serially on ex0 (realized makespan 40):
    # hindsight spreads them over both lanes
    jobs = [(10.0, 10.0, 0, 0.0)] * 4
    assert oracle_makespan(jobs, [0, 1]) < 40.0


def test_oracle_fleet_fallback_for_unfitted_executor():
    # executor 1 never ran anything: it prices at the fleet-mean rate and
    # the oracle still parallelises across it
    jobs = [(10.0, 10.0, 0, 0.0), (10.0, 10.0, 0, 0.0)]
    assert oracle_makespan(jobs, [0, 1]) == pytest.approx(10.0)


def test_oracle_never_exceeds_serial_pile_up():
    rng = np.random.default_rng(0)
    jobs = [(float(rng.integers(5, 50)), float(rng.uniform(1, 10)),
             int(rng.integers(0, 3)), float(rng.uniform(0, 1)))
            for _ in range(30)]
    serial = {}
    for n, t, k, c in jobs:
        serial[k] = serial.get(k, 0.0) + t + c
    assert oracle_makespan(jobs, [0, 1, 2]) <= max(serial.values()) + 1e-9


# ---------------------------------------------------------------------------
# queue rebalancing
# ---------------------------------------------------------------------------

def test_rebalance_empty_pool():
    assignment, moved = rebalance_queues({0: [], 1: []}, {0: 0.0, 1: 0.0},
                                         {})
    assert assignment == {0: [], 1: []} and moved == 0


def test_rebalance_spreads_one_sided_load():
    tasks = [ClientTask(c, 10) for c in range(6)]
    models = {0: WorkloadModel(1.0, 0.0), 1: WorkloadModel(1.0, 0.0)}
    assignment, moved = rebalance_queues({0: list(tasks), 1: []},
                                         {0: 0.0, 1: 0.0}, models)
    assert len(assignment[0]) == 3 and len(assignment[1]) == 3
    assert moved == 3
    # nothing lost or duplicated
    got = sorted(t.client for q in assignment.values() for t in q)
    assert got == list(range(6))


def test_rebalance_respects_horizons_and_rates():
    tasks = [ClientTask(c, 10) for c in range(4)]
    models = {0: WorkloadModel(1.0, 0.0), 1: WorkloadModel(1.0, 0.0)}
    # lane 1 is busy until far in the future: everything stays on lane 0
    assignment, moved = rebalance_queues({0: list(tasks), 1: []},
                                         {0: 0.0, 1: 1e6}, models)
    assert len(assignment[0]) == 4 and moved == 0
    # lane 1 is 10x faster: it takes the lion's share
    fast = {0: WorkloadModel(1.0, 0.0), 1: WorkloadModel(0.1, 0.0)}
    assignment, _ = rebalance_queues({0: list(tasks), 1: []},
                                     {0: 0.0, 1: 0.0}, fast)
    assert len(assignment[1]) > len(assignment[0])


def test_rebalance_deterministic_and_comm_aware():
    tasks = [ClientTask(c, 10 + c) for c in range(5)]
    queues = {0: tasks[:3], 1: tasks[3:]}
    horizons = {0: 2.0, 1: 0.0}
    models = {0: WorkloadModel(0.5, 0.1), 1: WorkloadModel(0.7, 0.0)}
    a = rebalance_queues(queues, horizons, models)
    b = rebalance_queues(queues, horizons, models)
    assert a == b
    # a prohibitive migration cost pins every task to the cheapest lane the
    # LPT pass would pick anyway — the call stays total (no task dropped)
    c, _ = rebalance_queues(queues, horizons, models,
                            comm_cost=lambda t: 100.0)
    assert sorted(t.client for q in c.values() for t in q) == \
        sorted(t.client for q in queues.values() for t in q)


# ---------------------------------------------------------------------------
# availability window-fit
# ---------------------------------------------------------------------------

def test_availability_fits():
    av = ClientAvailability({0: [(0.0, 5.0)]}, period=None)
    assert av.fits(0, 1.0, 3.0)          # 4 s remaining ≥ 3 s span
    assert not av.fits(0, 3.0, 3.0)      # 2 s remaining < 3 s span
    assert not av.fits(0, 6.0, 0.5)      # window already closed


def test_window_fit_selection_filters_short_windows():
    data = _data(n=20)
    # clients 0..9 have 1 s of window left at t=4; 10..19 are always on
    av = ClientAvailability(
        {c: ([(0.0, 5.0)] if c < 10 else [(0.0, 1e9)]) for c in range(20)},
        period=None)

    def pick(control):
        srv = _make_server(data, clients_per_round=12, availability=av,
                           control=control)
        # one fitted model ⇒ fleet-average predicts ~30 s per client, far
        # beyond the 1 s the short-window clients have left
        srv.estimator.last_fit = {0: WorkloadModel(t_sample=1.0, b=0.0)}
        srv.virtual_now = 4.0
        return {t.client for t in srv.select_clients()}

    fitted = pick(ControlPlane(window_fit=True))
    assert fitted and all(c >= 10 for c in fitted)
    # observer (lever off) still samples the short-window clients
    assert any(c < 10 for c in pick(ControlPlane.observer()))


def test_window_fit_inert_before_first_fit():
    data = _data(n=20)
    av = ClientAvailability({c: [(0.0, 5.0)] for c in range(20)},
                            period=None)
    a = _make_server(data, availability=av,
                     control=ControlPlane(window_fit=True))
    b = _make_server(data, availability=av, control=None)
    assert [t.client for t in a.select_clients()] == \
        [t.client for t in b.select_clients()]


# ---------------------------------------------------------------------------
# observer ≡ control=None (bit-exact), oracle tracking extras
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,opts", ENGINES)
def test_observer_is_bit_identical_to_none(engine, opts):
    a = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     control=None)
    b = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     control=ControlPlane.observer())
    ha = [a.run_round() for _ in range(4)]
    hb = [b.run_round() for _ in range(4)]
    _params_equal(a.params, b.params)
    assert [m.makespan for m in ha] == [m.makespan for m in hb]
    # the observer's only side effect: hindsight-oracle tracking.  (The
    # oracle prices jobs under the linear n·rate model, so against a
    # constant-per-chunk TickTimer it is a reference point, not a strict
    # lower bound — the benchmarks' gap can legitimately go negative.)
    assert all("oracle_makespan" not in m.extra for m in ha)
    assert all(m.extra["oracle_makespan"] > 0.0 for m in hb)


def test_semi_sync_observer_reports_static_frac():
    srv = _make_server(_data(), round_engine="semi-sync",
                       engine_opts={"chunk_size": 2, "deadline_frac": 0.7},
                       control=ControlPlane.observer())
    m = srv.run_round()
    assert m.extra["deadline_frac"] == pytest.approx(0.7)


def test_async_observer_reports_static_lambda():
    srv = _make_server(_data(), round_engine="async",
                       engine_opts={"chunk_size": 2,
                                    "staleness_lambda": 0.5},
                       control=ControlPlane.observer())
    m = srv.run_round()
    assert m.extra["staleness_lambda"] == pytest.approx(0.5)


def test_semi_sync_deadline_controller_moves_frac():
    ctrl = ControlPlane(deadline=DeadlineController(target_ratio=0.5,
                                                    alpha=1.0))
    srv = _make_server(_data(), round_engine="semi-sync",
                       engine_opts={"chunk_size": 2, "deadline_frac": 0.9},
                       control=ctrl)
    fracs = [srv.run_round().extra["deadline_frac"] for _ in range(4)]
    assert fracs[0] == pytest.approx(0.9)        # first round: fallback
    # warmup rounds (deadline ∞, nothing enforced) carry no signal — the
    # controller must NOT learn from them; it takes over once the first
    # enforced round lands
    assert fracs[1] == pytest.approx(0.9)
    assert fracs[2] != fracs[0]                  # controller took over
    assert all(ctrl.deadline.frac_min <= f <= ctrl.deadline.frac_max
               for f in fracs[2:])


def test_async_lambda_controller_moves_lambda():
    ctrl = ControlPlane(async_lambda=AsyncLambdaController(target_gamma=0.6))
    srv = _make_server(_data(), round_engine="async",
                       engine_opts={"chunk_size": 2,
                                    "staleness_lambda": 0.5},
                       control=ctrl)
    lams = [srv.run_round().extra["staleness_lambda"] for _ in range(4)]
    assert lams[0] == pytest.approx(0.5)         # first commit: fallback
    assert lams[1] != lams[0]
    assert all(ctrl.async_lambda.lam_min <= l <= ctrl.async_lambda.lam_max
               for l in lams[1:])


# ---------------------------------------------------------------------------
# comm/compute overlap: pricing-only, never slower
# ---------------------------------------------------------------------------

_NET = {c: LinkProfile(100.0, 50.0, 0.2) for c in range(40)}


@pytest.mark.parametrize("engine,opts", ENGINES)
def test_overlap_prices_only_never_slower(engine, opts):
    a = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     network=NetworkModel(_NET),
                     control=ControlPlane.observer())
    b = _make_server(_data(), round_engine=engine, engine_opts=opts,
                     network=NetworkModel(_NET),
                     control=ControlPlane(overlap_comm=True))
    ha = [a.run_round() for _ in range(4)]
    hb = [b.run_round() for _ in range(4)]
    # overlap re-prices comm but folds the same updates in the same order
    _params_equal(a.params, b.params)
    assert sum(m.makespan for m in hb) <= sum(m.makespan for m in ha) + 1e-9


def test_bsp_overlap_span_hides_slow_downlink_behind_compute():
    """A slow-link client LATE in the queue downloads while the earlier
    clients compute: the serial branch pays the queue-bottleneck download
    up front, the overlapped span hides it.  Equal links ⇒ the two prices
    coincide (the first client's download can overlap nothing)."""
    from repro.core.engine import _NetSim
    from repro.core.executor import ExecutorReport
    from repro.core.workload import RunRecord

    net = NetworkModel({0: LinkProfile(1e6, 1e6, 0.0),      # instant
                        1: LinkProfile(1e6, 100.0, 0.0)})   # 1.5 s download

    class _Srv:
        network = net
        availability = None
        _last_payload_nbytes = 150
        _wire_ratio = 1.0
    sim = _NetSim(_Srv(), t0=0.0)
    sim.payload_nbytes = 150

    rep = ExecutorReport(
        executor=0, partial={},
        records=[RunRecord(0, 0, 0, 30, 2.0), RunRecord(0, 1, 0, 30, 2.0)],
        virtual_time=4.0, wall_time=0.0, n_tasks=2,
        completed_clients=[0, 1])
    from repro.core.engine import BSPEngine
    overlap = BSPEngine._overlap_span(sim, [rep])
    sim2 = _NetSim(_Srv(), t0=0.0)
    sim2.payload_nbytes = 150
    serial = (sim2.down(rep.completed_clients) + rep.virtual_time
              + sim2.up(rep.completed_clients, rep.wire_bytes))
    # client 1's 1.5 s download hides behind client 0's 2 s of compute
    assert overlap < serial
    # span = client 0's own tiny download + both compute slices
    assert overlap == pytest.approx(150.0 / 1e6 + 4.0)
    # accounting parity: both branches charge the same downlink seconds
    assert sim.time_down == pytest.approx(sim2.time_down)


# ---------------------------------------------------------------------------
# controller determinism under chaos + crash/resume (DESIGN.md §10 × §12)
# ---------------------------------------------------------------------------

FAULT_PARAMS = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
_KILL_AFTER = {"bsp": 4, "semi-sync": 10, "async": 9}


def _fault_build(engine, ckpt_dir, control):
    data = _data(n=30)
    algo = make_algorithm("fedavg", grad_fn=GRAD_FN, lr=0.1, local_steps=2)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="ctrlckpt_"))
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0)) for k in range(3)]
    plan = FaultPlan.random(seed=3, horizon=80.0, executors=[0, 1, 2],
                            clients=list(range(30)),
                            crash_rate=0.05, restart_delay=5.0,
                            dropout_rate=0.1, dropout_duration=4.0,
                            corrupt_rate=0.05,
                            slowdown_rate=0.03, slowdown_duration=6.0)
    opts = {"chunk_size": 2} if engine != "bsp" else None
    return ParrotServer(params=FAULT_PARAMS, algorithm=algo,
                        executors=execs, data_by_client=data,
                        clients_per_round=8, seed=7, round_engine=engine,
                        engine_opts=opts, faults=plan,
                        retry=RetryPolicy(max_retries=2), control=control,
                        checkpoint_manager=CheckpointManager(
                            ckpt_dir, every_rounds=1, keep=10))


def _trajectory(history):
    return [(m.extra.get("staleness_lambda"), m.extra.get("deadline_frac"))
            for m in history]


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_adaptive_run_is_deterministic_under_chaos(engine, tmp_path):
    def mk(d):
        return _fault_build(engine, str(tmp_path / d),
                            ControlPlane.adaptive())
    a, b = mk("a"), mk("b")
    a.run(6)
    b.run(6)
    assert params_digest(a.params) == params_digest(b.params)
    assert _trajectory(a.history) == _trajectory(b.history)
    assert [m.makespan for m in a.history] == \
        [m.makespan for m in b.history]


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_adaptive_kill_then_auto_resume_is_bit_exact(engine, tmp_path):
    N = 8
    ref = _fault_build(engine, str(tmp_path / "ref"),
                       ControlPlane.adaptive())
    ref.run(N)
    want = params_digest(ref.params)

    d = str(tmp_path / "ck")
    victim = _fault_build(engine, d, ControlPlane.adaptive())
    ex0 = victim.executors[0]
    real, calls = ex0.run_queue, [0]

    def dying(*a, **kw):
        calls[0] += 1
        if calls[0] >= _KILL_AFTER[engine]:
            raise KeyboardInterrupt
        return real(*a, **kw)

    ex0.run_queue = dying
    with pytest.raises(KeyboardInterrupt):
        victim.run(N)
    assert 1 <= victim.round < N

    # a fresh server (fresh controllers) must reload the λ/deadline state
    # from the blob and replay the exact trajectory
    resumed = _fault_build(engine, d, ControlPlane.adaptive())
    resumed.run(N, auto_resume=True)
    assert resumed.round == N
    assert params_digest(resumed.params) == want
    assert _trajectory(resumed.history) == _trajectory(ref.history)
