"""SPMD global aggregate == host global aggregate (the production path)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.collective import spmd_global_aggregate
from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    global_aggregate)


def _partials(K=4, seed=0):
    rng = np.random.default_rng(seed)
    ops = {"delta": Op.WEIGHTED_AVG, "count": Op.SUM}
    parts = []
    for k in range(K):
        agg = LocalAggregator(ops)
        for _ in range(3):
            agg.fold(ClientResult(
                {"delta": {"w": jnp.asarray(rng.normal(size=(6, 2)),
                                            jnp.float32)},
                 "count": jnp.ones((), jnp.float32)},
                ops, weight=float(rng.integers(1, 50))))
        parts.append(agg.partial())
    return parts, ops


def test_spmd_aggregate_matches_host():
    parts, ops = _partials()
    host = global_aggregate(parts, ops)
    spmd = spmd_global_aggregate(parts, ops, mesh=None)
    np.testing.assert_allclose(np.asarray(host["delta"]["w"]),
                               np.asarray(spmd["delta"]["w"]), rtol=1e-6)
    assert float(host["count"]) == float(spmd["count"])


def test_spmd_aggregate_with_mesh():
    mesh = jax.make_mesh((1,), ("data",))
    parts, ops = _partials(K=3)
    host = global_aggregate(parts, ops)
    spmd = spmd_global_aggregate(parts, ops, mesh=mesh)
    np.testing.assert_allclose(np.asarray(host["delta"]["w"]),
                               np.asarray(spmd["delta"]["w"]), rtol=1e-6)
