"""Hierarchical aggregation (§4.2): exactness, OP registry, COLLECT, and the
kernel-backed fold path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate,
                                    payload_bytes)


def _results(n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(ClientResult(
            payload={
                "delta": {"w": jnp.asarray(rng.normal(size=(5, 3)),
                                           jnp.float32)},
                "tau": jnp.float32(rng.integers(1, 9)),
                "count": jnp.ones((), jnp.float32),
                "trace": jnp.asarray(rng.normal(size=(2,)), jnp.float32),
            },
            ops={"delta": Op.WEIGHTED_AVG, "tau": Op.AVG, "count": Op.SUM,
                 "trace": Op.COLLECT},
            weight=float(rng.integers(5, 200))))
    return out


OPS = {"delta": Op.WEIGHTED_AVG, "tau": Op.AVG, "count": Op.SUM,
       "trace": Op.COLLECT}


@pytest.mark.parametrize("K", [1, 2, 5])
def test_hierarchical_equals_flat_any_split(K):
    results = _results(11)
    flat = flat_aggregate(results, OPS)
    aggs = [LocalAggregator(OPS) for _ in range(K)]
    for i, r in enumerate(results):
        aggs[i % K].fold(r)
    hier = global_aggregate([a.partial() for a in aggs], OPS)
    np.testing.assert_allclose(np.asarray(flat["delta"]["w"]),
                               np.asarray(hier["delta"]["w"]), rtol=1e-6)
    np.testing.assert_allclose(float(flat["tau"]), float(hier["tau"]),
                               rtol=1e-6)
    assert float(hier["count"]) == len(results)
    assert len(hier["trace"]) == len(results)


def test_weighted_avg_is_weight_correct():
    r1 = ClientResult({"d": jnp.asarray([1.0])}, {"d": Op.WEIGHTED_AVG}, 1.0)
    r2 = ClientResult({"d": jnp.asarray([4.0])}, {"d": Op.WEIGHTED_AVG}, 3.0)
    out = flat_aggregate([r1, r2], {"d": Op.WEIGHTED_AVG})
    assert float(out["d"][0]) == pytest.approx((1 + 12) / 4)


def test_collect_preserves_order_and_weights():
    results = _results(6)
    flat = flat_aggregate(results, OPS)
    ws = [w for w, _ in flat["trace"]]
    assert ws == [r.weight for r in results]


def test_local_aggregator_memory_is_O_sa():
    """The partial's size must not grow with the number of folded clients
    (the paper's sequential-training memory claim)."""
    agg = LocalAggregator({"delta": Op.WEIGHTED_AVG})
    sizes = []
    for i, r in enumerate(_results(20)):
        agg.fold(ClientResult({"delta": r.payload["delta"]},
                              {"delta": Op.WEIGHTED_AVG}, r.weight))
        sizes.append(payload_bytes(agg.partial()["sums"]))
    assert len(set(sizes)) == 1


def test_kernel_backed_fold_matches_plain():
    results = _results(7, seed=3)
    ops = {"delta": Op.WEIGHTED_AVG}
    plain = LocalAggregator(ops, use_kernel=False)
    kern = LocalAggregator(ops, use_kernel=True)
    for r in results:
        slim = ClientResult({"delta": r.payload["delta"]}, ops, r.weight)
        plain.fold(slim)
        kern.fold(slim)
    a = global_aggregate([plain.partial()], ops)
    b = global_aggregate([kern.partial()], ops)
    np.testing.assert_allclose(np.asarray(a["delta"]["w"]),
                               np.asarray(b["delta"]["w"]), atol=1e-5)


def test_sum_op_ignores_weights():
    r1 = ClientResult({"c": jnp.asarray([2.0])}, {"c": Op.SUM}, 100.0)
    r2 = ClientResult({"c": jnp.asarray([3.0])}, {"c": Op.SUM}, 1.0)
    out = flat_aggregate([r1, r2], {"c": Op.SUM})
    assert float(out["c"][0]) == 5.0
