"""Data partitioners, synthetic datasets, optimizers, compression units."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (dirichlet_label_partition, make_classification_clients,
                        make_lm_clients, partition_sizes)
from repro.optim.optimizers import (adamw, apply_updates, fedadam, fedavgm,
                                    fedyogi, sgd)


def test_natural_sizes_heterogeneous():
    sizes = partition_sizes("natural", 500, seed=0)
    assert sizes.min() >= 4
    assert sizes.max() / np.median(sizes) > 3    # long tail


def test_quantity_skew_heavier_than_natural():
    nat = partition_sizes("natural", 2000, seed=0)
    qs = partition_sizes("quantity_skew", 2000, 5.0, seed=0)
    assert (qs.max() / np.median(qs)) > (nat.max() / np.median(nat))


def test_dirichlet_label_partition_covers_all_examples():
    labels = np.random.default_rng(0).integers(0, 10, size=5000)
    parts = dirichlet_label_partition(labels, 20, alpha=0.1, seed=0)
    got = np.sort(np.concatenate(parts))
    np.testing.assert_array_equal(got, np.arange(5000))


def test_dirichlet_is_label_skewed():
    labels = np.random.default_rng(0).integers(0, 10, size=20000)
    parts = dirichlet_label_partition(labels, 20, alpha=0.05, seed=0)
    # a strongly skewed client should be dominated by few classes
    fracs = []
    for p in parts:
        if len(p) < 50:
            continue
        counts = np.bincount(labels[p], minlength=10)
        fracs.append(counts.max() / counts.sum())
    assert np.median(fracs) > 0.5


def test_classification_clients_fixed_batch_shapes():
    data = make_classification_clients(10, dim=8, n_classes=4, batch_size=16,
                                       seed=0)
    for cd in data.values():
        for b in cd.batches:
            assert b["x"].shape == (16, 8)
            assert b["y"].shape == (16,)


def test_lm_clients_shapes():
    data = make_lm_clients(5, vocab=128, seq_len=32, batch_size=4, seed=0)
    for cd in data.values():
        for b in cd.batches:
            assert b["inputs"].shape == (4, 32)
            assert b["labels"].shape == (4, 32)
            assert b["inputs"].max() < 128


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_min(opt, steps=200):
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(steps):
        grads = {"x": 2 * params["x"]}       # d/dx ||x||^2
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    return float(jnp.max(jnp.abs(params["x"])))


def test_sgd_converges_on_quadratic():
    assert _quad_min(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quad_min(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _quad_min(adamw(0.1), steps=400) < 1e-2


@pytest.mark.parametrize("factory", [fedavgm, fedadam, fedyogi])
def test_server_optimizers_step_toward_delta(factory):
    srv = factory()
    params = {"x": jnp.zeros((4,))}
    srv.init(params)
    delta = {"x": jnp.ones((4,))}
    out = srv.step(params, delta)
    assert float(jnp.min(out["x"])) > 0      # moved in the delta direction


# ---------------------------------------------------------------------------
# compression units
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound():
    from repro.core.compression import Int8Compressor
    comp = Int8Compressor()
    rng = np.random.default_rng(0)
    a = rng.normal(size=(1000,)).astype(np.float32)
    c = comp._compress_array(a)
    b = comp._decompress_array(c)
    assert np.abs(a - b).max() <= np.abs(a).max() / 127.0 + 1e-6
    assert c.nbytes < a.nbytes / 3.5         # ~4x compression


def test_topk_wire_size():
    from repro.core.compression import TopKCompressor
    comp = TopKCompressor(fraction=0.01)
    a = np.random.default_rng(0).normal(size=(10000,)).astype(np.float32)
    c = comp._compress_array(a, "k")
    assert len(c.data["vals"]) == 100
    assert c.nbytes < a.nbytes / 10
