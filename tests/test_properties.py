"""Property-based tests (hypothesis) over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate)
from repro.core.scheduler import ClientTask, ParrotScheduler, makespan
from repro.core.workload import RunRecord, WorkloadEstimator, WorkloadModel

SETTINGS = dict(max_examples=25, deadline=None)


@given(weights=st.lists(st.floats(1.0, 100.0), min_size=2, max_size=12),
       K=st.integers(1, 6), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_hierarchical_aggregation_exact_for_any_partition(weights, K, seed):
    """Σ w x / Σ w is invariant to how clients are split across executors."""
    rng = np.random.default_rng(seed)
    ops = {"d": Op.WEIGHTED_AVG}
    results = [ClientResult({"d": jnp.asarray(rng.normal(size=(4,)),
                                              jnp.float32)}, ops, w)
               for w in weights]
    flat = flat_aggregate(results, ops)
    aggs = [LocalAggregator(ops) for _ in range(K)]
    for i, r in enumerate(results):
        aggs[int(rng.integers(K))].fold(r)
    hier = global_aggregate([a.partial() for a in aggs if a.n_clients], ops)
    np.testing.assert_allclose(np.asarray(flat["d"]), np.asarray(hier["d"]),
                               rtol=1e-5, atol=1e-6)


@given(sizes=st.lists(st.integers(1, 1000), min_size=1, max_size=60),
       K=st.integers(1, 8))
@settings(**SETTINGS)
def test_schedule_is_a_partition(sizes, K):
    """Every task assigned exactly once, no invented tasks."""
    sched = ParrotScheduler(WorkloadEstimator(), warmup_rounds=0)
    tasks = [ClientTask(i, n) for i, n in enumerate(sizes)]
    s = sched.schedule(1, tasks, list(range(K)))
    got = sorted(t.client for q in s.assignment.values() for t in q)
    assert got == list(range(len(sizes)))


@given(sizes=st.lists(st.integers(1, 500), min_size=4, max_size=40),
       K=st.integers(2, 6), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_lpt_never_worse_than_round_robin_homogeneous(sizes, K, seed):
    """With identical executors, LPT's predicted makespan <= round robin's
    (classic scheduling-theory property of the greedy heuristic)."""
    models = {k: WorkloadModel(0.01, 0.1) for k in range(K)}
    est = WorkloadEstimator()
    rng = np.random.default_rng(seed)
    for r in range(2):
        for i, n in enumerate(sizes):
            k = int(rng.integers(K))
            est.record(RunRecord(r, i, k, n, models[k].predict(n)))
    tasks = [ClientTask(i, n) for i, n in enumerate(sizes)]
    lpt = ParrotScheduler(est, warmup_rounds=0).schedule(
        3, tasks, list(range(K)))
    rr = ParrotScheduler(est, warmup_rounds=0, policy="none").schedule(
        3, tasks, list(range(K)))
    # LPT guarantees makespan <= (4/3 - 1/3K)·OPT; round robin >= OPT, so
    # LPT <= 4/3·RR (LPT can lose to RR on adversarial instances, but never
    # by more than the approximation bound)
    assert makespan(lpt.assignment, models) <= \
        makespan(rr.assignment, models) * (4 / 3) + 1e-9


@given(n=st.integers(4, 200), frac=st.floats(0.05, 1.0),
       rounds=st.integers(1, 8), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_topk_error_feedback_conserves_mass(n, frac, rounds, seed):
    """Error feedback invariant: Σ transmitted + residual == Σ inputs —
    nothing is ever lost, only delayed (what makes top-k unbiased long-run)."""
    from repro.core.compression import TopKCompressor
    rng = np.random.default_rng(seed)
    comp = TopKCompressor(fraction=frac)
    transmitted = np.zeros((n,), np.float32)
    total_in = np.zeros((n,), np.float32)
    for _ in range(rounds):
        delta = rng.normal(size=(n,)).astype(np.float32)
        total_in += delta
        c = comp._compress_array(delta, "x")
        transmitted += comp._decompress_array(c)
    residual = comp._residual["x"]
    np.testing.assert_allclose(transmitted + residual, total_in,
                               atol=1e-4, rtol=1e-4)


@given(T=st.integers(2, 64), d=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_rmsnorm_output_is_scale_invariant(T, d, seed):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scale (the invariant that
    makes it a norm)."""
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(T, d)).astype(np.float32)
    # invariance is exact only as eps -> 0; keep rows away from zero
    x += np.sign(x) * 0.5
    x = jnp.asarray(x)
    g = jnp.ones((d,), jnp.float32)
    a = ref.rmsnorm_ref(x, g)
    b = ref.rmsnorm_ref(3.7 * x, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@given(S=st.sampled_from([32, 64, 128]), chunk=st.sampled_from([8, 16, 32]),
       seed=st.integers(0, 30))
@settings(**SETTINGS)
def test_online_softmax_attention_chunk_invariant(S, chunk, seed):
    from repro.models.attention import chunked_attention, dense_attention
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, S, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, S, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, S, 2, 8)), jnp.float32)
    a = dense_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                               rtol=1e-4)


@given(sizes=st.lists(st.integers(1, 400), min_size=3, max_size=30),
       eta=st.floats(0.0, 4.0))
@settings(**SETTINGS)
def test_makespan_lower_bound(sizes, eta):
    """Predicted makespan >= total work / K (work conservation)."""
    K = 4
    models = {k: WorkloadModel(0.01 * (1 + (eta if k == 0 else 0)), 0.0)
              for k in range(K)}
    est = WorkloadEstimator()
    for i, n in enumerate(sizes):
        for k in range(K):
            est.record(RunRecord(0, i, k, n, models[k].predict(n)))
    tasks = [ClientTask(i, n) for i, n in enumerate(sizes)]
    s = ParrotScheduler(est, warmup_rounds=0).schedule(1, tasks,
                                                       list(range(K)))
    ms = makespan(s.assignment, models)
    fastest = min(m.t_sample for m in models.values())
    assert ms >= fastest * sum(sizes) / K - 1e-9
