"""Event-driven round engine tests (DESIGN.md §3).

The anchor is bit-exactness: ``round_engine="bsp"`` must reproduce the
pre-engine monolithic ``run_round`` *exactly* — same params (same float
summation order) and same makespan history.  ``LegacyServer`` below freezes
the pre-refactor loop verbatim as the golden reference; executors run under
a deterministic :class:`TickTimer` so measured durations are a pure function
of the code path taken, which makes makespan equality a proof that the
engine issues the identical call sequence.

Around the anchor: semi-sync deadline carry-over, async bounded-staleness
convergence against the flat single-process reference, failure injection
under every mode, the comm ``poll`` contract, chunked ``run_queue``
emission, and the orphaned-pending-schedule (dropped clients) regression.
"""
import concurrent.futures as cf
import math
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comm.collective import CollectiveComm
from repro.comm.local import LocalComm
from repro.core import (ClientStateManager, LocalAggregator, Op, ParrotServer,
                        RoundMetrics, SequentialExecutor, TickTimer,
                        VirtualClock, make_algorithm, run_flat_reference)
from repro.core.aggregation import (ClientResult, global_aggregate,
                                    merge_partials, scale_partial,
                                    staleness_weight)
from repro.core.executor import ExecutorFailure, hetero_gpus
from repro.core.scheduler import ClientTask, Schedule, split_chunks
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


def _data(n=40, seed=1):
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _eval_loss(params, data):
    tot, n = 0.0, 0
    for d in data.values():
        for b in d.batches:
            tot += float(_loss_fn(params, b)) * len(b["y"])
            n += len(b["y"])
    return tot / n


def _max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _make_server(algo, data, K=4, fail_at=None, speed=None, tick=None,
                 clients_per_round=10, **kw):
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = []
    for k in range(K):
        e = SequentialExecutor(
            k, algo, state_manager=sm,
            speed_model=speed or (lambda kk, r: 0.0),
            timer=TickTimer(1.0) if tick else None)
        if fail_at and k == fail_at[0]:
            e.fail_at = fail_at[1]
        execs.append(e)
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data,
                        clients_per_round=clients_per_round, seed=7, **kw)


# ---------------------------------------------------------------------------
# the frozen pre-engine loop (golden reference for bit-exactness)
# ---------------------------------------------------------------------------

class LegacyServer(ParrotServer):
    """Verbatim copy of the monolithic ``run_round``/``_dispatch`` as they
    existed before the engine refactor (PR 2 state).  Frozen here as the
    golden reference — do not "fix" or modernise this code."""

    def _dispatch(self, rnd, schedule, payload, skip_map=None):
        live = list(self.executors)
        self.comm.broadcast(payload, live, tag="broadcast")
        reports = []
        failed = []
        done_clients = set()

        def run(k):
            return self.executors[k].run_queue(
                rnd, schedule.queue(k), payload, self.data_by_client,
                skip_clients=(skip_map or {}).get(k))

        if self.parallel_dispatch:
            with cf.ThreadPoolExecutor(max_workers=len(live)) as pool:
                futs = {pool.submit(run, k): k for k in live}
                for fut in cf.as_completed(futs):
                    k = futs[fut]
                    try:
                        reports.append(fut.result())
                    except ExecutorFailure:
                        failed.append(k)
        else:
            for k in live:
                try:
                    reports.append(run(k))
                except ExecutorFailure:
                    failed.append(k)

        if failed:
            for rep in reports:
                done_clients.update(rep.completed_clients)
            survivors = [k for k in live if k not in failed]
            if not survivors:
                raise RuntimeError("all executors failed")
            leftovers = []
            for k in failed:
                for t in schedule.queue(k):
                    if t.client not in done_clients:
                        done_clients.add(t.client)
                        leftovers.append(t)
                del self.executors[k]
            for i, t in enumerate(leftovers):
                k = survivors[i % len(survivors)]
                rep = self.executors[k].run_queue(
                    rnd, [t], payload, self.data_by_client)
                reports.append(rep)

        for rep in reports:
            self.comm.executor_send(rep.executor,
                                    self._maybe_compress(rep.partial),
                                    tag="partial")
            rep.partial = self._maybe_decompress(
                self.comm.recv_from_executor(rep.executor, tag="partial"))
        return reports, len(failed)

    def run_round(self):
        rnd = self.round
        t_wall = time.perf_counter()
        if self._next_tasks is not None:
            tasks, self._next_tasks = self._next_tasks, None
        else:
            tasks = self.select_clients()

        if self._pending_schedule is not None:
            schedule, overlapped = self._pending_schedule, True
            self._pending_schedule = None
        else:
            schedule, overlapped = self.scheduler.schedule(
                rnd, tasks, list(self.executors)), False

        payload = self.algorithm.broadcast_payload(self.params,
                                                   self.server_state)
        skip_map, n_backups = self._plan_backups(schedule)
        reports, n_failed = self._dispatch(rnd, schedule, payload, skip_map)

        if self.overlap_scheduling:
            self.estimator.record_many(
                [rec for r in reports for rec in r.records])
            self._next_tasks = self.select_clients()
            self._pending_schedule = self.scheduler.schedule(
                rnd + 1, self._next_tasks, list(self.executors))

        partials = [r.partial for r in reports]
        ops = self.algorithm.ops()
        agg = global_aggregate(partials, ops)
        agg["_n_selected"] = sum(r.n_tasks for r in reports)
        self.params, self.server_state = self.algorithm.server_update(
            self.params, agg, self.server_state, len(self.data_by_client))

        records = [rec for r in reports for rec in r.records]
        err = float("nan")
        if self.estimator.last_fit:
            err = self.estimator.estimation_error(self.estimator.last_fit,
                                                  records)
        if not self.overlap_scheduling:
            self.estimator.record_many(records)
        makespan = max((r.virtual_time for r in reports), default=0.0)
        stats = self.comm.stats.reset()
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=0.0 if overlapped else schedule.schedule_time_s,
            estimate_time=0.0 if overlapped else schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(self.executors),
            estimation_error=err, failures=n_failed,
            extra={"backup_tasks": float(n_backups)})
        self.history.append(metrics)
        self.round += 1

        if self.checkpoint_manager is not None:
            self.checkpoint_manager.maybe_save(self)
        return metrics


BSP_VARIANTS = {
    "plain": {},
    "overlap": {"overlap_scheduling": True},
    "backups": {"backup_fraction": 0.3, "warmup_rounds": 2},
    "failure": {"warmup_rounds": 2},     # + fail_at on executor 2
}


@pytest.mark.parametrize("variant", sorted(BSP_VARIANTS))
def test_bsp_bit_exact_vs_legacy(variant):
    """mode="bsp" reproduces the pre-engine loop bit-exactly: identical
    params (same float summation order) AND identical makespan history
    (under TickTimer, makespan equality == call-sequence equality)."""
    kw = dict(BSP_VARIANTS[variant])
    fail = (2, (1, 1)) if variant == "failure" else None
    data = _data()
    legacy = LegacyServer.__new__(LegacyServer)
    srv_l = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
                         fail_at=fail, tick=True, **kw)
    # rebind to the legacy loop with identical construction
    srv_l.__class__ = LegacyServer
    srv_e = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
                         fail_at=fail, tick=True, round_engine="bsp", **kw)
    ms_l = [srv_l.run_round() for _ in range(5)]
    ms_e = [srv_e.run_round() for _ in range(5)]
    assert _max_diff(srv_l.params, srv_e.params) == 0.0
    assert [m.makespan for m in ms_l] == [m.makespan for m in ms_e]
    assert [m.comm_trips for m in ms_l] == [m.comm_trips for m in ms_e]
    assert [m.n_executors for m in ms_l] == [m.n_executors for m in ms_e]
    if variant == "failure":
        assert ms_e[1].failures == 1 and ms_e[2].n_executors == 3


def test_bsp_bit_exact_stateful_scaffold():
    srv_l = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), _data(),
                         tick=True)
    srv_l.__class__ = LegacyServer
    srv_e = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), _data(),
                         tick=True, round_engine="bsp")
    ms_l = [srv_l.run_round() for _ in range(4)]
    ms_e = [srv_e.run_round() for _ in range(4)]
    assert _max_diff(srv_l.params, srv_e.params) == 0.0
    assert [m.makespan for m in ms_l] == [m.makespan for m in ms_e]


# ---------------------------------------------------------------------------
# clock + timer
# ---------------------------------------------------------------------------

def test_virtual_clock_orders_by_time_then_seq():
    c = VirtualClock()
    c.push(2.0, "b")
    c.push(1.0, "a")
    c.push(1.0, "a2")
    c.push(3.0, "c")
    kinds = [c.pop().kind for _ in range(4)]
    assert kinds == ["a", "a2", "b", "c"]
    assert c.now == 3.0
    with pytest.raises(ValueError):
        c.push(1.0, "past")


def test_tick_timer_is_deterministic():
    t1, t2 = TickTimer(0.5), TickTimer(0.5)
    assert [t1() for _ in range(3)] == [t2() for _ in range(3)]


# ---------------------------------------------------------------------------
# comm poll pair
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comm_cls", [LocalComm, CollectiveComm])
def test_poll_non_blocking_contract(comm_cls):
    comm = comm_cls()
    assert comm.poll(0, "partial") is None
    comm.executor_send(0, {"x": 1}, tag="partial")
    assert comm.poll(1, "partial") is None          # wrong executor
    assert comm.poll(0, "other") is None            # wrong tag
    assert comm.poll(0, "partial") == {"x": 1}
    assert comm.poll(0, "partial") is None          # consumed


def test_local_poll_preserves_fifo():
    comm = LocalComm()
    comm.executor_send(3, "a", tag="t")
    comm.executor_send(3, "b", tag="t")
    assert comm.poll(3, "t") == "a"
    assert comm.poll(3, "t") == "b"


# ---------------------------------------------------------------------------
# chunked executor emission
# ---------------------------------------------------------------------------

def test_chunked_run_queue_emits_and_merges():
    data = _data(12)
    algo = make_algorithm("fedavg", GRAD_FN, 0.1)
    tasks = [ClientTask(c, data[c].n_samples) for c in sorted(data)[:10]]
    payload = algo.broadcast_payload(PARAMS0, algo.server_init(PARAMS0))

    ex1 = SequentialExecutor(0, algo)
    whole = ex1.run_queue(0, tasks, payload, data)

    seen = []
    ex2 = SequentialExecutor(1, algo)
    chunked = ex2.run_queue(0, tasks, payload, data, chunk_size=3,
                            on_partial=seen.append)
    assert len(seen) == math.ceil(len(tasks) / 3)
    assert [r.n_tasks for r in seen] == [3, 3, 3, 1]
    # same clients complete (order differs: signature-blocking is per-chunk)
    assert sorted(chunked.completed_clients) == sorted(whole.completed_clients)
    # merged chunk partials aggregate to the same result as one span
    ops = algo.ops()
    a = global_aggregate([whole.partial], ops)
    b = global_aggregate([chunked.partial], ops)
    assert _max_diff(a["delta"], b["delta"]) < 1e-6
    # per-chunk partials fold independently to the same aggregate too
    c = global_aggregate([r.partial for r in seen], ops)
    assert _max_diff(a["delta"], c["delta"]) < 1e-6


def test_chunked_fail_at_uses_global_task_index():
    data = _data(12)
    algo = make_algorithm("fedavg", GRAD_FN, 0.1)
    tasks = [ClientTask(c, data[c].n_samples) for c in sorted(data)[:8]]
    payload = algo.broadcast_payload(PARAMS0, algo.server_init(PARAMS0))
    ex = SequentialExecutor(0, algo, fail_at=(0, 5))
    seen = []
    with pytest.raises(ExecutorFailure) as ei:
        ex.run_queue(0, tasks, payload, data, chunk_size=2,
                     on_partial=seen.append)
    assert ei.value.task_index == 5
    assert len(seen) == 2          # chunks [0,1] and [2,3] completed first


def test_split_chunks():
    ts = [ClientTask(i, 1) for i in range(7)]
    assert [len(c) for c in split_chunks(ts, 3)] == [3, 3, 1]
    assert [t.client for c in split_chunks(ts, 3) for t in c] == list(range(7))


# ---------------------------------------------------------------------------
# staleness weighting on the wire format
# ---------------------------------------------------------------------------

def _partial_of(value, weight, extra_sum=None):
    ops = {"delta": Op.WEIGHTED_AVG}
    if extra_sum is not None:
        ops["cnt"] = Op.SUM
    agg = LocalAggregator(ops)
    payload = {"delta": {"w": jnp.full((4,), float(value))}}
    if extra_sum is not None:
        payload["cnt"] = jnp.asarray([float(extra_sum)])
    agg.fold(ClientResult(payload, ops, weight=weight))
    return agg.partial(), ops


def test_staleness_weight_formula():
    assert staleness_weight(0, 0.5) == 1.0
    assert staleness_weight(1, 0.5) == pytest.approx(1 / 1.5)
    assert staleness_weight(4, 0.25) == pytest.approx(0.5)


def test_scale_partial_weighted_avg_discounts_contribution():
    p1, ops = _partial_of(1.0, weight=2.0, extra_sum=10.0)
    p2, _ = _partial_of(5.0, weight=2.0, extra_sum=10.0)
    gamma = 0.5
    out = global_aggregate([p1, scale_partial(p2, gamma)], ops)
    # weighted avg with relative weight gamma on the stale partial
    expect = (2.0 * 1.0 + gamma * 2.0 * 5.0) / (2.0 + gamma * 2.0)
    assert _max_diff(out["delta"], {"w": jnp.full((4,), expect)}) < 1e-6
    # SUM entries are discounted to gamma * value
    assert float(out["cnt"][0]) == pytest.approx(10.0 + gamma * 10.0)


def test_scale_partial_gamma_one_is_identity():
    p, _ = _partial_of(3.0, weight=1.0)
    assert scale_partial(p, 1.0) is p


def test_merge_partials_matches_list_aggregate():
    p1, ops = _partial_of(1.0, weight=1.0)
    p2, _ = _partial_of(2.0, weight=3.0)
    p3, _ = _partial_of(-4.0, weight=2.0)
    merged = None
    for p in (p1, p2, p3):
        merged = merge_partials(merged, p)
    a = global_aggregate([p1, p2, p3], ops)
    b = global_aggregate([merged], ops)
    assert _max_diff(a["delta"], b["delta"]) < 1e-6
    assert merged["n_clients"] == 3
    # merging never mutated the source partials
    c = global_aggregate([p1, p2, p3], ops)
    assert _max_diff(a["delta"], c["delta"]) == 0.0


# ---------------------------------------------------------------------------
# schedule remap (orphaned pending schedule → dropped clients fix)
# ---------------------------------------------------------------------------

def test_schedule_remap_rehomes_orphans():
    ts = [ClientTask(i, 10) for i in range(6)]
    s = Schedule({0: ts[:2], 1: ts[2:4], 7: ts[4:]}, 0.0, 0.0, 0.0)
    moved = s.remap([0, 1])
    assert moved == 2
    assert 7 not in s.assignment
    got = sorted(t.client for q in s.assignment.values() for t in q)
    assert got == list(range(6))
    assert s.remap([0, 1]) == 0    # idempotent


def test_orphaned_pending_schedule_clients_still_run():
    """Regression: with overlap_scheduling, an executor lost between rounds
    leaves the pre-computed schedule assigning a queue to a dead id; the
    engine must re-map it instead of silently dropping those clients."""
    def run(overlap):
        srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
                           overlap_scheduling=overlap)
        srv.run_round()
        del srv.executors[3]        # elastic removal between rounds
        m = srv.run_round()
        return srv, m

    srv_o, m_o = run(True)          # pending schedule had 4 executors
    srv_n, m_n = run(False)         # fresh schedule over 3 executors
    assert m_o.extra.get("remapped_tasks", 0.0) > 0
    # every selected client folded: the overlapped run matches the
    # non-overlapped run (same rng stream → same cohort)
    assert _max_diff(srv_o.params, srv_n.params) < 1e-5


# ---------------------------------------------------------------------------
# semi-sync
# ---------------------------------------------------------------------------

def _semi_server(data, deadline_frac=0.5, over_select=1.5, speed=None, K=4,
                 fail_at=None, **kw):
    return _make_server(
        make_algorithm("fedavg", GRAD_FN, 0.1), data, K=K, speed=speed,
        fail_at=fail_at, round_engine="semi-sync",
        engine_opts={"deadline_frac": deadline_frac,
                     "over_select": over_select, "chunk_size": 2}, **kw)


def test_semi_sync_warmup_round_completes_fully():
    """No workload model yet → deadline ∞ → the whole (over-selected)
    cohort lands and nothing carries."""
    srv = _semi_server(_data())
    m = srv.run_round()
    assert m.extra["carried_tasks"] == 0.0
    assert m.extra["landed_clients"] == m.n_clients == 15   # ceil(1.5 × 10)


def test_semi_sync_deadline_carries_unfinished_tasks():
    """A 19×-slow executor cannot drain its queue by the deadline: its tail
    carries into the next round's pool and still gets trained."""
    srv = _semi_server(_data(), deadline_frac=0.5,
                       speed=hetero_gpus({3: 18.0}), warmup_rounds=1)
    loss0 = _eval_loss(srv.params, srv.data_by_client)
    ms = srv.run(8)
    carried = [m.extra["carried_tasks"] for m in ms]
    assert sum(carried) > 0, carried
    # a round after a carry still folds work and the pool includes the
    # carried tasks (n_clients == carried + fresh)
    r = next(i for i, c in enumerate(carried) if c > 0)
    assert ms[r + 1].n_clients == 15
    assert ms[r + 1].extra["landed_clients"] > 0
    assert _eval_loss(srv.params, srv.data_by_client) < loss0


def test_semi_sync_failure_recovers_and_shrinks_K():
    srv = _semi_server(_data(), fail_at=(2, (1, 1)), warmup_rounds=2)
    ms = srv.run(4)
    assert sum(m.failures for m in ms) == 1
    assert len(srv.executors) == 3
    assert ms[-1].n_executors == 3
    assert all(np.isfinite(jax.tree.leaves(srv.params)[0]).all()
               for _ in [0])
    assert ms[-1].extra["landed_clients"] > 0


# ---------------------------------------------------------------------------
# async (bounded staleness)
# ---------------------------------------------------------------------------

def _async_server(data, lam=0.5, speed=None, K=4, fail_at=None,
                  scheduler_policy="parrot", **kw):
    return _make_server(
        make_algorithm("fedavg", GRAD_FN, 0.1), data, K=K, speed=speed,
        fail_at=fail_at, round_engine="async",
        scheduler_policy=scheduler_policy,
        engine_opts={"staleness_lambda": lam, "chunk_size": 2}, **kw)


def test_async_converges_close_to_flat_reference():
    """20 bounded-staleness update windows land within tolerance of the
    20-round synchronous flat reference (ISSUE acceptance: async trains,
    staleness discount does not stall convergence)."""
    data = _data(60, seed=3)
    flat, _ = run_flat_reference(
        PARAMS0, make_algorithm("fedavg", GRAD_FN, 0.1), data,
        clients_per_round=10, n_rounds=20, seed=7)
    # TickTimer pins the event interleaving (and therefore the staleness
    # pattern), so the comparison does not drift with host noise
    srv = _async_server(_data(60, seed=3), tick=True)
    srv.run(20)
    loss0 = _eval_loss(PARAMS0, data)
    loss_flat = _eval_loss(flat, data)
    loss_async = _eval_loss(srv.params, srv.data_by_client)
    assert loss_async < loss0                       # it learned
    assert abs(loss_async - loss_flat) / loss_flat < 0.10


def test_async_stale_folds_are_discounted_and_counted():
    srv = _async_server(_data())
    ms = srv.run(8)
    stale = sum(m.extra["stale_folds"] for m in ms)
    # pipelining guarantees in-flight chunks across update boundaries
    assert stale > 0
    assert all(m.extra["mean_staleness"] >= 0 for m in ms)


def test_async_work_stealing_engages_under_heterogeneity():
    """With round-robin placement and one 15×-slow executor, fast executors
    drain their queues first and must steal from the straggler."""
    srv = _async_server(_data(60, seed=3), speed=hetero_gpus({0: 15.0}),
                        scheduler_policy="none")
    ms = srv.run(6)
    assert sum(m.extra["steals"] for m in ms) > 0


def test_async_failure_recovers_and_shrinks_K():
    srv = _async_server(_data(), fail_at=(1, (0, 1)))
    ms = srv.run(5)
    assert sum(m.failures for m in ms) == 1
    assert len(srv.executors) == 3
    loss = _eval_loss(srv.params, srv.data_by_client)
    assert np.isfinite(loss)


def test_async_failure_at_update_boundary_does_not_resurrect():
    """Regression: a failure event pushed by the very fold that reaches the
    update goal used to leave the loop with the event pending; the post-
    update wake then re-dispatched onto the doomed executor and the next
    round crashed (KeyError) when the stale chunk_done popped.  The executor
    must stay dead, its post-failure refill tasks must re-home, and no
    client may be lost from the in-flight set."""
    # rnd=-1 wildcard: die at the 4th dispatched task whichever update
    # window it lands in — with goal=2 every fold is an update boundary,
    # so the failure event is pending when a window closes
    srv = _make_server(
        make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
        K=3, fail_at=(1, (-1, 3)), tick=True, clients_per_round=2,
        round_engine="async", engine_opts={"chunk_size": 2})
    ms = srv.run(8)          # used to raise KeyError on a matching config
    assert sum(m.failures for m in ms) == 1
    assert len(srv.executors) == 2
    assert 1 not in srv.executors
    # the engine keeps making progress after the K shrink
    assert ms[-1].n_clients > 0


def test_bsp_only_knobs_rejected_by_des_engines():
    for knob in ({"backup_fraction": 0.2}, {"parallel_dispatch": True},
                 {"overlap_scheduling": True}):
        for mode in ("semi-sync", "async"):
            with pytest.raises(ValueError):
                _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
                             round_engine=mode, **knob)


def test_async_fail_at_index_is_cumulative_across_refills():
    """fail_at's task index counts tasks dispatched by the executor
    cumulatively across refills (a per-refill reset made mid-stream indices
    unreachable): index 9 only exists if offsets accumulate past the first
    refill (each executor starts with ~4 queued tasks)."""
    srv = _make_server(
        make_algorithm("fedavg", GRAD_FN, 0.1), _data(),
        K=4, fail_at=(1, (-1, 9)), tick=True, clients_per_round=8,
        round_engine="async", engine_opts={"chunk_size": 2})
    ms = srv.run(6)
    assert sum(m.failures for m in ms) == 1
    assert len(srv.executors) == 3


def test_async_deterministic_under_tick_timer():
    def run():
        srv = _async_server(_data(), tick=True)
        ms = srv.run(6)
        return srv.params, [m.makespan for m in ms]

    p1, m1 = run()
    p2, m2 = run()
    assert m1 == m2
    assert _max_diff(p1, p2) == 0.0


def test_async_makespan_beats_bsp_under_heterogeneity():
    """The headline claim: folding partials as they land hides stragglers
    that BSP must wait for.  Both modes run under identical dynamic
    heterogeneity and a TickTimer, so every executor block costs the same
    virtual dt and the comparison is deterministic: BSP pays
    ``max_k Σ (1+η_k)``, async pays roughly the fleet mean."""
    from repro.core.executor import dynamic_env

    def mean_makespan(mode, opts=None):
        srv = _make_server(
            make_algorithm("fedavg", GRAD_FN, 0.1), _data(80, seed=3),
            speed=dynamic_env(4, 10), round_engine=mode, tick=True,
            engine_opts=opts or {}, warmup_rounds=2,
            clients_per_round=32)
        ms = [srv.run_round().makespan for _ in range(10)]
        return float(np.mean(ms[3:]))

    bsp = mean_makespan("bsp")
    asy = mean_makespan("async", {"chunk_size": 8})
    assert asy < bsp * 0.75, (bsp, asy)
