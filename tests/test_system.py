"""End-to-end behaviour tests for the Parrot system (the paper's claims).

The central invariant (Fig. 4 / §4.2): Parrot's sequential + hierarchical
execution produces the SAME model as the flat single-process reference, for
every algorithm, under any scheduler, any executor count, with state spilled
to disk, with failures injected, and across checkpoint/restore.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm, run_flat_reference)
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


def _data(n=40, seed=1):
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _make_server(algo, data, K=4, state_dir=None, budget=1 << 20, **kw):
    sm = ClientStateManager(state_dir or tempfile.mkdtemp(),
                            memory_budget_bytes=budget)
    execs = [SequentialExecutor(k, algo, state_manager=sm) for k in range(K)]
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=10, seed=7,
                        **kw)


def _max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("name", ["fedavg", "fedprox", "fednova", "mime",
                                  "scaffold", "feddyn"])
def test_parrot_equals_flat_reference(name):
    """Hierarchical aggregation is exact for all six algorithms."""
    data = _data()
    flat, _ = run_flat_reference(
        PARAMS0, make_algorithm(name, GRAD_FN, 0.1, local_epochs=2),
        data, clients_per_round=10, n_rounds=3, seed=7)
    srv = _make_server(make_algorithm(name, GRAD_FN, 0.1, local_epochs=2),
                       data)
    srv.run(3)
    assert _max_diff(flat, srv.params) < 1e-5


@pytest.mark.parametrize("K", [1, 3, 8])
def test_result_independent_of_executor_count(K):
    """K is a pure throughput knob: the trained model must not depend on it."""
    data = _data()
    srv = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), data, K=K)
    srv.run(3)
    ref_srv = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), data, K=2)
    ref_srv.run(3)
    assert _max_diff(srv.params, ref_srv.params) < 1e-5


@pytest.mark.parametrize("policy", ["parrot", "uniform", "none"])
def test_result_independent_of_scheduler(policy):
    """Scheduling changes placement, never the aggregate."""
    data = _data()
    srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data,
                       scheduler_policy=policy)
    srv.run(3)
    flat, _ = run_flat_reference(
        PARAMS0, make_algorithm("fedavg", GRAD_FN, 0.1), data,
        clients_per_round=10, n_rounds=3, seed=7)
    assert _max_diff(flat, srv.params) < 1e-5


def test_stateful_with_tiny_memory_budget_spills_to_disk():
    """SCAFFOLD with a state-manager budget so small every state spills;
    results must be identical to the unbounded run (paper §3.4)."""
    data = _data()
    srv_small = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), data,
                             budget=1024)     # forces spill every save
    srv_small.run(4)
    srv_big = _make_server(make_algorithm("scaffold", GRAD_FN, 0.1), data,
                           budget=1 << 30)
    srv_big.run(4)
    assert _max_diff(srv_small.params, srv_big.params) < 1e-5
    sm = next(iter(srv_small.executors.values())).state_manager
    assert sm.stats["spills"] > 0 and sm.stats["loads"] > 0


def test_executor_failure_recovers_and_shrinks_K():
    """An executor dying mid-round: its remaining tasks re-run on survivors,
    K shrinks, and the round result equals the no-failure run."""
    data = _data()
    algo = make_algorithm("fedavg", GRAD_FN, 0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm) for k in range(4)]
    execs[2].fail_at = (1, 1)   # dies at round 1, task index 1
    # warmup_rounds=2 keeps round 1 on the deterministic uniform split, so
    # executor 2 is guaranteed >= 2 tasks and the injection always fires
    # (the LPT schedule depends on measured wall times and can starve it)
    srv = ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                       data_by_client=data, clients_per_round=10, seed=7,
                       warmup_rounds=2)
    srv.run(3)
    assert srv.history[1].failures == 1
    assert srv.history[2].n_executors == 3
    ref_srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data)
    ref_srv.run(3)
    assert _max_diff(srv.params, ref_srv.params) < 1e-5


def test_compression_int8_stays_close():
    from repro.core.compression import make_compressor
    data = _data()
    srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data,
                       compressor=make_compressor("int8"))
    srv.run(3)
    ref_srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data)
    ref_srv.run(3)
    # int8 is lossy but must stay in the same neighbourhood
    assert _max_diff(srv.params, ref_srv.params) < 0.05


def test_hierarchical_comm_is_O_K_not_O_Mp():
    """Table 1: comm trips O(K); broadcast K + K partials, not 2·M_p."""
    data = _data()
    srv = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data, K=4)
    m = srv.run_round()
    assert m.comm_trips == 2 * 4          # K broadcasts + K partials
    assert m.n_clients == 10              # M_p > K


def test_checkpoint_restore_resumes_identically():
    from repro.checkpoint import CheckpointManager, restore_latest
    data = _data()
    with tempfile.TemporaryDirectory() as d:
        algo = make_algorithm("scaffold", GRAD_FN, 0.1)
        srv = _make_server(algo, data, state_dir=d + "/state",
                           checkpoint_manager=CheckpointManager(
                               d + "/ckpt", keep=10))
        srv.run(3)
        params_after_3 = srv.params
        srv.run(2)          # rounds 3,4
        final = srv.params

        algo2 = make_algorithm("scaffold", GRAD_FN, 0.1)
        srv2 = _make_server(algo2, data, state_dir=d + "/state2")
        restored = restore_latest(srv2, d + "/ckpt")
        assert restored == 5
        step3 = os.path.join(d + "/ckpt", "step_00000003")
        assert os.path.isdir(step3)
        CheckpointManager(d + "/ckpt").restore(srv2, step3)
        assert _max_diff(srv2.params, params_after_3) < 1e-6
        srv2.run(2)
        assert _max_diff(srv2.params, final) < 1e-5


def test_torn_checkpoint_is_skipped_on_restore():
    from repro.checkpoint import CheckpointManager, restore_latest
    data = _data()
    with tempfile.TemporaryDirectory() as d:
        algo = make_algorithm("fedavg", GRAD_FN, 0.1)
        srv = _make_server(algo, data,
                           checkpoint_manager=CheckpointManager(
                               d + "/ckpt", keep=10))
        srv.run(2)
        # fabricate a torn (manifest-less) newer checkpoint
        torn = os.path.join(d + "/ckpt", "step_00000099")
        os.makedirs(torn)
        with open(os.path.join(torn, "server.pkl"), "wb") as f:
            f.write(b"garbage")
        with open(os.path.join(d + "/ckpt", "LATEST"), "w") as f:
            f.write("step_00000099")
        srv2 = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data)
        restored = restore_latest(srv2, d + "/ckpt")
        assert restored == 2      # fell back to the newest complete one


def test_overlap_scheduling_matches_non_overlapped():
    data = _data()
    srv_a = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data,
                         overlap_scheduling=True)
    srv_a.run(4)
    srv_b = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data,
                         overlap_scheduling=False)
    srv_b.run(4)
    assert _max_diff(srv_a.params, srv_b.params) < 1e-6


def test_parallel_dispatch_matches_serial():
    data = _data()
    srv_a = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data,
                         parallel_dispatch=True)
    srv_a.run(3)
    srv_b = _make_server(make_algorithm("fedavg", GRAD_FN, 0.1), data)
    srv_b.run(3)
    assert _max_diff(srv_a.params, srv_b.params) < 1e-5


def test_scheduling_reduces_makespan_under_heterogeneity():
    """The paper's headline claim (Figs. 5/9): with heterogeneous devices,
    Parrot scheduling beats naive round-robin placement."""
    from repro.core.executor import hetero_gpus
    data = _data(n=60, seed=3)
    ratios = {0: 0.0, 1: 0.0, 2: 3.0, 3: 3.0}   # two slow executors

    def run(policy):
        algo = make_algorithm("fedavg", GRAD_FN, 0.1)
        sm = ClientStateManager(tempfile.mkdtemp())
        execs = [SequentialExecutor(k, algo, state_manager=sm,
                                    speed_model=hetero_gpus(ratios))
                 for k in range(4)]
        srv = ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                           data_by_client=data, clients_per_round=20,
                           scheduler_policy=policy, warmup_rounds=2, seed=7)
        ms = [srv.run_round().makespan for _ in range(8)]
        return sum(ms[3:]) / len(ms[3:])   # after estimator warm-up

    assert run("parrot") < run("none") * 0.95
