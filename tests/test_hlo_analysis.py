"""Unit tests for the trip-count-aware HLO parser (launch/hlo_analysis.py)
on hand-written HLO snippets with known answers."""
from repro.launch.hlo_analysis import Module, collective_stats, compute_stats

HLO = """\
HloModule jit_step, is_scheduled=true

%cond.1 (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %c = s32[] constant(12)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

%body.1 (p2: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %x = f32[8,16]{1,0} get-tuple-element(%p2), index=1
  %w = f32[16,16]{1,0} constant(0)
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add.1
  %i2 = s32[] get-tuple-element(%p2), index=0
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
}

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %i0 = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%i0, %arg)
  %w2 = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  %ag = f32[32,16]{1,0} all-gather(%arg), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w2), index=1
}
"""


def test_trip_count_and_flops():
    mod = Module(HLO)
    # dot: 2 * 8*16 * 16 = 4096 flops, x12 trips = 49152
    assert compute_stats(HLO)["flops_per_device"] == 4096 * 12


def test_collectives_trip_aware():
    stats = collective_stats(HLO)
    # all-reduce in the loop: 8*16*4 bytes * factor 2 * 12 trips
    ar = stats["bytes_by_kind"]["all-reduce"]
    assert ar == 8 * 16 * 4 * 2 * 12
    # all-gather outside: 32*16*4 bytes * 1
    ag = stats["bytes_by_kind"]["all-gather"]
    assert ag == 32 * 16 * 4
    assert stats["count_by_kind"]["all-reduce"] == 12
    assert stats["count_by_kind"]["all-gather"] == 1


def test_multipliers_nested():
    mod = Module(HLO)
    assert mod.mult["body.1"] == 12
    assert mod.mult["main"] == 1


def test_comment_stripping():
    hlo = HLO.replace("(s32[], f32[8,16])",
                      "(s32[], /*index=1*/f32[8,16])")
    assert compute_stats(hlo)["flops_per_device"] == 4096 * 12
