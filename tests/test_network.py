"""Trace-driven network & availability simulation tests (DESIGN.md §9).

Anchors:
  * comm-transparency — a free network (infinite bandwidth, zero latency)
    reproduces the comm-free engines' params AND makespans exactly for bsp
    and semi-sync (the fold order is preserved by construction);
  * seeded-trace determinism — same trace seed, same schedules, same
    makespans, same params, for all three engines;
  * makespan monotonicity — raising every client's bandwidth never
    increases the simulated makespan (uniform scheduling isolates the
    pricing from placement);
  * compression-network interaction — top-k strictly reduces the simulated
    makespan under a constrained uplink at equal rounds.

Plus unit coverage of the pricing/availability math, the trace layer, the
bandwidth-aware Eq. 4, dropout/idle fast-forward paths, and the two
compression satellites (nested dtype-aware wire accounting, jitted int8).
"""
import math
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ClientAvailability, ClientStateManager, LinkProfile,
                        NetworkModel, ParrotServer, SequentialExecutor,
                        TickTimer, make_algorithm)
from repro.core.network import FREE_LINK
from repro.core.scheduler import ClientTask
from repro.core.workload import WorkloadModel
from repro.data import (load_behavior_trace, load_capacity_trace,
                        save_behavior_trace, save_capacity_trace,
                        synthesize_behavior_trace, synthesize_capacity_trace)


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


def _data(n=40, seed=1):
    from repro.data import make_classification_clients
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _make_server(data, K=4, clients_per_round=10, speed=None, **kw):
    algo = make_algorithm("fedavg", GRAD_FN, lr=0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=speed or (lambda kk, r: 0.0),
                                timer=TickTimer(1.0))
             for k in range(K)]
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data,
                        clients_per_round=clients_per_round, seed=7, **kw)


def _params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


ENGINES = [("bsp", None),
           ("semi-sync", {"chunk_size": 2, "deadline_frac": 0.7}),
           ("async", {"chunk_size": 2})]


# ---------------------------------------------------------------------------
# pricing / availability units
# ---------------------------------------------------------------------------

def test_link_pricing_math():
    net = NetworkModel({0: LinkProfile(100.0, 1000.0, 0.5),
                        1: LinkProfile(50.0, 2000.0, 0.1)})
    # bottleneck: min bandwidth, max latency
    assert net.upload_time([0], 1000) == pytest.approx(0.5 + 10.0)
    assert net.upload_time([0, 1], 1000) == pytest.approx(0.5 + 20.0)
    assert net.download_time([0, 1], 1000) == pytest.approx(0.5 + 1.0)
    # unknown client -> default FREE_LINK: zero comm
    assert net.upload_time([99], 10**9) == 0.0
    assert net.upload_time([], 1000) == 0.0
    # per-client round trip (Eq. 4 addend)
    assert net.client_comm_time(1, 2000, 50) == pytest.approx(
        (0.1 + 1.0) + (0.1 + 1.0))


def test_network_scaled_is_elementwise():
    net = NetworkModel({0: LinkProfile(100.0, 200.0, 0.25)})
    s = net.scaled(4.0)
    assert s.link(0).uplink_bps == 400.0
    assert s.link(0).downlink_bps == 800.0
    assert s.link(0).latency_s == 0.25          # latency unchanged


def test_availability_windows_and_period():
    av = ClientAvailability({0: [(2.0, 5.0)], 1: [(0.0, 1.0), (6.0, 8.0)]},
                            period=10.0)
    assert not av.available(0, 1.0) and av.available(0, 2.0)
    assert av.available(0, 12.5)                # periodic fold
    assert av.remaining(0, 3.0) == pytest.approx(2.0)
    assert av.remaining(0, 5.0) == 0.0
    assert av.next_available(0, 0.0) == pytest.approx(2.0)
    assert av.next_available(1, 1.5) == pytest.approx(6.0)
    assert av.next_available(1, 9.0) == pytest.approx(10.0)  # wraps to 0.0
    # clients without an entry are unconstrained
    assert av.available(42, 1e9)
    assert av.remaining(42, 0.0) == math.inf


def test_availability_never_again_is_inf():
    av = ClientAvailability({0: [(0.0, 1.0)]}, period=None)
    assert av.next_available(0, 2.0) == math.inf
    assert av.remaining(0, 2.0) == 0.0


def test_availability_empty_windows_with_period():
    # a trace row with no active windows: never available, never crashes
    av = ClientAvailability({0: []}, period=10.0)
    assert not av.available(0, 3.0)
    assert av.remaining(0, 3.0) == 0.0
    assert av.next_available(0, 3.0) == math.inf


# ---------------------------------------------------------------------------
# trace layer
# ---------------------------------------------------------------------------

def test_capacity_trace_seeded_and_roundtrip(tmp_path):
    a = synthesize_capacity_trace(16, seed=3)
    b = synthesize_capacity_trace(16, seed=3)
    c = synthesize_capacity_trace(16, seed=4)
    assert a == b
    assert a != c
    for suffix in ("json", "csv"):
        p = str(tmp_path / f"cap.{suffix}")
        save_capacity_trace(p, a)
        assert load_capacity_trace(p) == a


def test_behavior_trace_seeded_and_roundtrip(tmp_path):
    a = synthesize_behavior_trace(8, seed=5, period_s=100.0)
    assert a == synthesize_behavior_trace(8, seed=5, period_s=100.0)
    p = str(tmp_path / "beh.json")
    save_behavior_trace(p, a)
    assert load_behavior_trace(p) == a
    av = ClientAvailability.from_trace(a)
    assert av.period == 100.0
    # every client has at least one active instant
    assert all(math.isfinite(av.next_available(r.client_id, 0.0)) for r in a)


def test_network_from_trace_units():
    rows = [dict(client_id=0, uplink_kbps=8.0, downlink_kbps=16.0,
                 latency_ms=250.0)]
    net = NetworkModel.from_trace(rows)
    l = net.link(0)
    assert l.uplink_bps == pytest.approx(1000.0)    # 8 kbps = 1000 B/s
    assert l.downlink_bps == pytest.approx(2000.0)
    assert l.latency_s == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# comm-transparency: free network == no network, bit-exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("comp", [None, "topk"])
@pytest.mark.parametrize("engine,opts", ENGINES[:2],
                         ids=["bsp", "semi-sync"])
def test_free_network_bit_exact(engine, opts, comp):
    """Infinite bandwidth + zero latency must reproduce the comm-free run
    exactly — including with a STATEFUL compressor: the network path
    compresses at dispatch time, the comm-free path at fold time, and only
    per-executor error-feedback residual streams make both identical (a
    hetero speed model makes the cross-executor orders actually differ)."""
    from repro.core.compression import make_compressor
    from repro.core.executor import hetero_gpus
    data = _data()
    speed = hetero_gpus({0: 0.0, 1: 0.5, 2: 1.0, 3: 3.0})

    def build(network=None):
        return _make_server(data, round_engine=engine, engine_opts=opts,
                            speed=speed, network=network,
                            compressor=make_compressor(comp or "none", 0.1))

    ref = build()
    net = build(NetworkModel.uniform(math.inf, math.inf, 0.0))
    ms_ref = [ref.run_round().makespan for _ in range(4)]
    ms_net = [net.run_round().makespan for _ in range(4)]
    assert ms_ref == ms_net
    _params_equal(ref.params, net.params)


def test_always_available_bit_exact():
    data = _data()
    ref = _make_server(data, round_engine="bsp")
    av = _make_server(data, round_engine="bsp",
                      availability=ClientAvailability.always())
    ms_ref = [ref.run_round().makespan for _ in range(4)]
    ms_av = [av.run_round().makespan for _ in range(4)]
    assert ms_ref == ms_av
    _params_equal(ref.params, av.params)


# ---------------------------------------------------------------------------
# seeded-trace determinism: same seed -> identical schedules & makespans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,opts", ENGINES,
                         ids=["bsp", "semi-sync", "async"])
def test_seeded_trace_determinism(engine, opts):
    data = _data()

    def run():
        srv = _make_server(
            data, round_engine=engine, engine_opts=opts,
            network=NetworkModel.from_trace(
                synthesize_capacity_trace(40, seed=7,
                                          median_uplink_kbps=200.0)),
            availability=ClientAvailability.diurnal(
                40, period_s=500.0, duty_mean=0.7, seed=9))
        hist = [srv.run_round() for _ in range(5)]
        return srv, [m.makespan for m in hist]

    s1, ms1 = run()
    s2, ms2 = run()
    assert ms1 == ms2
    _params_equal(s1.params, s2.params)
    assert [m.extra.get("dropped_clients", 0.0) for m in s1.history] == \
           [m.extra.get("dropped_clients", 0.0) for m in s2.history]


# ---------------------------------------------------------------------------
# monotonicity: more bandwidth never increases the makespan
# ---------------------------------------------------------------------------

def test_makespan_monotone_in_bandwidth():
    data = _data()
    base = NetworkModel.from_trace(
        synthesize_capacity_trace(40, seed=11, median_uplink_kbps=100.0))

    def run(net):
        # uniform scheduling: the assignment is independent of the network,
        # so every per-executor span is a sum/max of terms monotone in bw
        srv = _make_server(data, round_engine="bsp", network=net,
                           scheduler_policy="uniform")
        return [srv.run_round().makespan for _ in range(4)]

    slow = run(base)
    fast = run(base.scaled(2.0))
    fastest = run(base.scaled(100.0))
    for a, b in zip(fast, slow):
        assert a <= b + 1e-9
    for a, b in zip(fastest, fast):
        assert a <= b + 1e-9


# ---------------------------------------------------------------------------
# compression x network: top-k shrinks the wire, so it shrinks the round
# ---------------------------------------------------------------------------

def test_topk_reduces_makespan_under_constrained_uplink():
    from repro.core.compression import make_compressor
    data = _data()
    net = NetworkModel.uniform(uplink_bps=2_000.0, downlink_bps=1e8,
                               latency_s=0.0)

    def run(comp):
        srv = _make_server(data, round_engine="bsp", network=net,
                           scheduler_policy="uniform", compressor=comp)
        return [srv.run_round() for _ in range(4)]

    dense = run(None)
    topk = run(make_compressor("topk", 0.05))
    # equal rounds, strictly smaller wire -> strictly smaller makespan
    assert sum(m.makespan for m in topk) < sum(m.makespan for m in dense)
    assert (sum(m.extra["comm_wire_bytes"] for m in topk)
            < sum(m.extra["comm_wire_bytes"] for m in dense))
    # and the upload leg is what shrank
    assert (sum(m.extra["comm_time_up"] for m in topk)
            < sum(m.extra["comm_time_up"] for m in dense))


def test_int8_reduces_makespan_under_constrained_uplink():
    from repro.core.compression import make_compressor
    data = _data()
    net = NetworkModel.uniform(uplink_bps=2_000.0, downlink_bps=1e8,
                               latency_s=0.0)

    def run(comp):
        srv = _make_server(data, round_engine="bsp", network=net,
                           scheduler_policy="uniform", compressor=comp)
        return sum(srv.run_round().makespan for _ in range(4))

    assert run(make_compressor("int8")) < run(None)


# ---------------------------------------------------------------------------
# availability: selection filter, dropout, idle fast-forward
# ---------------------------------------------------------------------------

def test_offline_clients_never_selected():
    data = _data()
    # clients 0..19 permanently offline, 20..39 always on
    av = ClientAvailability({c: [] for c in range(20)}, period=None)
    srv = _make_server(data, round_engine="bsp", availability=av)
    for _ in range(4):
        srv.run_round()
    ran = {r.client for recs in srv.estimator._records.values()
           for r in recs}
    assert ran and all(c >= 20 for c in ran)


def test_mid_chunk_expiry_drops_via_split_available():
    from repro.core.engine import _NetSim

    class _Srv:   # minimal server stub for the pricer
        network = None
        availability = ClientAvailability({0: [(0.0, 5.0)]}, period=None)
        _last_payload_nbytes = 0
        _wire_ratio = 1.0

    sim = _NetSim(_Srv(), t0=0.0)
    tasks = [ClientTask(0, 10), ClientTask(1, 10)]
    # at t=4 client 0 has 1s left; a 3s chunk is predicted to outlive it
    kept, dropped = sim.split_available(tasks, start_local=4.0, pred_dur=3.0)
    assert [t.client for t in kept] == [1]
    assert [t.client for t in dropped] == [0]
    assert sim.dropped == 1
    # a 0.5s chunk fits the remaining window
    kept, dropped = sim.split_available(tasks, start_local=4.0, pred_dur=0.5)
    assert [t.client for t in kept] == [0, 1]


def test_semi_sync_dropout_carries_offline_clients():
    data = _data()
    # everyone shares one absolute window that closes early: once virtual
    # time passes it, dispatches drop and the round fast-forwards
    av = ClientAvailability({c: [(0.0, 1e9)] for c in range(40)},
                            period=None)
    srv = _make_server(data, round_engine="semi-sync",
                       engine_opts={"chunk_size": 2}, availability=av)
    m = srv.run_round()
    assert m.n_clients > 0
    assert m.extra["dropped_clients"] == 0.0


def test_idle_fast_forward_when_everyone_offline():
    data = _data()
    av = ClientAvailability({c: [(100.0, 1e9)] for c in range(40)},
                            period=None)
    srv = _make_server(data, round_engine="bsp", availability=av)
    m = srv.run_round()
    assert m.extra["idle_time"] == pytest.approx(100.0)
    assert srv.virtual_now >= 100.0
    assert m.n_clients > 0                      # the round ran after the jump


def test_overlap_scheduling_survives_availability_gap():
    """overlap_scheduling pre-builds next round's schedule; when that
    cohort was empty (everyone offline at round end), the fast-forwarded
    round must schedule its reselected clients fresh instead of using the
    stale empty schedule."""
    data = _data()
    # online only for the first 5s of every 100s period: round 0 runs at
    # t=0, its overlap selection lands mid-gap (TickTimer makespans >> 5),
    # so round 1 must jump to t=100 and re-schedule
    av = ClientAvailability({c: [(0.0, 5.0)] for c in range(40)},
                            period=100.0)
    srv = _make_server(data, round_engine="bsp", availability=av,
                       overlap_scheduling=True)
    hist = [srv.run_round() for _ in range(6)]
    gaps = [m for m in hist if m.extra.get("idle_time", 0.0) > 0]
    assert gaps                                 # the window gap was hit
    for m in gaps:
        assert m.n_clients > 0
        assert m.makespan > 0                   # the new cohort really ran


def test_semi_sync_fast_forward_excludes_carry():
    """An offline carried client whose window opens at the jump target must
    not be selected fresh on top of its pending carried task."""
    from repro.core.engine import SemiSyncEngine
    data = _data(n=12)
    av = ClientAvailability({c: [(10.0, 1e9)] for c in range(12)},
                            period=None)
    srv = _make_server(data, K=2, clients_per_round=10,
                       round_engine="semi-sync",
                       engine_opts={"chunk_size": 2}, availability=av)
    srv.engine._carry = [ClientTask(0, data[0].n_samples)]
    m = srv.run_round()
    # 11 fresh clients folded; client 0 stayed in the carry pool, once
    assert m.extra["landed_clients"] == 11.0
    assert [t.client for t in srv.engine._carry] == [0]


def test_expiry_drops_advance_virtual_time():
    """Windows far shorter than the post-warmup predicted spans: every
    dispatch drops its clients, but virtual time must still jump past an
    availability boundary each round (no verbatim-repeat livelock)."""
    data = _data()
    av = ClientAvailability({c: [(0.0, 2.0)] for c in range(40)},
                            period=50.0)
    srv = _make_server(data, round_engine="bsp", availability=av)
    trace = []
    for _ in range(5):
        srv.run_round()
        trace.append(srv.virtual_now)
    assert all(b > a for a, b in zip(trace, trace[1:]))
    # at least one round actually hit the dropout path
    assert sum(m.extra.get("dropped_clients", 0.0)
               for m in srv.history) > 0


def test_async_short_windows_limp_forward_not_hang():
    """Short periodic windows: most dispatches drop, but whatever fits a
    window folds, rounds return, and virtual time advances one period per
    wake — no nanosecond-spin, no verbatim repeats."""
    data = _data()
    av = ClientAvailability({c: [(0.0, 2.0)] for c in range(40)},
                            period=50.0)
    srv = _make_server(data, round_engine="async",
                       engine_opts={"chunk_size": 2}, availability=av)
    trace = []
    for _ in range(6):
        m = srv.run_round()
        trace.append(srv.virtual_now)
        assert m.n_clients > 0                  # something always folds
    assert all(b > a for a, b in zip(trace, trace[1:]))


def test_async_impossible_windows_raise_not_spin():
    """Truly degenerate: every window is predicted too short for ANY chunk
    (pinned pessimistic models, uniform policy so they never refit).
    run_round must raise after bounded boundary-jumps instead of spinning
    across window boundaries forever."""
    from repro.core.workload import WorkloadModel
    data = _data()
    av = ClientAvailability({c: [(0.0, 2.0)] for c in range(40)},
                            period=50.0)
    srv = _make_server(data, round_engine="async",
                       engine_opts={"chunk_size": 2}, availability=av,
                       scheduler_policy="uniform")
    srv.run_round()                             # warmup: no models, runs
    srv.estimator.last_fit = {k: WorkloadModel(t_sample=10.0, b=100.0)
                              for k in srv.executors}
    with pytest.raises(RuntimeError, match="starved"):
        for _ in range(8):
            srv.run_round()


def test_async_wakes_after_availability_gap():
    data = _data()
    av = ClientAvailability({c: [(50.0, 1e9)] for c in range(40)},
                            period=None)
    srv = _make_server(data, round_engine="async",
                       engine_opts={"chunk_size": 2}, availability=av)
    m = srv.run_round()
    assert m.n_clients > 0
    assert srv.virtual_now >= 50.0              # slept until clients joined


# ---------------------------------------------------------------------------
# bandwidth-aware Eq. 4
# ---------------------------------------------------------------------------

def test_schedule_comm_cost_shifts_load():
    from repro.core import ParrotScheduler, WorkloadEstimator
    from repro.core.workload import RunRecord
    est = WorkloadEstimator()
    for k in (0, 1):
        for i, n in enumerate((50, 100, 150)):
            est.record(RunRecord(round=0, client=i, executor=k,
                                 n_samples=n, time=float(n)))
    sched = ParrotScheduler(est, warmup_rounds=1)
    tasks = [ClientTask(0, 100), ClientTask(1, 99), ClientTask(2, 98),
             ClientTask(3, 97)]
    plain = sched.schedule(1, tasks, [0, 1])
    priced = sched.schedule(1, tasks, [0, 1],
                            comm_cost=lambda t: 200.0 if t.client == 0
                            else 0.0)
    # comm-free: LPT balances 2/2; with client 0's slow link its executor
    # fills up and the remaining tasks route around it
    assert sorted(len(plain.queue(k)) for k in (0, 1)) == [2, 2]
    heavy = next(k for k in (0, 1)
                 if any(t.client == 0 for t in priced.queue(k)))
    assert len(priced.queue(heavy)) == 1
    assert priced.predicted_makespan > plain.predicted_makespan


def test_predict_span_adds_comm():
    from repro.core.scheduler import predict_span
    m = WorkloadModel(t_sample=1.0, b=2.0)
    tasks = [ClientTask(0, 10), ClientTask(1, 5)]
    assert predict_span(m, tasks) == pytest.approx(17.0)
    assert predict_span(m, tasks, comm=lambda cs: 4.0) == pytest.approx(21.0)
    # warmup stays optimistic even with comm priced
    assert predict_span(None, tasks, comm=lambda cs: 4.0) == 0.0


# ---------------------------------------------------------------------------
# compression satellites
# ---------------------------------------------------------------------------

def test_nested_wire_bytes_respects_dtype():
    from repro.core.compression import _wire_bytes
    sums = {"delta": {"w": jnp.zeros((100,), jnp.bfloat16)},
            "tau": {"w": jnp.zeros((10,), jnp.float32)}}
    assert _wire_bytes(sums) == 100 * 2 + 10 * 4


def test_int8_jit_matches_eager_reference():
    from repro.core.compression import Int8Compressor
    rng = np.random.default_rng(0)
    a = rng.normal(size=(513,)).astype(np.float32)
    comp = Int8Compressor()
    c = comp._compress_array(a)
    assert np.asarray(c.data["q"]).dtype == np.int8
    # eager reference (the pre-jit implementation)
    scale = max(float(np.max(np.abs(a))) / 127.0, 1e-12)
    q_ref = np.clip(np.round(a / scale), -127, 127).astype(np.int8)
    np.testing.assert_allclose(np.asarray(c.data["q"]), q_ref, atol=1)
    back = np.asarray(comp._decompress_array(c))
    assert np.abs(a - back).max() <= np.abs(a).max() / 127.0 + 1e-6


def test_int8_empty_segment():
    from repro.core.compression import Int8Compressor
    comp = Int8Compressor()
    c = comp._compress_array(np.zeros((0,), np.float32))
    assert np.asarray(comp._decompress_array(c)).shape == (0,)


# ---------------------------------------------------------------------------
# async engine: comm delay feeds staleness; checkpoint carries CommEvents
# ---------------------------------------------------------------------------

def test_async_comm_delay_increases_staleness():
    data = _data()

    def mean_staleness(net):
        srv = _make_server(data, round_engine="async",
                           engine_opts={"chunk_size": 2}, network=net)
        hist = [srv.run_round() for _ in range(6)]
        return float(np.mean([m.extra["mean_staleness"] for m in hist[2:]]))

    slow = mean_staleness(NetworkModel.uniform(500.0, 1e8, 0.0))
    fast = mean_staleness(NetworkModel.uniform(1e9, 1e9, 0.0))
    assert slow >= fast


@pytest.mark.parametrize("engine,opts", ENGINES,
                         ids=["bsp", "semi-sync", "async"])
def test_resume_with_network_is_bit_exact(engine, opts, tmp_path):
    """Checkpoint at round 2 under a bandwidth trace + diurnal churn,
    restore into a fresh server, run on: params and makespans must match
    the uninterrupted run (the network anchors — virtual_now, payload
    size, wire ratio — ride the checkpoint blob)."""
    import os
    from repro.checkpoint.manager import CheckpointManager
    data = _data()
    net = NetworkModel.from_trace(
        synthesize_capacity_trace(40, seed=21, median_uplink_kbps=300.0))
    av = ClientAvailability.diurnal(40, period_s=400.0, duty_mean=0.8,
                                    seed=22)

    def build(ckpt_dir=None):
        srv = _make_server(data, round_engine=engine, engine_opts=opts,
                           network=net, availability=av)
        if ckpt_dir:
            srv.checkpoint_manager = CheckpointManager(ckpt_dir,
                                                       every_rounds=1,
                                                       keep=10)
        return srv

    d = str(tmp_path / "ck")
    a = build(d)
    for _ in range(5):
        a.run_round()
    b = build()
    CheckpointManager(d).restore(b, os.path.join(d, "step_%08d" % 2))
    assert b.round == 2
    assert b.virtual_now > 0.0                  # anchor restored, not reset
    for _ in range(3):
        b.run_round()
    _params_equal(a.params, b.params)
    assert [m.makespan for m in a.history[2:]] == \
        [m.makespan for m in b.history[2:]]


def test_async_state_dict_roundtrips_inflight_comm():
    import pickle
    from repro.core.engine import AsyncEngine
    data = _data()
    net = NetworkModel.uniform(2_000.0, 1e8, 0.01)
    srv = _make_server(data, round_engine="async",
                       engine_opts={"chunk_size": 2}, network=net)
    srv.run_round()
    state = srv.engine.state_dict()
    kinds = {e[2] for e in state["clock"]["events"]}
    assert state["initialized"]
    assert "chunk_arrived" in kinds             # an upload is in flight
    assert kinds <= {"chunk_done", "chunk_arrived", "wake",
                     "executor_failed"}
    # the checkpoint manager pickles the blob: in-flight CommEvents must
    # survive the round-trip into a fresh engine
    state = pickle.loads(pickle.dumps(state))
    eng = AsyncEngine(chunk_size=2)
    eng.load_state_dict(state)
    assert len(eng._clock) == len(srv.engine._clock)
