"""Model substrate unit tests: attention impl equivalence, SSD chunked vs
sequential, MoE dispatch impls, xLSTM mixers, cache ring-buffer semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.configs.registry import ARCHS
from repro.models import attention, lm, moe, ssm, transformer


def test_chunked_attention_equals_dense():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (2, 128, 4, 32)) for kk in ks)
    for window in (0, 48):
        a = attention.dense_attention(q, k, v, causal=True, window=window)
        b = attention.chunked_attention(q, k, v, causal=True, window=window,
                                        chunk=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


def test_chunked_attention_chunk_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (1, 256, 2, 16)) for kk in ks)
    outs = [attention.chunked_attention(q, k, v, causal=True, chunk=c)
            for c in (32, 64, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   atol=1e-5, rtol=1e-4)


def test_ssd_chunked_equals_sequential_scan():
    B, S, H, N, P = 2, 128, 3, 8, 16
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (B, S, H, N))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, N)) * 0.3
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, P))
    la = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 3),
                                            (B, S, H)))
    h0 = jnp.zeros((B, H, N, P))
    y_c, h_c = ssm.chunked_linear_scan(q, k, v, la, h0, chunk=32)
    y_s, h_s = ssm.sequential_linear_scan(q, k, v, la, h0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s), atol=1e-4,
                               rtol=1e-3)


def test_mamba_streaming_decode_equals_full():
    """Step-by-step mamba (conv state + h carry) == one full pass."""
    cfg = ARCHS["hymba-1.5b"].reduced()
    key = jax.random.PRNGKey(3)
    params = ssm.mamba_init(key, cfg)
    B, S = 2, 24
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    y_full, _ = ssm.mamba_apply(params, x, cfg)
    st = ssm.mamba_init_state(cfg, B, x.dtype)
    ys = []
    for t in range(S):
        y_t, st = ssm.mamba_step(params, x[:, t:t + 1], st, cfg)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               atol=1e-4, rtol=1e-3)


def test_slstm_streaming_equals_full():
    cfg = ARCHS["xlstm-125m"].reduced()
    key = jax.random.PRNGKey(4)
    params = ssm.slstm_init(key, cfg)
    B, S = 2, 16
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    y_full, _ = ssm.slstm_apply(params, x, cfg)
    st = ssm.slstm_init_state(cfg, B, x.dtype)
    ys = []
    for t in range(S):
        y_t, st = ssm.slstm_step(params, x[:, t:t + 1], st, cfg)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(ys, axis=1)),
                               atol=1e-4, rtol=1e-3)


def test_moe_dispatch_impls_agree_when_dropless():
    cfg = ARCHS["grok-1-314b"].reduced()   # capacity_factor=4 -> dropless
    key = jax.random.PRNGKey(5)
    params = moe.moe_init(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, cfg.d_model))
    cfg_g = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, dispatch_impl="gather"))
    out_e, aux_e = moe.moe_ffn(params, x, cfg)
    out_g, aux_g = moe.moe_ffn(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               atol=1e-4, rtol=1e-3)
    assert abs(float(aux_e) - float(aux_g)) < 1e-5


def test_moe_capacity_drops_tokens_deterministically():
    cfg = ARCHS["grok-1-314b"].reduced()
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    key = jax.random.PRNGKey(6)
    params = moe.moe_init(key, tight)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 64, tight.d_model))
    o1, _ = moe.moe_ffn(params, x, tight)
    o2, _ = moe.moe_ffn(params, x, tight)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))
    # some tokens dropped -> some outputs exactly zero
    row_norms = np.linalg.norm(np.asarray(o1), axis=-1).reshape(-1)
    assert (row_norms < 1e-7).any()


def test_sliding_window_cache_ring_wraps():
    """Decode past the window: old positions are overwritten and masked."""
    cfg = ARCHS["hymba-1.5b"].reduced()   # window 32
    key = jax.random.PRNGKey(7)
    params = lm.init_params(key, cfg)
    B, S = 1, 48                          # > window 32
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    h, _, _ = lm.forward(params, toks, cfg)
    full = lm._head(params, h, cfg)
    prefill = jax.jit(lm.make_prefill_step(cfg, B, S, cache_len=S + 1))
    _, caches = prefill(params, toks[:, :S])
    decode = jax.jit(lm.make_decode_step(cfg))
    logits_d, _ = decode(params, toks[:, S:S + 1], caches, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_d[:, 0]),
                               np.asarray(full[:, S]), atol=2e-4, rtol=1e-3)


def test_scan_layers_equals_unrolled():
    cfg = ARCHS["phi3-mini-3.8b"].reduced()
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    key = jax.random.PRNGKey(8)
    params = lm.init_params(key, cfg)
    x = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    h_s, _, _ = lm.forward(params, x, cfg)
    h_u, _, _ = lm.forward(params, x, cfg_u)
    np.testing.assert_allclose(np.asarray(h_s), np.asarray(h_u), atol=1e-5,
                               rtol=1e-4)


def test_remat_does_not_change_gradients():
    cfg = ARCHS["qwen2-0.5b"].reduced()
    cfg_r = dataclasses.replace(cfg, remat=True)
    key = jax.random.PRNGKey(9)
    params = lm.init_params(key, cfg)
    batch = {"inputs": jax.random.randint(key, (2, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    g1 = jax.grad(lm.loss_and_aux)(params, batch, cfg)
    g2 = jax.grad(lm.loss_and_aux)(params, batch, cfg_r)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


def test_rope_positions_shift_consistency():
    """RoPE is relative: logits for the same suffix shift with cache pos."""
    from repro.models.layers import apply_rope
    key = jax.random.PRNGKey(10)
    x = jax.random.normal(key, (1, 4, 2, 16))
    r0 = apply_rope(x, jnp.arange(4), 10000.0)
    r5 = apply_rope(x, jnp.arange(4) + 5, 10000.0)
    # dot products between rotated pairs depend only on position delta
    d0 = jnp.einsum("bshd,bthd->st", r0, r0)
    d5 = jnp.einsum("bshd,bthd->st", r5, r5)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d5), atol=1e-4)
