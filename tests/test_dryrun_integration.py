"""Dry-run integration: lower+compile in a SUBPROCESS with forced host
devices (the test process must keep seeing 1 device), on a small mesh with
small-but-structured configs, exercising the whole launch path including the
HLO analysis."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import jax
from repro.configs.registry import get_arch
from repro.configs.base import shape_by_name, ShapeConfig
from repro.launch.inputs import input_specs
from repro.launch.mesh import use_mesh
from repro.sharding import enable_activation_policy
from repro.launch.hlo_analysis import collective_stats, compute_stats, cost_dict

arch, kind = sys.argv[1], sys.argv[2]
cfg = get_arch(arch)
cfg = dataclasses.replace(cfg.reduced(), n_layers=4, d_model=128, d_ff=256,
                          n_heads=4, n_kv_heads=2, head_dim=32,
                          vocab_size=512, dtype="bfloat16", remat=True,
                          logit_chunk=0)
if cfg.xlstm is not None:
    cfg = dataclasses.replace(cfg, d_ff=0)
shape = {"train": ShapeConfig("t", 128, 8, "train"),
         "prefill": ShapeConfig("p", 128, 8, "prefill"),
         "decode": ShapeConfig("d", 128, 8, "decode")}[kind]
mesh = jax.make_mesh((4, 2), ("data", "model"))
enable_activation_policy(mesh)
spec = input_specs(cfg, shape, mesh)
with use_mesh(mesh):
    lowered = jax.jit(spec.step_fn, in_shardings=spec.in_shardings,
                      donate_argnums=spec.donate_argnums).lower(*spec.args)
    compiled = lowered.compile()
hlo = compiled.as_text()
out = {
    "mem": int(compiled.memory_analysis().temp_size_in_bytes),
    "coll": collective_stats(hlo)["total_bytes_per_device"],
    "comp": compute_stats(hlo),
    "xla_flops": cost_dict(compiled).get("flops", 0.0),
}
print("RESULT" + json.dumps(out))
"""


def _run(arch, kind):
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", SCRIPT, arch, kind],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind", [
    ("qwen2-0.5b", "train"),
    ("grok-1-314b", "train"),
    ("hymba-1.5b", "decode"),
    ("xlstm-125m", "prefill"),
])
def test_small_mesh_dryrun_cell(arch, kind):
    out = _run(arch, kind)
    assert out["mem"] > 0
    # trip-count-aware flops must exceed raw XLA (scan bodies counted once)
    if kind == "train":
        assert out["comp"]["flops_per_device"] > out["xla_flops"] * 1.5
    assert out["comp"]["flops_per_device"] > 0


@pytest.mark.slow
def test_trip_count_extraction_matches_layer_count():
    """The n_layers=4 scan must multiply collective/flop counts by ~4: check
    the analysis sees a x4 between 4-layer and 8-layer variants."""
    env = dict(os.environ, PYTHONPATH=SRC)
    script = SCRIPT.replace("n_layers=4", "n_layers=8")
    r = subprocess.run([sys.executable, "-c", script, "qwen2-0.5b", "train"],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT")][-1]
    out8 = json.loads(line[len("RESULT"):])
    out4 = _run("qwen2-0.5b", "train")
    ratio = out8["comp"]["flops_per_device"] / out4["comp"]["flops_per_device"]
    assert 1.5 < ratio < 2.6, ratio   # ~2x flops for 2x layers
