"""Subprocess driver for the K-device parity tests.

Device count is fixed at process start (XLA reads
``--xla_force_host_platform_device_count`` once), so the multi-device
scenarios run in a child process that sets ``XLA_FLAGS`` before importing
jax.  This module IS that child: it builds identical workloads, runs them
single-device (no placement) and device-parallel (one executor per virtual
device), and prints a JSON verdict for ``test_device_parallel.py``.

Run directly for debugging:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        python tests/device_parity_driver.py
"""
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientData, ClientStateManager, ControlPlane,
                        DevicePlacement, ParrotServer, SequentialExecutor,
                        TickTimer, make_algorithm)
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    x = batch["x"]
    h = jax.nn.relu(x @ params["w0"] + params["b0"])
    logits = h @ params["w1"] + params["b1"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))


def mlp_params(dim=16, hidden=32, classes=10, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
            "b0": jnp.zeros((hidden,)),
            "w1": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
            "b1": jnp.zeros((classes,))}


def build(engine, opts, *, K=4, devices=None, algorithm="fedavg",
          fail_at=None, fail_on=None):
    data = make_classification_clients(
        24, dim=16, n_classes=10, partition="natural", partition_arg=5.0,
        mean_samples=40, batch_size=20, seed=0)
    algo = make_algorithm(algorithm, GRAD_FN, 0.05, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="devpar_"))
    timer = TickTimer()
    execs = [SequentialExecutor(
        k, algo, state_manager=sm, timer=timer,
        device=None if devices is None else devices[k % len(devices)],
        fail_at=fail_at if k == fail_on else None)
        for k in range(K)]
    return ParrotServer(params=mlp_params(), algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=8,
                        round_engine=engine, engine_opts=opts, seed=0)


def params_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def run_pair(engine, opts, rounds=4, **kw):
    """(single-device run, K-device run) of the same workload."""
    a = build(engine, opts, **kw)
    b = build(engine, opts, devices=jax.devices(), **kw)
    hist_a = [a.run_round() for _ in range(rounds)]
    hist_b = [b.run_round() for _ in range(rounds)]
    return a, b, hist_a, hist_b


def main() -> None:
    out = {"n_devices": len(jax.devices())}

    # -- bit-exact parity, all three engines, K == device count -----------
    for engine, opts in [("bsp", None),
                         ("semi-sync", {"chunk_size": 2}),
                         ("async", {"chunk_size": 2})]:
        a, b, ha, hb = run_pair(engine, opts)
        out[f"parity/{engine}/params"] = params_equal(a.params, b.params)
        out[f"parity/{engine}/makespans"] = \
            [m.makespan for m in ha] == [m.makespan for m in hb]

    # stateful algorithm: client states live device-resident on the pinned
    # executors (keep_device save path) yet must round-trip identically
    a, b, _, _ = run_pair("bsp", None, algorithm="scaffold")
    out["parity/scaffold/params"] = params_equal(a.params, b.params)

    # end-to-end shard_map/psum fold: force the sharded reduction at this
    # (small) model size — it must stay bit-identical to the single-device
    # host left-fold all the way through the round loop
    a = build("bsp", None)
    b = build("bsp", None, devices=jax.devices())
    b.placement.psum_min_elements = 0
    for _ in range(4):
        a.run_round()
        b.run_round()
    out["parity/psum_fold/params"] = params_equal(a.params, b.params)

    # K > device count: executors share devices, the fold takes the
    # colocating path — still bit-exact
    a, b, _, _ = run_pair("bsp", None, K=2 * len(jax.devices()))
    out["parity/oversubscribed/params"] = params_equal(a.params, b.params)

    # -- control plane: DES gang waves on == off, bit-exact ---------------
    # equal-sized clients so every head chunk plans into one aligned block
    # wave (run_queues_ganged's homogeneity gate); under the shared
    # TickTimer every measured span equals dt regardless of interleaving,
    # so ganged reports — and therefore params AND makespans — must be
    # bit-identical to the serial dispatch
    rng = np.random.default_rng(0)
    udata = {}
    for c in range(24):
        ys = rng.integers(0, 10, size=40).astype(np.int32)
        xs = rng.normal(size=(40, 16)).astype(np.float32)
        udata[c] = ClientData(
            batches=[{"x": xs[i:i + 20], "y": ys[i:i + 20]}
                     for i in range(0, 40, 20)], n_samples=40)

    def gang_build(engine, opts, control):
        algo = make_algorithm("fedavg", GRAD_FN, 0.05, local_epochs=1)
        sm = ClientStateManager(tempfile.mkdtemp(prefix="gang_"))
        timer = TickTimer()
        execs = [SequentialExecutor(k, algo, state_manager=sm, timer=timer,
                                    device=jax.devices()[k])
                 for k in range(4)]
        return ParrotServer(params=mlp_params(), algorithm=algo,
                            executors=execs, data_by_client=udata,
                            clients_per_round=8, round_engine=engine,
                            engine_opts=opts, control=control, seed=0)

    def count_calls(srv, box):
        for ex in srv.executors.values():
            real = ex.run_queue

            def counting(*a, _real=real, **kw):
                box[0] += 1
                return _real(*a, **kw)

            ex.run_queue = counting

    for engine in ("semi-sync", "async"):
        a = gang_build(engine, {"chunk_size": 2}, ControlPlane.observer())
        b = gang_build(engine, {"chunk_size": 2},
                       ControlPlane(gang_waves=True))
        ca, cb = [0], [0]
        count_calls(a, ca)
        count_calls(b, cb)
        ha = [a.run_round() for _ in range(4)]
        hb = [b.run_round() for _ in range(4)]
        out[f"control/gang/{engine}/params"] = params_equal(a.params,
                                                            b.params)
        out[f"control/gang/{engine}/makespans"] = \
            [m.makespan for m in ha] == [m.makespan for m in hb]
        # the gang actually fired: ganged head chunks bypass run_queue
        out[f"control/gang/{engine}/fired"] = cb[0] < ca[0]

    # -- executor failure: dead pin released, survivors re-home ----------
    a, b, _, hb = run_pair("bsp", None, fail_at=(1, 0), fail_on=2, rounds=3)
    out["failure/params"] = params_equal(a.params, b.params)
    out["failure/k_shrank"] = (hb[-1].n_executors == 3
                               and b.placement is not None
                               and 2 not in b.placement.executors())

    # -- device failure: executors on the dead device re-pin and the run
    # continues bit-identically (placement is transparent to scheduling)
    ref = build("bsp", None, devices=jax.devices())
    for _ in range(2):
        ref.run_round()
    vic = build("bsp", None, devices=jax.devices())
    vic.run_round()
    dead = vic.placement.device(2)
    moved = vic.placement.fail_device(dead)
    vic.placement.assign([vic.executors[k] for k in moved])
    vic.run_round()
    out["device_failure/moved"] = moved == [2]
    out["device_failure/repinned_live"] = \
        vic.executors[2].device.id != dead.id
    out["device_failure/params"] = params_equal(ref.params, vic.params)

    print("RESULT " + json.dumps(out))


if __name__ == "__main__":
    main()
