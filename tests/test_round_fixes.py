"""Round-engine regressions: the compression round-trip (aggregate what was
actually sent over the wire) and speculative straggler backup tasks."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ClientStateManager, ParrotServer, SequentialExecutor,
                        make_algorithm)
from repro.data import make_classification_clients


def _loss_fn(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


GRAD_FN = jax.jit(jax.value_and_grad(_loss_fn))
PARAMS0 = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}


def _data(n=40, seed=1):
    return make_classification_clients(n, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=seed)


def _make_server(data, **kw):
    algo = make_algorithm("fedavg", GRAD_FN, 0.1)
    sm = ClientStateManager(tempfile.mkdtemp())
    execs = [SequentialExecutor(k, algo, state_manager=sm) for k in range(4)]
    return ParrotServer(params=PARAMS0, algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=10, seed=7,
                        **kw)


def _max_diff(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# compression round-trip: what is aggregated must be what crossed the wire
# ---------------------------------------------------------------------------

class _ZeroingCompressor:
    """Sentinel: the wire copy carries all-zero sums.  If aggregation sees
    the zeros, the global delta is zero and params cannot move; the old bug
    aggregated the executor-local (uncompressed) partial instead."""

    def compress_partial(self, partial, key=None):
        out = dict(partial)
        sums = partial["sums"]
        out["sums"] = {"__flat__": True,
                       "buffers": {g: b * 0.0
                                   for g, b in sums["buffers"].items()}}
        return out

    def decompress_partial(self, partial):
        return partial


class _ScalingCompressor:
    """Lossless round-trip marker: compress doubles, decompress halves.
    Params must land exactly where the uncompressed run lands — only true
    when decompress is applied to the received wire copy."""

    def compress_partial(self, partial, key=None):
        out = dict(partial)
        out["sums"] = {"__flat__": True,
                       "buffers": {g: b * 2.0
                                   for g, b in partial["sums"]["buffers"].items()}}
        return out

    def decompress_partial(self, partial):
        out = dict(partial)
        out["sums"] = {"__flat__": True,
                       "buffers": {g: b * 0.5
                                   for g, b in partial["sums"]["buffers"].items()}}
        return out


def test_compressed_values_reach_aggregation():
    data = _data()
    srv = _make_server(data, compressor=_ZeroingCompressor())
    srv.run_round()
    assert _max_diff(srv.params, PARAMS0) == 0.0


def test_round_trip_decompresses_the_wire_copy():
    data = _data()
    srv_c = _make_server(data, compressor=_ScalingCompressor())
    srv_c.run(2)
    srv = _make_server(data)
    srv.run(2)
    assert _max_diff(srv_c.params, srv.params) < 1e-7


def test_topk_error_feedback_stays_in_sync_with_wire():
    """With the fix, round r+1's transmitted values include round r's
    residual, so two rounds of fraction-1/2 top-k keep params close to the
    uncompressed run (error feedback delays, never loses, mass)."""
    from repro.core.compression import TopKCompressor
    data = _data()
    srv_c = _make_server(data, compressor=TopKCompressor(fraction=0.5))
    srv_c.run(3)
    assert srv_c.compressor._residual          # residuals actually accrued
    srv = _make_server(data)
    srv.run(3)
    # sparsified aggregation differs from dense but must stay in the same
    # neighbourhood thanks to error feedback
    diff = _max_diff(srv_c.params, srv.params)
    # residual streams are per-executor (keyed by the server), which moves
    # the sparsified trajectory slightly vs the old shared-residual runs
    assert 0.0 < diff < 0.08


# ---------------------------------------------------------------------------
# speculative backup tasks
# ---------------------------------------------------------------------------

def test_backup_tasks_duplicate_but_fold_once():
    data = _data()
    srv = _make_server(data, backup_fraction=0.5, warmup_rounds=1)
    for _ in range(3):
        m = srv.run_round()
        # every selected client folds exactly once despite the duplicates
        assert m.n_clients == 10
    assert any(m.extra.get("backup_tasks", 0) > 0 for m in srv.history)


def test_backup_tasks_do_not_change_the_model():
    data = _data()
    srv_b = _make_server(data, backup_fraction=0.5)
    srv_b.run(3)
    srv = _make_server(data, backup_fraction=0.0)
    srv.run(3)
    assert _max_diff(srv_b.params, srv.params) < 1e-5
    assert all(m.extra.get("backup_tasks", 0) == 0 for m in srv.history)


def test_backup_default_off():
    data = _data()
    srv = _make_server(data)
    srv.run_round()
    assert srv.history[0].extra["backup_tasks"] == 0.0


def test_backup_survives_slow_and_fast_both_failing():
    """The duplicated tail lives in two queues; if both its executors die in
    the same round each tail client must still re-run (and fold) exactly
    once on the survivors."""
    data = _data()
    # replicate round 0's plan to learn which executors get the duplicates
    probe = _make_server(data, backup_fraction=1.0)
    tasks = probe.select_clients()
    sched = probe.scheduler.schedule(0, tasks, list(probe.executors))
    loads = {k: sum(t.n_samples for t in sched.queue(k))
             for k in probe.executors}
    slow = max(loads, key=loads.get)
    fast = min(loads, key=loads.get)

    srv = _make_server(data, backup_fraction=1.0)
    srv.executors[slow].fail_at = (0, 0)
    srv.executors[fast].fail_at = (0, 0)
    m = srv.run_round()
    assert m.failures == 2 and m.n_executors == 2
    ref = _make_server(data)
    ref.run_round()
    assert _max_diff(srv.params, ref.params) < 1e-5


def test_payload_bytes_counts_compressed_wire_partials():
    """CompressedTensor leaves (str dtype) count at their achieved nbytes —
    the CollectiveComm accounting path has no try/except around this."""
    from repro.core.aggregation import payload_bytes
    from repro.core.compression import TopKCompressor
    srv = _make_server(data := _data())
    rep = srv.executors[0].run_queue(
        0, srv.scheduler.schedule(0, srv.select_clients(),
                                  [0]).queue(0),
        srv.algorithm.broadcast_payload(srv.params, srv.server_state),
        data)
    wire = TopKCompressor(fraction=0.1).compress_partial(rep.partial)
    dense = payload_bytes(rep.partial["sums"])
    compressed = payload_bytes(wire["sums"])
    assert 0 < compressed < dense
