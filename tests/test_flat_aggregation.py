"""Flat-buffer hierarchical aggregation: bit-exact equivalence with the
reference ``flat_aggregate`` for all four OPs (COLLECT passthrough and mixed
bf16/fp32 deltas included), layout round-trips, micro-batch flush
boundaries, and the flat compressor wire format.

Bit-exactness strategy: payloads and weights are small integers, so every
product and partial sum is exactly representable in fp32 (and bf16) — any
reordering the batched fold introduces must still produce identical bits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate,
                                    payload_bytes)
from repro.core.flat import FlatLayout, is_flat_partial

OPS = {"delta": Op.WEIGHTED_AVG, "tau": Op.AVG, "count": Op.SUM,
       "trace": Op.COLLECT}


def _int_results(n, seed=0):
    """Integer-valued payloads: mixed bf16/fp32 leaves inside 'delta'."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        out.append(ClientResult(
            payload={
                "delta": {
                    "w": jnp.asarray(rng.integers(-8, 9, size=(4, 3)),
                                     jnp.bfloat16),
                    "b": jnp.asarray(rng.integers(-8, 9, size=(5,)),
                                     jnp.float32),
                },
                "tau": jnp.float32(rng.integers(1, 9)),
                "count": jnp.ones((), jnp.float32),
                "trace": jnp.asarray(rng.integers(-4, 5, size=(2,)),
                                     jnp.float32),
            },
            ops=OPS, weight=float(rng.integers(1, 16))))
    return out


def _assert_bit_exact(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("K", [1, 2, 3, 5])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_hierarchical_bit_exact_vs_flat(K, use_kernel):
    """All four OPs, any executor split, kernel and jnp flush paths."""
    results = _int_results(11)
    flat = flat_aggregate(results, OPS)
    aggs = [LocalAggregator(OPS, use_kernel=use_kernel) for _ in range(K)]
    for i, r in enumerate(results):
        aggs[i % K].fold(r)
    hier = global_aggregate([a.partial() for a in aggs], OPS)
    _assert_bit_exact(flat["delta"], hier["delta"])
    _assert_bit_exact(flat["tau"], hier["tau"])
    _assert_bit_exact(flat["count"], hier["count"])
    # COLLECT passthrough: per-executor concatenation order (executor k's
    # clients in fold order, executors in partial order), values untouched
    expect = [r for k in range(K) for i, r in enumerate(results) if i % K == k]
    assert [w for w, _ in hier["trace"]] == [r.weight for r in expect]
    for (_, a), r in zip(hier["trace"], expect):
        _assert_bit_exact(a, r.payload["trace"])


@pytest.mark.parametrize("micro_batch", [1, 3, 16, 100])
def test_micro_batch_boundary_is_invisible(micro_batch):
    """Flush boundaries (full batches, tails, zero-row padding) must not
    change a single bit of the aggregate."""
    results = _int_results(7, seed=3)
    ref = flat_aggregate(results, OPS)
    agg = LocalAggregator(OPS, use_kernel=True, micro_batch=micro_batch)
    for r in results:
        agg.fold(r)
    out = global_aggregate([agg.partial()], OPS)
    _assert_bit_exact(ref["delta"], out["delta"])
    _assert_bit_exact(ref["tau"], out["tau"])
    _assert_bit_exact(ref["count"], out["count"])


def test_partial_interleaved_with_folds():
    """partial() mid-stream (flush + accumulator exposure) must not disturb
    subsequent folds."""
    results = _int_results(9, seed=4)
    ref = flat_aggregate(results, OPS)
    agg = LocalAggregator(OPS, use_kernel=True, micro_batch=4)
    for i, r in enumerate(results):
        agg.fold(r)
        if i % 2 == 0:
            agg.partial()               # mid-stream snapshot
    _assert_bit_exact(ref["delta"],
                      global_aggregate([agg.partial()], OPS)["delta"])


def test_layout_flatten_unflatten_roundtrip():
    results = _int_results(1)
    payload = results[0].payload
    layout = FlatLayout.build(OPS, payload)
    buffers = layout.flatten(payload)
    back = layout.unflatten({g: b.astype(jnp.float32)
                             for g, b in buffers.items()})
    _assert_bit_exact(back["delta"], payload["delta"])
    _assert_bit_exact(back["tau"], payload["tau"])
    assert "trace" not in back          # COLLECT never enters the layout


def test_group_dtype_follows_leaves():
    """All-bf16 deltas stay bf16 on the buffer (the bandwidth lever); mixed
    bf16/fp32 promotes to fp32."""
    mixed = _int_results(1)[0].payload
    layout = FlatLayout.build(OPS, mixed)
    assert layout.group_dtypes["weighted"] == jnp.float32
    bf16_only = {"delta": {"w": jnp.ones((4, 3), jnp.bfloat16)}}
    layout2 = FlatLayout.build({"delta": Op.WEIGHTED_AVG}, bf16_only)
    assert layout2.group_dtypes["weighted"] == jnp.bfloat16


def test_flat_partial_memory_is_O_sa():
    """One buffer per group, size independent of folded client count."""
    agg = LocalAggregator(OPS, use_kernel=True, micro_batch=4)
    sizes = []
    for r in _int_results(10):
        agg.fold(r)
        p = agg.partial()
        assert is_flat_partial(p)
        sizes.append(payload_bytes(p["sums"]))
    assert len(set(sizes)) == 1


def test_mixed_flat_and_nested_partials_interop():
    """A hand-built legacy nested partial combines with flat partials."""
    results = _int_results(6, seed=5)
    ref = flat_aggregate(results, OPS)
    agg = LocalAggregator(OPS)
    for r in results[:4]:
        agg.fold(r)
    flat_part = agg.partial()
    legacy = LocalAggregator(OPS)
    for r in results[4:]:
        legacy.fold(r)
    lp = legacy.partial()
    from repro.core.flat import to_nested_sums
    nested_part = dict(lp, sums=to_nested_sums(lp))   # legacy wire format
    out = global_aggregate([flat_part, nested_part], OPS)
    _assert_bit_exact(ref["delta"], out["delta"])
    _assert_bit_exact(ref["count"], out["count"])


def test_spmd_flat_matches_host():
    from repro.comm.collective import spmd_global_aggregate
    results = _int_results(8, seed=6)
    aggs = [LocalAggregator(OPS) for _ in range(2)]
    for i, r in enumerate(results):
        aggs[i % 2].fold(r)
    parts = [a.partial() for a in aggs]
    host = global_aggregate(parts, OPS)
    spmd = spmd_global_aggregate(parts, OPS, mesh=None)
    _assert_bit_exact(host["delta"], spmd["delta"])
    _assert_bit_exact(host["tau"], spmd["tau"])


# ---------------------------------------------------------------------------
# compressors on the flat wire format
# ---------------------------------------------------------------------------

def test_topk_full_fraction_roundtrips_flat_partial():
    from repro.core.compression import TopKCompressor
    agg = LocalAggregator(OPS)
    for r in _int_results(5, seed=7):
        agg.fold(r)
    p = agg.partial()
    comp = TopKCompressor(fraction=1.0)     # keep everything: lossless
    wire = comp.compress_partial(p)
    assert wire["_wire_bytes"] > 0
    back = comp.decompress_partial(wire)
    for g, buf in p["sums"]["buffers"].items():
        np.testing.assert_array_equal(np.asarray(buf),
                                      np.asarray(back["sums"]["buffers"][g]))


def test_topk_compresses_only_target_entry_span():
    """'delta' spans compress; the co-resident 'count'/'tau' segments of the
    unit buffer ride raw and survive exactly."""
    from repro.core.compression import TopKCompressor
    agg = LocalAggregator(OPS)
    results = _int_results(5, seed=8)
    for r in results:
        agg.fold(r)
    p = agg.partial()
    comp = TopKCompressor(fraction=0.2)
    back = comp.decompress_partial(comp.compress_partial(p))
    out = global_aggregate([back], OPS)
    ref = global_aggregate([p], OPS)
    _assert_bit_exact(ref["tau"], out["tau"])         # untouched entries
    _assert_bit_exact(ref["count"], out["count"])
    # compressed delta is sparsified, not dropped
    assert np.count_nonzero(np.asarray(jax.tree.leaves(out["delta"])[0])) > 0


def test_int8_flat_wire_is_4x_smaller():
    from repro.core.compression import Int8Compressor
    agg = LocalAggregator({"delta": Op.WEIGHTED_AVG})
    rng = np.random.default_rng(9)
    for _ in range(4):
        agg.fold(ClientResult(
            {"delta": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)},
            {"delta": Op.WEIGHTED_AVG}, weight=2.0))
    p = agg.partial()
    comp = Int8Compressor()
    wire = comp.compress_partial(p)
    dense = payload_bytes(p["sums"])
    assert wire["_wire_bytes"] < dense / 3.5
    back = comp.decompress_partial(wire)
    np.testing.assert_allclose(
        np.asarray(back["sums"]["buffers"]["weighted"]),
        np.asarray(p["sums"]["buffers"]["weighted"]),
        atol=float(np.abs(np.asarray(p["sums"]["buffers"]["weighted"])).max())
        / 100)


# ---------------------------------------------------------------------------
# kernel tiling (explicit blk sweeps keep multi-block + padding covered now
# that the wrapper auto-sizes to a single block in interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,blk", [(1000, 256), (65536, 4096), (100001, 8192)])
@pytest.mark.parametrize("C", [1, 5])
def test_agg_kernel_explicit_blk_tiling(n, blk, C):
    from repro.kernels import agg_weighted_sum as ak
    from repro.kernels import ref
    key = jax.random.PRNGKey(0)
    acc = jax.random.normal(key, (n,), jnp.float32)
    deltas = jax.random.normal(jax.random.fold_in(key, 1), (C, n),
                               jnp.bfloat16)
    w = jnp.linspace(0.5, 2.0, C)
    out = ak.agg_weighted_sum(acc, deltas, w, blk=blk, interpret=True)
    exp = ref.agg_weighted_sum_ref(acc, deltas, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_dispatch_counter_counts_batched_flushes():
    from repro.kernels import ops as kops
    results = _int_results(8, seed=10)
    slim_ops = {"delta": Op.WEIGHTED_AVG}
    kops.reset_agg_dispatch_count()
    agg = LocalAggregator(slim_ops, use_kernel=True, micro_batch=4)
    for r in results:
        agg.fold(ClientResult({"delta": r.payload["delta"]}, slim_ops,
                              r.weight))
    agg.partial()
    # 8 clients at B=4 -> exactly 2 dispatches for the whole queue
    assert kops.agg_dispatch_count() == 2
