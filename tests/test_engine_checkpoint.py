"""Round-engine in-flight checkpointing (DESIGN.md §7): the async engine's
pipeline (queues, clock events with in-flight chunk partials, staleness
versions, per-queue offsets, fold buffer) and the semi-sync carry pool
round-trip through ``checkpoint/manager.py`` and resume bit-exactly —
including crash-consistent auto-resume after a mid-round kill under an
active fault plan (DESIGN.md §10).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager, params_digest
from repro.core import (ClientStateManager, FaultPlan, ParrotServer,
                        RetryPolicy, SequentialExecutor, TickTimer,
                        make_algorithm)
from repro.data import make_classification_clients


def _grad_fn():
    def loss(params, batch):
        x = batch["x"]
        h = jax.nn.relu(x @ params["w0"] + params["b0"])
        logits = h @ params["w1"] + params["b1"]
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    return jax.jit(jax.value_and_grad(loss))


GRAD_FN = _grad_fn()


def _params(dim=16, hidden=24, classes=10):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"w0": jax.random.normal(k1, (dim, hidden)) / np.sqrt(dim),
            "b0": jnp.zeros((hidden,)),
            "w1": jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden),
            "b1": jnp.zeros((classes,))}


def _build(engine, ckpt_dir=None, algorithm="scaffold", compressor=None):
    data = make_classification_clients(
        24, dim=16, n_classes=10, partition="natural", partition_arg=5.0,
        mean_samples=40, batch_size=20, seed=0)
    algo = make_algorithm(algorithm, GRAD_FN, 0.05, local_epochs=1)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="engckpt_"))
    timer = TickTimer()
    execs = [SequentialExecutor(k, algo, state_manager=sm, timer=timer)
             for k in range(3)]
    cm = (CheckpointManager(ckpt_dir, every_rounds=1, keep=10)
          if ckpt_dir else None)
    opts = {"chunk_size": 3} if engine != "bsp" else None
    return ParrotServer(params=_params(), algorithm=algo, executors=execs,
                        data_by_client=data, clients_per_round=8,
                        round_engine=engine, engine_opts=opts,
                        checkpoint_manager=cm, compressor=compressor, seed=0)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


@pytest.mark.parametrize("engine", ["async", "semi-sync"])
def test_resume_mid_pipeline_is_bit_exact(engine, tmp_path):
    """Run 5 rounds with per-round checkpoints; restore at round 2 into a
    FRESH server+engine and run the remaining 3 — params must match the
    uninterrupted run bit for bit (the async restore resumes with chunks
    in flight and a partially-filled fold buffer)."""
    d = str(tmp_path / "ck")
    a = _build(engine, ckpt_dir=d)
    for _ in range(5):
        a.run_round()
    b = _build(engine)
    CheckpointManager(d).restore(b, os.path.join(d, "step_%08d" % 2))
    assert b.round == 2
    for _ in range(3):
        b.run_round()
    assert _leaves_equal(a.params, b.params)
    assert [m.makespan for m in a.history[2:]] == \
        [m.makespan for m in b.history[2:]]


@pytest.mark.parametrize("engine,comp", [("bsp", "topk"),
                                         ("semi-sync", "topk"),
                                         ("async", "topk"),
                                         ("async", "powersgd")])
def test_resume_under_compression_is_bit_exact(engine, comp, tmp_path):
    """Compressor state (top-k error-feedback residuals / PowerSGD P-Q warm
    starts) rides in the checkpoint blob: a restore-at-round-2 resume must
    match the uninterrupted run bit for bit under a STATEFUL compressor —
    without the blob entry the resumed run restarts from zero residuals and
    silently diverges."""
    from repro.core.compression import make_compressor

    def mk():
        return make_compressor(comp, 0.25, rank=2)

    d = str(tmp_path / "ck")
    a = _build(engine, ckpt_dir=d, compressor=mk())
    for _ in range(5):
        a.run_round()
    b = _build(engine, compressor=mk())
    CheckpointManager(d).restore(b, os.path.join(d, "step_%08d" % 2))
    assert b.round == 2
    for _ in range(3):
        b.run_round()
    assert _leaves_equal(a.params, b.params)


def test_async_state_dict_captures_pipeline():
    srv = _build("async")
    srv.run_round()
    state = srv.engine.state_dict()
    assert state["initialized"] and state["mode"] == "async"
    # something is genuinely in flight at an update boundary
    assert state["clock"]["events"]
    assert any(es["inflight"] for es in state["states"].values())
    # host-resident: every array in the blob is numpy, not a device array
    for t, seq, kind, data in state["clock"]["events"]:
        if kind == "chunk_done":
            for leaf in jax.tree.leaves(data[1].partial):
                assert not hasattr(leaf, "sharding") or \
                    isinstance(leaf, np.ndarray)


def test_mode_mismatch_rejected():
    a = _build("async")
    a.run_round()
    b = _build("semi-sync")
    with pytest.raises(ValueError):
        b.engine.load_state_dict(a.engine.state_dict())


def test_bsp_engine_state_is_none_and_restores():
    srv = _build("bsp")
    assert srv.engine.state_dict() is None
    srv.engine.load_state_dict(None)        # no-op


# ---------------------------------------------------------------------------
# crash-consistent auto-resume (DESIGN.md §10): kill the process mid-round
# under an active fault plan, then ``run(N, auto_resume=True)`` on a fresh
# server must land on the uninterrupted run's exact params
# ---------------------------------------------------------------------------

def _fault_loss(params, batch):
    logits = batch["x"] @ params["w"] + params["b"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - gold)


FAULT_GRAD = jax.jit(jax.value_and_grad(_fault_loss))
FAULT_PARAMS = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
# run_queue call counts (on executor 0) at which the kill lands mid-round
# for each engine's dispatch cadence — chosen so the interrupt fires well
# inside the 8-round run, after at least one durable checkpoint
_KILL_AFTER = {"bsp": 4, "semi-sync": 14, "async": 11}


def _fault_build(engine, ckpt_dir):
    data = make_classification_clients(30, dim=8, n_classes=4,
                                       mean_samples=30, batch_size=10,
                                       seed=1)
    algo = make_algorithm("fedavg", grad_fn=FAULT_GRAD, lr=0.1,
                          local_steps=2)
    sm = ClientStateManager(tempfile.mkdtemp(prefix="faultckpt_"))
    execs = [SequentialExecutor(k, algo, state_manager=sm,
                                speed_model=lambda kk, r: 0.0,
                                timer=TickTimer(1.0)) for k in range(3)]
    plan = FaultPlan.random(seed=3, horizon=80.0, executors=[0, 1, 2],
                            clients=list(range(30)),
                            crash_rate=0.05, restart_delay=5.0,
                            dropout_rate=0.1, dropout_duration=4.0,
                            corrupt_rate=0.05,
                            slowdown_rate=0.03, slowdown_duration=6.0)
    opts = {"chunk_size": 2} if engine != "bsp" else None
    return ParrotServer(params=FAULT_PARAMS, algorithm=algo,
                        executors=execs, data_by_client=data,
                        clients_per_round=8, seed=7, round_engine=engine,
                        engine_opts=opts, faults=plan,
                        retry=RetryPolicy(max_retries=2),
                        checkpoint_manager=CheckpointManager(
                            ckpt_dir, every_rounds=1, keep=10))


@pytest.mark.parametrize("engine", ["bsp", "semi-sync", "async"])
def test_kill_mid_round_then_auto_resume_is_bit_exact(engine, tmp_path):
    N = 8
    # uninterrupted reference (its checkpoints are never read back)
    ref = _fault_build(engine, str(tmp_path / "ref"))
    ref.run(N)
    want = params_digest(ref.params)

    # same run, killed mid-round: executor 0's run_queue raises
    # KeyboardInterrupt partway through a round, after some durable
    # checkpoints exist — exactly a process kill between fsyncs
    d = str(tmp_path / "ck")
    victim = _fault_build(engine, d)
    ex0 = victim.executors[0]
    real, calls = ex0.run_queue, [0]

    def dying(*a, **kw):
        calls[0] += 1
        if calls[0] >= _KILL_AFTER[engine]:
            raise KeyboardInterrupt
        return real(*a, **kw)

    ex0.run_queue = dying
    with pytest.raises(KeyboardInterrupt):
        victim.run(N)
    assert 1 <= victim.round < N        # the kill landed mid-run

    # fresh process: a NEW server over the same config auto-resumes from
    # the last durable round boundary and replays the rest
    resumed = _fault_build(engine, d)
    resumed.run(N, auto_resume=True)
    assert resumed.round == N
    assert params_digest(resumed.params) == want
    assert len(resumed.history) == N
