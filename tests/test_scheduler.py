"""Scheduler (Algorithm 3) and workload-model (Eq. 2) unit tests."""
import numpy as np
import pytest

from repro.core.scheduler import ClientTask, ParrotScheduler, makespan
from repro.core.workload import (RunRecord, WorkloadEstimator, WorkloadModel)


def _tasks(sizes):
    return [ClientTask(i, int(n)) for i, n in enumerate(sizes)]


def _feed(est, models, sizes, rounds=3, seed=0):
    rng = np.random.default_rng(seed)
    for r in range(rounds):
        for i, n in enumerate(sizes):
            k = int(rng.integers(len(models)))
            est.record(RunRecord(round=r, client=i, executor=k,
                                 n_samples=int(n),
                                 time=models[k].predict(n)))


def test_estimator_recovers_linear_model():
    est = WorkloadEstimator()
    true = {0: WorkloadModel(0.01, 0.5), 1: WorkloadModel(0.03, 1.0)}
    rng = np.random.default_rng(0)
    for r in range(5):
        for _ in range(10):
            for k, m in true.items():
                n = int(rng.integers(10, 500))
                est.record(RunRecord(r, 0, k, n, m.predict(n)))
    fit = est.fit(5)
    for k, m in true.items():
        assert abs(fit[k].t_sample - m.t_sample) < 1e-6
        assert abs(fit[k].b - m.b) < 1e-4


def test_time_window_discards_stale_history():
    """Fig. 11: after a speed change, all-history fits are poisoned; a
    window-limited fit tracks the new regime."""
    est_all = WorkloadEstimator(time_window=0)
    est_win = WorkloadEstimator(time_window=2)
    slow = WorkloadModel(0.05, 1.0)
    fast = WorkloadModel(0.005, 0.1)
    rng = np.random.default_rng(1)
    for r in range(10):
        m = slow if r < 8 else fast          # regime switch at round 8
        for _ in range(20):
            n = int(rng.integers(10, 500))
            rec = RunRecord(r, 0, 0, n, m.predict(n))
            est_all.record(rec)
            est_win.record(rec)
    fit_all = est_all.fit(10)[0]
    fit_win = est_win.fit(10)[0]
    assert abs(fit_win.t_sample - fast.t_sample) < 1e-6
    assert abs(fit_all.t_sample - fast.t_sample) > 0.005


def test_lpt_beats_round_robin_on_skewed_sizes():
    est = WorkloadEstimator()
    models = {k: WorkloadModel(0.01, 0.1) for k in range(4)}
    sizes = [1000, 10, 10, 10, 10, 10, 10, 10, 500, 500]
    _feed(est, models, sizes)
    sched = ParrotScheduler(est, warmup_rounds=0)
    s = sched.schedule(5, _tasks(sizes), list(range(4)))
    rr = ParrotScheduler(est, warmup_rounds=0, policy="none")
    s_rr = rr.schedule(5, _tasks(sizes), list(range(4)))
    assert makespan(s.assignment, models) <= makespan(s_rr.assignment, models)


def test_heterogeneous_devices_get_fewer_samples():
    """Eq. 4: a 4x-slower executor should be assigned ~4x less work."""
    est = WorkloadEstimator()
    true = {0: WorkloadModel(0.01, 0.0), 1: WorkloadModel(0.04, 0.0)}
    rng = np.random.default_rng(2)
    for r in range(3):
        for _ in range(30):
            for k, m in true.items():
                n = int(rng.integers(10, 300))
                est.record(RunRecord(r, 0, k, n, m.predict(n)))
    sched = ParrotScheduler(est, warmup_rounds=0)
    sizes = [100] * 40
    s = sched.schedule(3, _tasks(sizes), [0, 1])
    n0 = sum(t.n_samples for t in s.queue(0))
    n1 = sum(t.n_samples for t in s.queue(1))
    assert n0 > 2.5 * n1


def test_all_tasks_assigned_exactly_once():
    est = WorkloadEstimator()
    sched = ParrotScheduler(est, warmup_rounds=0)
    sizes = list(range(1, 58))
    s = sched.schedule(1, _tasks(sizes), list(range(7)))
    assigned = sorted(t.client for q in s.assignment.values() for t in q)
    assert assigned == list(range(len(sizes)))


def test_warmup_uses_uniform_split():
    est = WorkloadEstimator()
    sched = ParrotScheduler(est, warmup_rounds=2)
    s = sched.schedule(0, _tasks([10] * 12), [0, 1, 2])
    lens = sorted(len(q) for q in s.assignment.values())
    assert lens == [4, 4, 4]


def test_elastic_membership_changes_K_between_rounds():
    """The executor set is a per-round argument (elastic scaling)."""
    est = WorkloadEstimator()
    sched = ParrotScheduler(est, warmup_rounds=0)
    s4 = sched.schedule(1, _tasks([10] * 16), [0, 1, 2, 3])
    s2 = sched.schedule(2, _tasks([10] * 16), [0, 2])   # two died
    assert set(s4.assignment) == {0, 1, 2, 3}
    assert set(s2.assignment) == {0, 2}
    assert sum(len(q) for q in s2.assignment.values()) == 16


def test_scheduling_cost_is_linear_in_K_times_Mp():
    """§4.5: O(K·M_p) — doubling both should ~4x the work, and stay tiny."""
    import time
    est = WorkloadEstimator()
    sched = ParrotScheduler(est, warmup_rounds=0)
    t0 = time.perf_counter()
    sched.schedule(1, _tasks(np.random.default_rng(0).integers(
        1, 1000, size=1000)), list(range(32)))
    dt = time.perf_counter() - t0
    assert dt < 1.0   # 1000 clients x 32 executors scheduled in < 1s
