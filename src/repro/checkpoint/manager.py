"""Round-granular atomic checkpointing (fault tolerance; DESIGN.md §7).

Layout:
  <dir>/step_<round>/
      server.pkl          — params, server optimizer/algorithm state, RNG,
                            estimator history, round counter, engine
                            in-flight state (async pipeline / semi-sync
                            carry pool; see RoundEngine.state_dict)
      state/              — client-state shard files (hard-linked from the
                            state managers; incremental)
      MANIFEST.json       — written LAST; a checkpoint without a manifest is
                            treated as torn and ignored on restore
  <dir>/LATEST            — text file naming the newest complete step

Writes go to a temp dir then ``os.replace`` into place, so a crash mid-save
never corrupts the previous checkpoint.  The manifest additionally records a
sha256 digest of the params (``params_digest``); ``restore`` re-computes it
from the loaded blob and refuses a checkpoint whose bytes rotted or were
tampered with *before* mutating the server — a failed restore leaves the
server untouched.  ``restore_latest`` walks backwards past torn AND corrupt
checkpoints.  ``keep`` bounds retained checkpoints (GC).

Crash recovery (DESIGN.md §10): the blob carries the executor topology and
the fault injector's runtime state, so ``ParrotServer.run(...,
auto_resume=True)`` after a mid-round kill restores the last durable round
boundary — executors that were crashed at save time are retired on restore
(their scheduled restart revives and re-pins them later) — and replays the
remaining rounds deterministically.
"""
from __future__ import annotations

import hashlib
import json
import os
import pickle
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def params_digest(params: Any) -> str:
    """sha256 over the params pytree's leaves (host bytes, in tree order,
    shape/dtype tagged so a reshaped-but-identical buffer cannot collide).
    The integrity check for checkpoint blobs — and the equality witness the
    chaos/resume tests compare across runs."""
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(params):
        arr = np.asarray(leaf)
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, every_rounds: int = 1, keep: int = 3):
        self.directory = directory
        self.every_rounds = every_rounds
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, rnd: int) -> str:
        return os.path.join(self.directory, f"step_{rnd:08d}")

    def save(self, server: Any) -> str:
        rnd = server.round
        final = self._step_dir(rnd)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_ckpt_")
        try:
            blob = {
                "round": rnd,
                "params": jax.tree.map(np.asarray, server.params),
                "server_state": jax.tree.map(np.asarray, server.server_state),
                "rng_state": server.rng.bit_generator.state,
                "estimator_records": {
                    k: list(v) for k, v in server.estimator._records.items()},
                # the *fitted* models too: the async engine consults
                # last_fit between schedules (steal victims, dropout
                # predictions) — a resume that refits lazily would diverge
                "estimator_fit": dict(server.estimator.last_fit),
                "history": server.history,
                "executor_ids": sorted(server.executors),
                # engine in-flight state (async pipeline / semi-sync carry):
                # host-side plain data via RoundEngine.state_dict, so a
                # restore resumes the discrete-event pipeline exactly where
                # the save left it (None for the stateless BSP engine)
                "engine": server.engine.state_dict(),
                # network-simulation anchors (DESIGN.md §9): cumulative
                # virtual time (availability windows), last broadcast size
                # and achieved wire ratio (comm predictions/pricing) — a
                # resumed run must price comm exactly as the original would
                "virtual_now": server.virtual_now,
                "last_payload_nbytes": server._last_payload_nbytes,
                "wire_ratio": server._wire_ratio,
                # fault-injection runtime state (fired one-shot events,
                # per-client retry budgets): a resumed run must replay the
                # REMAINING fault plan, not the whole plan from t=0
                "faults": (server.faults.state_dict()
                           if getattr(server, "faults", None) is not None
                           else None),
                # adaptive control-plane state (λ / deadline controller
                # values + EWMAs): a resumed run must replay the same
                # controller trajectory bit-exactly (DESIGN.md §12)
                "control": (server.control.state_dict()
                            if getattr(server, "control", None) is not None
                            else None),
                # compressor state (top-k error-feedback residuals, PowerSGD
                # P/Q warm starts): without it a resume under compression
                # silently diverges from the uninterrupted run.  hasattr-
                # guarded: duck-typed custom compressors without state_dict
                # checkpoint as stateless.
                "compressor": (server.compressor.state_dict()
                               if getattr(server, "compressor", None)
                               is not None
                               and hasattr(server.compressor, "state_dict")
                               else None),
                # telemetry state (DESIGN.md §13): tracer spans + metrics
                # registry ride along so auto_resume reproduces the
                # uninterrupted run's trace exactly
                "telemetry": (server.telemetry.state_dict()
                              if getattr(server, "telemetry", None)
                              is not None else None),
                "time": time.time(),
            }
            digest = params_digest(blob["params"])
            with open(os.path.join(tmp, "server.pkl"), "wb") as f:
                pickle.dump(blob, f, protocol=pickle.HIGHEST_PROTOCOL)
            # client-state shards (stateful algorithms); executors usually
            # share one manager — flush each distinct manager once
            state_dir = os.path.join(tmp, "state")
            seen = set()
            for ex in server.executors.values():
                sm = ex.state_manager
                if sm is not None and id(sm) not in seen:
                    seen.add(id(sm))
                    sm.checkpoint(state_dir)
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump({"round": rnd, "complete": True,
                           "params_digest": digest}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
            f.write(os.path.basename(final))
        os.replace(os.path.join(self.directory, "LATEST.tmp"),
                   os.path.join(self.directory, "LATEST"))
        self._gc()
        return final

    def maybe_save(self, server: Any) -> Optional[str]:
        if server.round % self.every_rounds == 0:
            return self.save(server)
        return None

    def _gc(self) -> None:
        steps = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for d in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, server: Any, step_dir: str) -> int:
        # load + verify BEFORE touching the server: a corrupt blob (bit rot,
        # torn write that somehow kept its manifest, tampering) must raise
        # with the server still in its pre-restore state
        with open(os.path.join(step_dir, "server.pkl"), "rb") as f:
            blob = pickle.load(f)
        manifest_path = os.path.join(step_dir, "MANIFEST.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as f:
                manifest = json.load(f)
            want = manifest.get("params_digest")
            if want is not None and params_digest(blob["params"]) != want:
                raise ValueError(
                    f"checkpoint {step_dir} failed integrity check: params "
                    f"digest mismatch (expected {want[:12]}…)")
        server.params = jax.tree.map(jax.numpy.asarray, blob["params"])
        server.server_state = jax.tree.map(jax.numpy.asarray,
                                           blob["server_state"])
        server.rng.bit_generator.state = blob["rng_state"]
        server.estimator._records.clear()
        for k, v in blob["estimator_records"].items():
            server.estimator._records[int(k)] = list(v)
        server.estimator.last_fit = dict(blob.get("estimator_fit", {}))
        server.history = list(blob["history"])
        server.round = blob["round"]
        server.virtual_now = float(blob.get("virtual_now", 0.0))
        server._last_payload_nbytes = int(blob.get("last_payload_nbytes", 0))
        server._wire_ratio = float(blob.get("wire_ratio", 1.0))
        server.engine.load_state_dict(blob.get("engine"))
        if getattr(server, "faults", None) is not None:
            server.faults.load_state_dict(blob.get("faults"))
        if getattr(server, "control", None) is not None:
            server.control.load_state_dict(blob.get("control"))
        if getattr(server, "compressor", None) is not None \
                and hasattr(server.compressor, "load_state_dict"):
            server.compressor.load_state_dict(blob.get("compressor"))
        if getattr(server, "telemetry", None) is not None:
            # wholesale replace (construction-time plan spans included), so
            # a resumed trace equals the uninterrupted run's
            server.telemetry.load_state_dict(blob.get("telemetry"))
        # reconcile the executor topology with the checkpointed one: a
        # fresh server is constructed with the FULL executor set, but the
        # saved run may have had some crashed — retire those (releasing
        # their pins) so the resumed run schedules on the same live set;
        # their scheduled restart events revive them later.  Executors the
        # blob knows but this server lacks can't be conjured — that is a
        # configuration error the engines will surface.
        want_ids = set(blob.get("executor_ids", server.executors))
        for k in sorted(set(server.executors) - want_ids):
            server._drop_executor(k)
        for k in sorted(want_ids - set(server.executors)):
            server._revive_executor(k)
        state_dir = os.path.join(step_dir, "state")
        if os.path.isdir(state_dir):
            seen = set()
            for ex in server.executors.values():
                sm = ex.state_manager
                if sm is not None and id(sm) not in seen:
                    seen.add(id(sm))
                    sm.restore(state_dir)
        return server.round


def restore_latest(server: Any, directory: str) -> Optional[int]:
    """Restore the newest complete checkpoint; walks past torn ones."""
    mgr = CheckpointManager(directory)
    latest = os.path.join(directory, "LATEST")
    candidates: List[str] = []
    if os.path.exists(latest):
        with open(latest) as f:
            candidates.append(os.path.join(directory, f.read().strip()))
    candidates.extend(sorted(
        (os.path.join(directory, d) for d in os.listdir(directory)
         if d.startswith("step_")), reverse=True))
    seen = set()
    for cand in candidates:
        if cand in seen or not os.path.isdir(cand):
            continue
        seen.add(cand)
        manifest = os.path.join(cand, "MANIFEST.json")
        if not os.path.exists(manifest):
            continue  # torn checkpoint
        try:
            with open(manifest) as f:
                if not json.load(f).get("complete"):
                    continue
            return mgr.restore(server, cand)
        except Exception:
            continue
    return None
