import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: ``lower().compile()`` every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: sharding
mismatches, compile-time OOM and unsupported collectives all fail here.
Outputs one JSON per cell (memory analysis, cost analysis, per-kind
collective bytes) under ``results/dryrun/`` — the roofline analysis
(benchmarks/roofline.py) consumes them.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--arch-filter moe]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ALL_SHAPES, shape_by_name
from repro.configs.registry import ARCHS, cell_is_runnable, get_arch
from repro.launch.hlo_analysis import (collective_stats, compute_stats,
                                       cost_dict)
from repro.launch.inputs import input_specs
from repro.launch.mesh import make_production_mesh, use_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = RESULTS_DIR, verbose: bool = True,
             overrides: dict | None = None) -> dict:
    cfg = get_arch(arch)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = shape_by_name(shape_name)
    ok, why = cell_is_runnable(cfg, shape)
    tag = f"{arch}__{shape_name}__{'multipod' if multi_pod else 'pod'}"
    if not ok:
        rec = {"cell": tag, "status": "skipped", "reason": why}
        _save(rec, out_dir, tag)
        if verbose:
            print(f"[dryrun] {tag}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.sharding import enable_activation_policy
    enable_activation_policy(mesh)
    spec = input_specs(cfg, shape, mesh)
    t0 = time.perf_counter()
    rec = {"cell": tag, "arch": arch, "shape": shape_name,
           "multi_pod": multi_pod, "mesh": dict(zip(mesh.axis_names,
                                                    mesh.devices.shape))}
    try:
        with use_mesh(mesh):
            jitted = jax.jit(spec.step_fn, in_shardings=spec.in_shardings,
                             donate_argnums=spec.donate_argnums)
            lowered = jitted.lower(*spec.args)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = cost_dict(compiled)
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
        comp = compute_stats(hlo)
        n_dev = mesh.devices.size

        rec.update({
            "status": "ok",
            "step": spec.static_desc,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "n_devices": n_dev,
            "memory": _mem_dict(mem),
            # raw cost_analysis (counts scan bodies once — kept for reference)
            "xla_flops_per_device": cost.get("flops", 0.0),
            "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
            # trip-count-aware estimates (see hlo_analysis.compute_stats)
            "flops_per_device": comp["flops_per_device"],
            "bytes_per_device": comp["bytes_per_device_est"],
            "collectives": coll,
            "model": {"n_params": get_arch(arch).n_params(),
                      "n_active_params": get_arch(arch).n_active_params()},
        })
        if verbose:
            print(f"[dryrun] {tag}: OK  lower {t_lower:.1f}s  "
                  f"compile {t_compile:.1f}s")
            print(f"  memory_analysis: {rec['memory']}")
            print(f"  flops/dev={rec['flops_per_device']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"(xla raw: {rec['xla_flops_per_device']:.3e})")
            print(f"  collectives: {coll['summary']}")
    except Exception as e:  # noqa: BLE001 — report and continue the sweep
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        if verbose:
            print(f"[dryrun] {tag}: ERROR {type(e).__name__}: {e}")
    _save(rec, out_dir, tag)
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def _save(rec: dict, out_dir: str, tag: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{tag}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--arch-filter", default="")
    ap.add_argument("--out-dir", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    n_ok = n_err = n_skip = 0
    if args.all:
        for name in ARCHS:
            if args.arch_filter and args.arch_filter not in name:
                continue
            for shape in ALL_SHAPES:
                for mp in meshes:
                    rec = run_cell(name, shape.name, mp, args.out_dir)
                    n_ok += rec["status"] == "ok"
                    n_err += rec["status"] == "error"
                    n_skip += rec["status"] == "skipped"
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, mp, args.out_dir)
            n_ok += rec["status"] == "ok"
            n_err += rec["status"] == "error"
            n_skip += rec["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
