"""Serving driver: batched prefill + decode against any registry arch.

On CPU this runs reduced configs end-to-end (generates real tokens); the
full-config path is exercised by the dry-run.  Demonstrates the serve side
of the framework: ring-buffer KV caches, recurrent state carry-through, and
batched request scheduling.

  python -m repro.launch.serve --arch hymba-1.5b --batch 4 --prompt-len 64 \
      --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) config — dry-run scale")
    args = ap.parse_args(argv)

    from repro.configs.registry import get_arch
    from repro.models import lm

    cfg = get_arch(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    B, P, G = args.batch, args.prompt_len, args.gen

    if cfg.input_kind == "embeddings":
        prompt = jax.random.normal(key, (B, P, cfg.d_model))
    else:
        prompt = jax.random.randint(key, (B, P), 0, cfg.vocab_size)

    prefill = jax.jit(lm.make_prefill_step(cfg, B, P, cache_len=P + G))
    decode = jax.jit(lm.make_decode_step(cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out_tokens = [toks]
    t0 = time.perf_counter()
    for i in range(G - 1):
        step_in = toks
        if cfg.input_kind == "embeddings":
            # stub frontend: embed generated ids through the token table
            step_in = jnp.take(params["embed"]["w"], toks, axis=0)
        logits, caches = decode(params, step_in, caches, jnp.int32(P + i))
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out_tokens.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] arch={cfg.name} B={B} prompt={P} gen={G}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms "
          f"({B*P/t_prefill:.0f} tok/s); decode {t_decode*1e3:.1f} ms "
          f"({B*(G-1)/max(t_decode,1e-9):.0f} tok/s)")
    print(f"[serve] sample tokens: {gen[0, :16].tolist()}")


if __name__ == "__main__":
    main()
