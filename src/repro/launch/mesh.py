"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the first
jax initialisation.
"""
from __future__ import annotations

import jax


def use_mesh(mesh):
    """Version-portable ``with use_mesh(mesh):`` context.

    ``jax.set_mesh`` only exists on jax >= 0.6; 0.5 has
    ``jax.sharding.use_mesh``; on 0.4.x the ``Mesh`` object itself is the
    context manager.  The dry-run path must run on all three.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices: int | None = None, model_axis: int = 1,
                   devices=None):
    """A small mesh over whatever devices exist (tests / examples).

    ``devices`` pins an explicit device list *in that order* — the device-
    placement layer (``core/placement.py``) builds its cross-device
    reduction mesh this way so mesh order matches executor pin order (the
    rank-ordered psum must fold partials in executor order to stay
    bit-identical to the host left-fold).
    """
    if devices is not None:
        import numpy as np
        from jax.sharding import Mesh
        n = len(devices)
        assert n % model_axis == 0
        return Mesh(np.array(devices).reshape(n // model_axis, model_axis),
                    ("data", "model"))
    n = n_devices or len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))
