"""End-to-end Parrot FL training driver.

Runs Algorithm 2 with K sequential executors over a synthetic federated
dataset, any of the 6 FL algorithms, heterogeneity-aware scheduling, state
management, checkpointing and auto-resume.  The client model is either a
reduced LM from the arch registry (``--arch``) or a small MLP (``--model
mlp``, the CPU-friendly default mirroring the paper's FEMNIST setting).

Examples:
  python -m repro.launch.train --algorithm scaffold --rounds 20
  python -m repro.launch.train --arch qwen2-0.5b --rounds 5 --clients 50
  python -m repro.launch.train --resume --ckpt-dir /tmp/parrot_ckpt
"""
from __future__ import annotations

import argparse
import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def build_grad_fn(model: str, arch: str | None, lr: float):
    """Returns (grad_fn, params0) for the chosen client model."""
    key = jax.random.PRNGKey(0)
    if model == "mlp":
        dims = [32, 64, 10]
        ks = jax.random.split(key, len(dims) - 1)
        params = {f"w{i}": jax.random.normal(k, (a, b)) / np.sqrt(a)
                  for i, (k, a, b) in enumerate(zip(ks, dims[:-1], dims[1:]))}
        params.update({f"b{i}": jnp.zeros((b,))
                       for i, b in enumerate(dims[1:])})

        def loss_fn(p, batch):
            x = batch["x"]
            n = len(dims) - 1
            for i in range(n):
                x = x @ p[f"w{i}"] + p[f"b{i}"]
                if i < n - 1:
                    x = jax.nn.relu(x)
            lse = jax.nn.logsumexp(x, axis=-1)
            gold = jnp.take_along_axis(
                x, batch["y"][:, None].astype(jnp.int32), axis=-1)[:, 0]
            return jnp.mean(lse - gold)

        return jax.jit(jax.value_and_grad(loss_fn)), params

    from repro.configs.registry import get_arch
    from repro.models import lm
    cfg = get_arch(arch).reduced()
    params = lm.init_params(key, cfg)

    def loss_fn(p, batch):
        return lm.loss_and_aux(p, batch, cfg)

    return jax.jit(jax.value_and_grad(loss_fn)), params


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="fedavg",
                    choices=["fedavg", "fedprox", "fednova", "mime",
                             "scaffold", "feddyn"])
    ap.add_argument("--model", default="mlp", choices=["mlp", "lm"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--clients-per-round", type=int, default=20)
    ap.add_argument("--executors", type=int, default=4)
    ap.add_argument("--local-epochs", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--scheduler", default="parrot",
                    choices=["parrot", "uniform", "none"])
    ap.add_argument("--time-window", type=int, default=0)
    ap.add_argument("--partition", default="natural")
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.checkpoint import CheckpointManager, restore_latest
    from repro.core import (ClientStateManager, ParrotServer,
                            SequentialExecutor, make_algorithm)
    from repro.core.compression import make_compressor
    from repro.data import make_classification_clients, make_lm_clients

    grad_fn, params = build_grad_fn(args.model, args.arch, args.lr)
    if args.model == "mlp":
        data = make_classification_clients(
            args.clients, dim=32, n_classes=10, partition=args.partition,
            seed=args.seed)
    else:
        from repro.configs.registry import get_arch
        cfg = get_arch(args.arch).reduced()
        data = make_lm_clients(args.clients, vocab=cfg.vocab_size,
                               partition=args.partition, seed=args.seed)

    algo = make_algorithm(args.algorithm, grad_fn, args.lr,
                          local_epochs=args.local_epochs)
    state_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="parrot_state_")
    sm = ClientStateManager(os.path.join(state_dir, "client_state"))
    executors = [SequentialExecutor(k, algo, state_manager=sm)
                 for k in range(args.executors)]
    ckpt = CheckpointManager(os.path.join(state_dir, "ckpt"),
                             every_rounds=args.ckpt_every) \
        if args.ckpt_dir else None
    server = ParrotServer(
        params=params, algorithm=algo, executors=executors,
        data_by_client=data, clients_per_round=args.clients_per_round,
        scheduler_policy=args.scheduler, time_window=args.time_window,
        compressor=make_compressor(args.compression),
        checkpoint_manager=ckpt, seed=args.seed)

    start = 0
    if args.resume and args.ckpt_dir:
        restored = restore_latest(server, os.path.join(state_dir, "ckpt"))
        if restored is not None:
            start = restored
            print(f"[train] resumed from round {restored}")

    for _ in range(start, args.rounds):
        m = server.run_round()
        print(f"[round {m.round:4d}] makespan={m.makespan:.3f}s "
              f"sched={m.schedule_time*1e3:.2f}ms "
              f"comm={m.comm_bytes/1e6:.2f}MB trips={m.comm_trips} "
              f"K={m.n_executors} est_err={m.estimation_error:.3f}")
    print("[train] done")


if __name__ == "__main__":
    main()
