"""ShapeDtypeStruct stand-ins for every model input (no allocation).

``input_specs(cfg, shape)`` returns everything the dry-run needs to lower a
cell: the step function, the abstract arguments, and their shardings under a
given mesh.  The same builders feed the launchers (train.py / serve.py) with
real arrays.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm, transformer
from repro.sharding import specs as shard_specs


@dataclass
class CellSpec:
    step_fn: Callable
    args: Tuple[Any, ...]                  # ShapeDtypeStructs
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()
    static_desc: str = ""


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(lambda k: lm.init_params(k, cfg),
                          jax.random.PRNGKey(0))


def _inputs_sds(cfg: ModelConfig, batch: int, seq: int):
    if cfg.input_kind == "embeddings":
        return jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                lr: float = 0.05) -> CellSpec:
    B, S = shape.global_batch, shape.seq_len
    p_sds = params_abstract(cfg)
    p_shard = shard_specs.params_shardings(p_sds, mesh)
    bspec = lambda sds, seq_axis=None: NamedSharding(
        mesh, shard_specs.batch_spec(sds.shape, mesh, seq_axis=seq_axis))

    if shape.kind == "train":
        step = lm.make_train_step(cfg, lr)
        inputs = _inputs_sds(cfg, B, S)
        labels = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch = {"inputs": inputs, "labels": labels}
        batch_shard = {"inputs": bspec(inputs, seq_axis=1),
                       "labels": bspec(labels, seq_axis=1)}
        return CellSpec(step, (p_sds, batch), (p_shard, batch_shard),
                        donate_argnums=(0,),
                        static_desc=f"train_step B={B} S={S}")

    if shape.kind == "prefill":
        step = lm.make_prefill_step(cfg, B, S)
        inputs = _inputs_sds(cfg, B, S)
        return CellSpec(step, (p_sds, inputs),
                        (p_shard, bspec(inputs, seq_axis=1)),
                        static_desc=f"prefill B={B} S={S}")

    # decode / long_decode: one new token against a seq_len cache
    step = lm.make_decode_step(cfg)
    inputs = _inputs_sds(cfg, B, 1)
    caches = jax.eval_shape(
        lambda: transformer.stack_cache(cfg, B, S, jnp.dtype(cfg.dtype)))
    cache_shard = shard_specs.caches_shardings(caches, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    return CellSpec(step, (p_sds, inputs, caches, pos),
                    (p_shard, bspec(inputs), cache_shard, pos_shard),
                    donate_argnums=(2,),
                    static_desc=f"decode B={B} cache={S}")
