"""Post-partitioning HLO analysis: trip-count-aware FLOPs, bytes and
collective bytes per device.

Why not just ``cost_analysis()``: XLA's analysis counts each ``while`` (scan)
body ONCE, so scan-over-layers models undercount FLOPs/bytes by ~n_layers,
and it has no collective breakdown at all.  We parse ``compiled.as_text()``
(shapes there are per-partition, i.e. per-device):

1. split the module into computations; build a name → result-type table;
2. recover every while loop's trip count from its condition's
   ``compare(..., constant)`` and propagate multipliers down the call tree
   (nested scans multiply);
3. FLOPs: every ``dot`` = 2 × |result| × contracted-dims (operand shapes via
   the name table), weighted by its computation's multiplier;
4. bytes: per instruction, result + operand bytes (≈ one write + reads),
   weighted likewise — an estimate (fusion-internal reuse is invisible), good
   to the tens of percent, which is what a roofline needs;
5. collectives: result bytes by op kind with ring-algorithm factors
   (all-reduce 2×, reduce-scatter ≈ group size ×, others 1×).
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*([^=]+?)\s"
                       r"([a-z][\w\-]*)\((.*)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%([\w\.\-]+)\s*\(.*->")
_WHILE_ATTR = re.compile(r"condition=%([\w\.\-]+).*?body=%([\w\.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_DOT_LHS_C = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "copy-start", "copy-done",
}


def cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on jax >= 0.5 but a
    one-element list of dicts on 0.4.x — normalise to a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_dims(type_str: str) -> Optional[List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d.strip()]


class Module:
    """Parsed HLO module: computations, instruction table, multipliers."""

    def __init__(self, hlo: str):
        self.comps: Dict[str, List[Tuple[str, str, str, str]]] = {}
        #            comp -> [(name, result_type, opcode, rest-of-line)]
        self.types: Dict[str, str] = {}          # instr name -> result type
        current = None
        comment = re.compile(r"/\*.*?\*/")
        for raw in hlo.splitlines():
            line = comment.sub("", raw.rstrip())
            if not line.strip():
                continue
            if not line.startswith(" "):
                m = _COMP_HDR.match(line.strip())
                if m:
                    current = m.group(1)
                    self.comps[current] = []
                continue
            if current is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, rtype, opcode, rest = m.groups()
            self.comps[current].append((name, rtype.strip(), opcode, rest))
            self.types[name] = rtype.strip()
        self.mult = self._multipliers()

    # ------------------------------------------------------------------
    def _trip_count(self, cond: str) -> int:
        """Loop bound from the condition computation.  XLA wraps the compare
        in a kLoop fusion, so the robust signal is simply the max integer
        constant in the condition (it is the bound; other constants are 0/1
        strides, so max() is correct and verified against known layer/chunk
        counts in the dry-run tests)."""
        consts = []
        for name, rtype, opcode, rest in self.comps.get(cond, []):
            if opcode == "constant":
                m = re.match(r"(\d+)\)", rest)
                if m:
                    consts.append(int(m.group(1)))
        return max(consts) if consts else 1

    def _multipliers(self) -> Dict[str, int]:
        parents: Dict[str, Tuple[str, int]] = {}
        for comp, instrs in self.comps.items():
            for name, rtype, opcode, rest in instrs:
                if opcode == "while":
                    m = _WHILE_ATTR.search(rest)
                    if m:
                        cond, body = m.groups()
                        trip = self._trip_count(cond)
                        parents[body] = (comp, trip)
                        parents[cond] = (comp, trip)
                else:
                    for callee in _CALL_ATTR.findall(rest):
                        parents.setdefault(callee, (comp, 1))

        mult: Dict[str, int] = {}

        def resolve(name: str, depth=0) -> int:
            if name in mult:
                return mult[name]
            if depth > 64 or name not in parents:
                mult[name] = 1
                return 1
            parent, trip = parents[name]
            mult[name] = resolve(parent, depth + 1) * trip
            return mult[name]

        for name in self.comps:
            resolve(name)
        self._parents = parents
        return mult

    def _inlined(self) -> set:
        """Computations whose bytes are represented by a caller instruction
        (fusion bodies, reducers, sort comparators — anything reached via
        calls=/to_apply= rather than while control flow)."""
        out = set()
        for comp, instrs in self.comps.items():
            for name, rtype, opcode, rest in instrs:
                if opcode != "while":
                    for callee in _CALL_ATTR.findall(rest):
                        out.add(callee)
        return out

    # ------------------------------------------------------------------
    def flops(self) -> float:
        total = 0.0
        for comp, instrs in self.comps.items():
            m = self.mult.get(comp, 1)
            for name, rtype, opcode, rest in instrs:
                if opcode != "dot":
                    continue
                dims = _type_dims(rtype)
                if dims is None:
                    continue
                result_elems = 1
                for d in dims:
                    result_elems *= d
                contracted = 1
                ops = _OPERAND_RE.findall(rest.split("),")[0])
                cm = _DOT_LHS_C.search(rest)
                if ops and cm and cm.group(1):
                    lhs_dims = _type_dims(self.types.get(ops[0], ""))
                    if lhs_dims:
                        for i in cm.group(1).split(","):
                            i = int(i)
                            if i < len(lhs_dims):
                                contracted *= lhs_dims[i]
                total += 2.0 * result_elems * contracted * m
        return total

    def bytes_accessed(self) -> float:
        inlined = self._inlined()
        total = 0.0
        for comp, instrs in self.comps.items():
            if comp in inlined:
                continue
            m = self.mult.get(comp, 1)
            for name, rtype, opcode, rest in instrs:
                if opcode in _SKIP_BYTES_OPS:
                    continue
                b = _type_bytes(rtype)
                # + operand reads (first few named operands)
                for op in _OPERAND_RE.findall(rest.split(")", 1)[0])[:6]:
                    b += _type_bytes(self.types.get(op, ""))
                total += b * m
        return total

    def collectives(self) -> dict:
        bytes_by_kind: Dict[str, float] = collections.defaultdict(float)
        count_by_kind: Dict[str, int] = collections.defaultdict(int)
        for comp, instrs in self.comps.items():
            m = self.mult.get(comp, 1)
            for name, rtype, opcode, rest in instrs:
                kind = opcode[:-6] if opcode.endswith("-start") else opcode
                if kind not in _COLL_KINDS or opcode.endswith("-done"):
                    continue
                b = _type_bytes(rtype)
                if opcode.endswith("-start"):
                    b //= 2          # async start result = (operand, result)
                factor = _FACTORS[kind]
                if kind == "reduce-scatter":
                    g = _group_size(rest)
                    factor = float(g) if g else 8.0
                bytes_by_kind[kind] += b * factor * m
                count_by_kind[kind] += m
        total = sum(bytes_by_kind.values())
        return {
            "bytes_by_kind": dict(bytes_by_kind),
            "count_by_kind": dict(count_by_kind),
            "total_bytes_per_device": total,
            "summary": {k: f"{v:.3e}" for k, v in bytes_by_kind.items()},
        }


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return 0


def compute_stats(hlo: str) -> dict:
    mod = Module(hlo)
    return {"flops_per_device": mod.flops(),
            "bytes_per_device_est": mod.bytes_accessed()}


def collective_stats(hlo: str) -> dict:
    return Module(hlo).collectives()


def analyze(hlo: str) -> dict:
    mod = Module(hlo)
    return {"flops_per_device": mod.flops(),
            "bytes_per_device_est": mod.bytes_accessed(),
            "collectives": mod.collectives()}
