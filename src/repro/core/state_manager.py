"""Client state manager for stateful FL algorithms (paper §3.4).

Simulating M stateful clients needs O(s_d · M) state which cannot live in
accelerator (or even host) memory at scale; Parrot's manager keeps a bounded
in-memory working set and spills the rest to disk, loading each client's
state on demand when an executor begins simulating it.  Memory becomes
O(s_d · K) (one live state per executor) and disk O(s_d · M) — Table 1.

Multi-host design: client ids are hash-partitioned across hosts
(``owner_host``); each host's manager only ever holds its shard, so the
aggregate footprint scales with hosts.  The manager is checkpointable
(incremental: only dirty states are rewritten) for fault tolerance.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import pickle
import shutil
import tempfile
import threading
from typing import Any, Dict, Iterable, List, Optional

import jax
import numpy as np


def owner_host(client_id: int, n_hosts: int) -> int:
    """Deterministic hash partition of client state ownership."""
    h = hashlib.blake2s(str(client_id).encode(), digest_size=4).digest()
    return int.from_bytes(h, "little") % max(n_hosts, 1)


def _tree_bytes(tree: Any) -> int:
    return sum(a.nbytes for a in jax.tree.leaves(tree)
               if hasattr(a, "nbytes"))


class ClientStateManager:
    """LRU-bounded in-memory store with disk spill.

    Parameters
    ----------
    spill_dir: directory for spilled / checkpointed state files.
    memory_budget_bytes: in-memory working-set bound; 0 -> unbounded
        (useful for measuring the no-manager baseline in benchmarks).
    """

    def __init__(self, spill_dir: str, memory_budget_bytes: int = 1 << 28,
                 host: int = 0, n_hosts: int = 1):
        self.spill_dir = spill_dir
        self.memory_budget = memory_budget_bytes
        self.host = host
        self.n_hosts = n_hosts
        os.makedirs(spill_dir, exist_ok=True)
        self._mem: "collections.OrderedDict[int, Any]" = collections.OrderedDict()
        self._mem_bytes = 0
        self._dirty: set = set()
        self._on_disk: set = set()
        self._lock = threading.RLock()
        self.stats = {"hits": 0, "misses": 0, "spills": 0, "loads": 0}

    # ------------------------------------------------------------------ io
    def _path(self, client: int) -> str:
        return os.path.join(self.spill_dir, f"client_{client}.pkl")

    def _spill_one(self) -> None:
        client, tree = self._mem.popitem(last=False)          # LRU eviction
        self._mem_bytes -= _tree_bytes(tree)
        if client in self._dirty:
            self._write(client, tree)
            self._dirty.discard(client)
        self.stats["spills"] += 1

    def _write(self, client: int, tree: Any) -> None:
        path = self._path(client)
        fd, tmp = tempfile.mkstemp(dir=self.spill_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(jax.tree.map(np.asarray, tree), f,
                            protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)                             # atomic
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._on_disk.add(client)

    def _read(self, client: int) -> Any:
        with open(self._path(client), "rb") as f:
            return pickle.load(f)

    # ----------------------------------------------------------------- api
    def save(self, client: int, state: Any, keep_device: bool = False) -> None:
        """``Save_State`` in Algorithm 2.

        ``keep_device=True`` stores the state's arrays as they are —
        device-resident jax arrays from a pinned executor stay on the
        device (no blocking host copy on the dispatch path); they are
        materialised to host numpy only if/when the LRU spills or a
        checkpoint flushes them."""
        assert owner_host(client, self.n_hosts) == self.host or self.n_hosts == 1, \
            f"client {client} not owned by host {self.host}"
        with self._lock:
            if not keep_device:
                state = jax.tree.map(np.asarray, state)
            if client in self._mem:
                self._mem_bytes -= _tree_bytes(self._mem.pop(client))
            self._mem[client] = state
            self._mem_bytes += _tree_bytes(state)
            self._dirty.add(client)
            while self.memory_budget and self._mem_bytes > self.memory_budget \
                    and len(self._mem) > 1:
                self._spill_one()

    def load(self, client: int, default: Any = None) -> Any:
        """``Load_State`` in Algorithm 2 (LRU touch)."""
        with self._lock:
            if client in self._mem:
                self.stats["hits"] += 1
                self._mem.move_to_end(client)
                return self._mem[client]
            if client in self._on_disk:
                self.stats["misses"] += 1
                self.stats["loads"] += 1
                tree = self._read(client)
                self._mem[client] = tree
                self._mem_bytes += _tree_bytes(tree)
                while self.memory_budget and self._mem_bytes > self.memory_budget \
                        and len(self._mem) > 1:
                    self._spill_one()
                return tree
            return default

    def save_many(self, states: Dict[int, Any],
                  keep_device: bool = False) -> None:
        """Batched ``Save_State`` for a block of B clients (one lock trip —
        the compiled-engine executor writes a whole vmapped block back in
        one call; the RLock makes the nested per-client saves free)."""
        with self._lock:
            for client, state in states.items():
                self.save(client, state, keep_device=keep_device)

    def load_many(self, clients: Iterable[int], default: Any = None,
                  device: Any = None) -> List[Any]:
        """Batched ``Load_State``: one state per client, in order, under a
        single lock acquisition (the executor stacks the results for the
        vmapped scan).  ``device`` places each loaded state onto the
        requesting executor's device (host→device for spilled numpy states,
        a direct D2D copy for states another executor left resident
        elsewhere, and a no-op for states already home)."""
        with self._lock:
            out = [self.load(client, default) for client in clients]
        if device is not None:
            out = [s if s is None else jax.device_put(s, device)
                   for s in out]
        return out

    def __contains__(self, client: int) -> bool:
        return client in self._mem or client in self._on_disk

    def known_clients(self) -> List[int]:
        return sorted(set(self._mem) | self._on_disk)

    @property
    def memory_bytes(self) -> int:
        return self._mem_bytes

    def disk_bytes(self) -> int:
        tot = 0
        for c in self._on_disk:
            try:
                tot += os.path.getsize(self._path(c))
            except OSError:
                pass
        return tot

    # -------------------------------------------------------- checkpointing
    def checkpoint(self, ckpt_dir: str) -> None:
        """Flush dirty states to disk and hard-link the shard into a
        checkpoint directory (incremental: clean states are only linked)."""
        os.makedirs(ckpt_dir, exist_ok=True)
        with self._lock:
            for client in list(self._dirty):
                self._write(client, self._mem[client])
            self._dirty.clear()
            manifest = {"host": self.host, "n_hosts": self.n_hosts,
                        "clients": sorted(self._on_disk)}
            for client in self._on_disk:
                dst = os.path.join(ckpt_dir, f"client_{client}.pkl")
                if os.path.exists(dst):
                    os.unlink(dst)
                try:
                    os.link(self._path(client), dst)
                except OSError:
                    shutil.copy2(self._path(client), dst)
            with open(os.path.join(ckpt_dir, f"state_manifest_{self.host}.json"),
                      "w") as f:
                json.dump(manifest, f)

    def restore(self, ckpt_dir: str) -> int:
        """Re-adopt a checkpointed shard; returns number of clients restored."""
        path = os.path.join(ckpt_dir, f"state_manifest_{self.host}.json")
        if not os.path.exists(path):
            return 0
        with open(path) as f:
            manifest = json.load(f)
        n = 0
        with self._lock:
            # adopt-exactly: drop any state not in the manifest (a later
            # round's leftovers would otherwise leak into the replay)
            self._mem.clear()
            self._mem_bytes = 0
            self._dirty.clear()
            for client in list(self._on_disk):
                if client not in set(manifest["clients"]):
                    try:
                        os.unlink(self._path(client))
                    except OSError:
                        pass
            self._on_disk.clear()
            for client in manifest["clients"]:
                src = os.path.join(ckpt_dir, f"client_{client}.pkl")
                if not os.path.exists(src):
                    continue
                dst = self._path(client)
                if os.path.abspath(src) != os.path.abspath(dst):
                    shutil.copy2(src, dst)
                self._on_disk.add(client)
                n += 1
        return n

    def rebalance(self, new_n_hosts: int, peers: Dict[int, "ClientStateManager"]) -> int:
        """Elastic membership change: re-hash ownership and hand off states
        that now belong to other hosts.  Returns number moved."""
        moved = 0
        with self._lock:
            for client in self.known_clients():
                new_owner = owner_host(client, new_n_hosts)
                if new_owner == self.host:
                    continue
                state = self.load(client)
                peers[new_owner].save(client, state)
                if client in self._mem:
                    self._mem_bytes -= _tree_bytes(self._mem.pop(client))
                if client in self._on_disk:
                    self._on_disk.discard(client)
                    try:
                        os.unlink(self._path(client))
                    except OSError:
                        pass
                self._dirty.discard(client)
                moved += 1
        self.n_hosts = new_n_hosts
        return moved
