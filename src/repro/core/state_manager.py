"""Client state manager for stateful FL algorithms (paper §3.4).

Simulating M stateful clients needs O(s_d · M) state which cannot live in
accelerator (or even host) memory at scale; Parrot's manager keeps a bounded
in-memory working set and spills the rest below.  Memory becomes O(s_d · K)
(one live state per executor) and disk O(s_d · M) — Table 1.

Million-client layout (DESIGN.md §11) — three tiers, shard-granular below
tier 0:

  tier 0  per-client LRU of live states (possibly device-resident via
          ``keep_device=True``), bounded by ``memory_budget_bytes``.
  tier 1  host-RAM shard cache: evicted states pack into fixed-size shards
          of ``shard_clients`` consecutive ids (``shard_of = id //
          shard_clients``), LRU-bounded by ``shard_cache_bytes``.
  disk    one pickle file *per shard*, not per client — 1M clients at the
          default shard size is ~16k inodes, not 1M.

Spilled dirty states are content-digested: an eviction whose bytes already
match the on-disk copy never rewrites it, and clean evictions never touch
disk at all (their value always has a live copy in a lower tier).
``prefetch(ids)`` — keyed by the engine's schedule (the next chunk's client
ids) — stages whole shards into tier 1 ahead of the executor reaching them,
so state loads overlap compute on the virtual clock and never double-read
the disk.

Multi-host design: client ids are hash-partitioned across hosts
(``owner_host``); each host's manager only ever holds its shard, so the
aggregate footprint scales with hosts.  The manager is checkpointable
(incremental and shard-granular: only dirty shards are rewritten, clean
ones are hard-linked) for fault tolerance.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import pickle
import shutil
import tempfile
import threading
from typing import Any, Dict, Iterable, List, Optional

import jax
import numpy as np


def owner_host(client_id: int, n_hosts: int) -> int:
    """Deterministic hash partition of client state ownership."""
    h = hashlib.blake2s(str(client_id).encode(), digest_size=4).digest()
    return int.from_bytes(h, "little") % max(n_hosts, 1)


def _tree_bytes(tree: Any) -> int:
    return sum(a.nbytes for a in jax.tree.leaves(tree)
               if hasattr(a, "nbytes"))


def _host_tree(tree: Any) -> Any:
    return jax.tree.map(np.asarray, tree)


def _digest(host_tree: Any) -> bytes:
    return hashlib.blake2s(
        pickle.dumps(host_tree, protocol=pickle.HIGHEST_PROTOCOL)).digest()


class ClientStateManager:
    """Tiered LRU store: per-client RAM over shard-file disk spill.

    Parameters
    ----------
    spill_dir: directory for spilled / checkpointed shard files.
    memory_budget_bytes: tier-0 (per-client) working-set bound; 0 ->
        unbounded (useful for measuring the no-manager baseline).
    shard_clients: ids per shard file (``shard = id // shard_clients``).
    shard_cache_bytes: tier-1 (host-RAM shard cache) bound; None mirrors
        ``memory_budget_bytes``, 0 -> unbounded.
    """

    def __init__(self, spill_dir: str, memory_budget_bytes: int = 1 << 28,
                 host: int = 0, n_hosts: int = 1,
                 shard_clients: int = 64,
                 shard_cache_bytes: Optional[int] = None):
        self.spill_dir = spill_dir
        self.memory_budget = memory_budget_bytes
        self.host = host
        self.n_hosts = n_hosts
        self.shard_clients = max(int(shard_clients), 1)
        self.shard_cache_budget = (memory_budget_bytes
                                   if shard_cache_bytes is None
                                   else shard_cache_bytes)
        os.makedirs(spill_dir, exist_ok=True)
        # tier 0: client -> state (LRU; device arrays allowed)
        self._mem: "collections.OrderedDict[int, Any]" = collections.OrderedDict()
        self._mem_bytes = 0
        self._dirty: set = set()
        # tier 1: shard id -> {client: host state} (LRU over shards)
        self._shards: "collections.OrderedDict[int, Dict[int, Any]]" = \
            collections.OrderedDict()
        self._shard_bytes = 0
        self._shard_dirty: set = set()
        # disk: shard id -> clients present in the shard file
        self._disk_clients: Dict[int, set] = {}
        # content digests: on-disk value per client, and values staged in
        # tier 1 awaiting a flush (promoted to ``_digests`` on write)
        self._digests: Dict[int, bytes] = {}
        self._staged: Dict[int, bytes] = {}
        self._lock = threading.RLock()
        self.stats = {"hits": 0, "misses": 0, "spills": 0, "loads": 0,
                      "disk_loads": 0, "disk_writes": 0, "prefetched": 0,
                      "skipped_rewrites": 0}

    # ------------------------------------------------------------------ io
    def shard_of(self, client: int) -> int:
        return int(client) // self.shard_clients

    def _shard_path(self, sid: int) -> str:
        return os.path.join(self.spill_dir,
                            f"shard_{self.host}_{sid:06d}.pkl")

    def _read_shard_file(self, sid: int) -> Dict[int, Any]:
        with open(self._shard_path(sid), "rb") as f:
            return pickle.load(f)

    def _write_shard_file(self, sid: int, contents: Dict[int, Any]) -> None:
        path = self._shard_path(sid)
        fd, tmp = tempfile.mkstemp(dir=self.spill_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(contents, f, protocol=pickle.HIGHEST_PROTOCOL)
            os.replace(tmp, path)                             # atomic
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.stats["disk_writes"] += 1

    def _flush_shard(self, sid: int) -> None:
        """Write one dirty shard: merge its RAM entries over whatever else
        the shard file holds (RAM is newer), one file write for the whole
        shard."""
        ram = self._shards.get(sid, {})
        on_disk = self._disk_clients.get(sid, set())
        merged = dict(ram)
        missing = on_disk - merged.keys()
        if missing:
            try:
                old = self._read_shard_file(sid)
            except OSError:
                old = {}
            for c in missing:
                if c in old:
                    merged[c] = old[c]
        if merged:
            self._write_shard_file(sid, merged)
            self._disk_clients[sid] = set(merged)
        else:
            try:
                os.unlink(self._shard_path(sid))
            except OSError:
                pass
            self._disk_clients.pop(sid, None)
        for c in ram:
            if c in self._staged:
                self._digests[c] = self._staged.pop(c)
        self._shard_dirty.discard(sid)

    def _load_shard(self, sid: int) -> None:
        """Read one shard file into tier 1 (RAM entries win — they are
        staged newer values)."""
        try:
            disk = self._read_shard_file(sid)
        except OSError:
            return
        self.stats["disk_loads"] += 1
        ram = self._shards.get(sid)
        if ram is None:
            ram = self._shards[sid] = {}
        for c, tree in disk.items():
            if c not in ram:
                ram[c] = tree
                self._shard_bytes += _tree_bytes(tree)
        self._shards.move_to_end(sid)

    def _evict_shards(self) -> None:
        while (self.shard_cache_budget
               and self._shard_bytes > self.shard_cache_budget
               and self._shards):
            sid = next(iter(self._shards))                     # LRU shard
            if sid in self._shard_dirty:
                self._flush_shard(sid)
            contents = self._shards.pop(sid)
            self._shard_bytes -= sum(_tree_bytes(t)
                                     for t in contents.values())

    def _stage(self, client: int, host_tree: Any, dig: bytes) -> None:
        """Place one host state into its tier-1 shard and mark the shard
        dirty (it now differs from its file)."""
        sid = self.shard_of(client)
        sh = self._shards.get(sid)
        if sh is None:
            sh = self._shards[sid] = {}
        if client in sh:
            self._shard_bytes -= _tree_bytes(sh[client])
        sh[client] = host_tree
        self._shard_bytes += _tree_bytes(host_tree)
        self._shards.move_to_end(sid)
        self._shard_dirty.add(sid)
        self._staged[client] = dig
        self._evict_shards()

    def _spill_one(self) -> None:
        """Evict the LRU tier-0 state.  Clean states drop (their value is
        already live in a lower tier — never touches disk); dirty states
        content-digest first and skip the restage when the bytes already
        match what the lower tiers hold (ISSUE 8 satellite: no redundant
        rewrite of byte-identical state)."""
        client, tree = self._mem.popitem(last=False)          # LRU eviction
        self._mem_bytes -= _tree_bytes(tree)
        self.stats["spills"] += 1
        if client not in self._dirty:
            return
        self._dirty.discard(client)
        host_tree = _host_tree(tree)
        dig = _digest(host_tree)
        pending = self._staged.get(client)
        if pending is not None:
            if pending == dig:                 # staged copy already matches
                self.stats["skipped_rewrites"] += 1
                return
        elif self._digests.get(client) == dig:  # on-disk copy matches
            self.stats["skipped_rewrites"] += 1
            return
        self._stage(client, host_tree, dig)

    # ----------------------------------------------------------------- api
    def save(self, client: int, state: Any, keep_device: bool = False) -> None:
        """``Save_State`` in Algorithm 2.

        ``keep_device=True`` stores the state's arrays as they are —
        device-resident jax arrays from a pinned executor stay on the
        device (no blocking host copy on the dispatch path); they are
        materialised to host numpy only if/when the LRU spills or a
        checkpoint flushes them."""
        assert owner_host(client, self.n_hosts) == self.host or self.n_hosts == 1, \
            f"client {client} not owned by host {self.host}"
        with self._lock:
            if not keep_device:
                state = _host_tree(state)
            if client in self._mem:
                self._mem_bytes -= _tree_bytes(self._mem.pop(client))
            self._mem[client] = state
            self._mem_bytes += _tree_bytes(state)
            self._dirty.add(client)
            while self.memory_budget and self._mem_bytes > self.memory_budget \
                    and len(self._mem) > 1:
                self._spill_one()

    def load(self, client: int, default: Any = None) -> Any:
        """``Load_State`` in Algorithm 2 (LRU touch).  Misses fill from the
        shard RAM tier, then from the shard file (which stages the whole
        shard in tier 1 — the read granularity prefetch exploits)."""
        with self._lock:
            if client in self._mem:
                self.stats["hits"] += 1
                self._mem.move_to_end(client)
                return self._mem[client]
            sid = self.shard_of(client)
            sh = self._shards.get(sid)
            if sh is None or client not in sh:
                if client in self._disk_clients.get(sid, ()):
                    self._load_shard(sid)
                    sh = self._shards.get(sid)
            if sh is not None and client in sh:
                self.stats["misses"] += 1
                self.stats["loads"] += 1
                tree = sh[client]
                self._shards.move_to_end(sid)
                self._mem[client] = tree
                self._mem_bytes += _tree_bytes(tree)
                while self.memory_budget \
                        and self._mem_bytes > self.memory_budget \
                        and len(self._mem) > 1:
                    self._spill_one()
                self._evict_shards()
                return tree
            return default

    def prefetch(self, clients: Iterable[int]) -> int:
        """Schedule-keyed look-ahead: stage the shards holding ``clients``
        into the RAM tier *without* touching the tier-0 LRU, so the
        upcoming ``load_many`` never reads disk for them.  Returns the
        number of ids actually staged (already-resident ids cost
        nothing — prefetched ids never double-load)."""
        staged = 0
        with self._lock:
            for client in clients:
                client = int(client)
                if client in self._mem:
                    continue
                sid = self.shard_of(client)
                sh = self._shards.get(sid)
                if sh is not None and client in sh:
                    continue
                if client in self._disk_clients.get(sid, ()):
                    self._load_shard(sid)
                    if client in self._shards.get(sid, ()):
                        staged += 1
            if staged:
                self.stats["prefetched"] += staged
                self._evict_shards()
        return staged

    def save_many(self, states: Dict[int, Any],
                  keep_device: bool = False) -> None:
        """Batched ``Save_State`` for a block of B clients (one lock trip —
        the compiled-engine executor writes a whole vmapped block back in
        one call; the RLock makes the nested per-client saves free)."""
        with self._lock:
            for client, state in states.items():
                self.save(client, state, keep_device=keep_device)

    def load_many(self, clients: Iterable[int], default: Any = None,
                  device: Any = None) -> List[Any]:
        """Batched ``Load_State``: one state per client, in order, under a
        single lock acquisition (the executor stacks the results for the
        vmapped scan).  ``device`` places each loaded state onto the
        requesting executor's device (host→device for spilled numpy states,
        a direct D2D copy for states another executor left resident
        elsewhere, and a no-op for states already home)."""
        with self._lock:
            out = [self.load(client, default) for client in clients]
        if device is not None:
            out = [s if s is None else jax.device_put(s, device)
                   for s in out]
        return out

    def __contains__(self, client: int) -> bool:
        if client in self._mem:
            return True
        sid = self.shard_of(client)
        return (client in self._shards.get(sid, ())
                or client in self._disk_clients.get(sid, ()))

    def known_clients(self) -> List[int]:
        known = set(self._mem)
        for sh in self._shards.values():
            known.update(sh)
        for clients in self._disk_clients.values():
            known.update(clients)
        return sorted(known)

    @property
    def memory_bytes(self) -> int:
        return self._mem_bytes

    @property
    def shard_ram_bytes(self) -> int:
        return self._shard_bytes

    def disk_bytes(self) -> int:
        tot = 0
        for sid, clients in self._disk_clients.items():
            if not clients:
                continue
            try:
                tot += os.path.getsize(self._shard_path(sid))
            except OSError:
                pass
        return tot

    def stats_snapshot(self) -> Dict[str, float]:
        """Cumulative counters plus current tier byte gauges (the
        ``*_bytes`` keys) — what the server surfaces into
        ``RoundMetrics.extra["state_manager"]`` each round."""
        with self._lock:
            snap: Dict[str, float] = dict(self.stats)
            snap["mem_bytes"] = self._mem_bytes
            snap["shard_ram_bytes"] = self._shard_bytes
            snap["disk_bytes"] = self.disk_bytes()
            return snap

    # -------------------------------------------------------- checkpointing
    def checkpoint(self, ckpt_dir: str) -> None:
        """Flush dirty state shard-granularly and hard-link the shard files
        into a checkpoint directory (incremental: clean shards are only
        linked, and states byte-identical to their durable copy are not
        rewritten)."""
        os.makedirs(ckpt_dir, exist_ok=True)
        with self._lock:
            for client in sorted(self._dirty):
                host_tree = _host_tree(self._mem[client])
                dig = _digest(host_tree)
                pending = self._staged.get(client)
                if pending is not None:
                    if pending == dig:
                        self.stats["skipped_rewrites"] += 1
                        continue
                elif self._digests.get(client) == dig:
                    self.stats["skipped_rewrites"] += 1
                    continue
                self._stage(client, host_tree, dig)
            self._dirty.clear()
            for sid in sorted(self._shard_dirty):
                self._flush_shard(sid)
            manifest = {
                "host": self.host, "n_hosts": self.n_hosts,
                "shard_clients": self.shard_clients,
                "clients": sorted(
                    c for cl in self._disk_clients.values() for c in cl),
                "shards": {str(sid): sorted(cl)
                           for sid, cl in sorted(self._disk_clients.items())
                           if cl},
            }
            for sid, clients in self._disk_clients.items():
                if not clients:
                    continue
                dst = os.path.join(ckpt_dir,
                                   os.path.basename(self._shard_path(sid)))
                if os.path.exists(dst):
                    os.unlink(dst)
                try:
                    os.link(self._shard_path(sid), dst)
                except OSError:
                    shutil.copy2(self._shard_path(sid), dst)
            with open(os.path.join(ckpt_dir, f"state_manifest_{self.host}.json"),
                      "w") as f:
                json.dump(manifest, f)
            self._evict_shards()

    def restore(self, ckpt_dir: str) -> int:
        """Re-adopt a checkpointed shard set; returns number of clients
        restored."""
        path = os.path.join(ckpt_dir, f"state_manifest_{self.host}.json")
        if not os.path.exists(path):
            return 0
        with open(path) as f:
            manifest = json.load(f)
        with self._lock:
            # adopt-exactly: drop any state not in the manifest (a later
            # round's leftovers would otherwise leak into the replay)
            self._mem.clear()
            self._mem_bytes = 0
            self._dirty.clear()
            self._shards.clear()
            self._shard_bytes = 0
            self._shard_dirty.clear()
            self._digests.clear()
            self._staged.clear()
            for sid in list(self._disk_clients):
                try:
                    os.unlink(self._shard_path(sid))
                except OSError:
                    pass
            self._disk_clients.clear()
            self.shard_clients = int(manifest.get("shard_clients",
                                                  self.shard_clients))
            n = 0
            for sid_str, clients in manifest.get("shards", {}).items():
                sid = int(sid_str)
                src = os.path.join(ckpt_dir,
                                   os.path.basename(self._shard_path(sid)))
                if not os.path.exists(src):
                    continue
                dst = self._shard_path(sid)
                # checkpoints hard-link shard files, so a restore into the
                # original spill dir may find dst already IS src (same
                # inode) — copying onto itself would raise SameFileError
                if not (os.path.exists(dst) and os.path.samefile(src, dst)):
                    shutil.copy2(src, dst)
                self._disk_clients[sid] = set(int(c) for c in clients)
                n += len(clients)
        return n

    def rebalance(self, new_n_hosts: int, peers: Dict[int, "ClientStateManager"]) -> int:
        """Elastic membership change: re-hash ownership and hand off states
        that now belong to other hosts.  Returns number moved."""
        moved = 0
        with self._lock:
            for client in self.known_clients():
                new_owner = owner_host(client, new_n_hosts)
                if new_owner == self.host:
                    continue
                state = self.load(client)
                peers[new_owner].save(client, state)
                self._discard(client)
                moved += 1
            for sid in sorted(self._shard_dirty):
                self._flush_shard(sid)
        self.n_hosts = new_n_hosts
        return moved

    def _discard(self, client: int) -> None:
        """Forget one client everywhere (rebalance hand-off)."""
        if client in self._mem:
            self._mem_bytes -= _tree_bytes(self._mem.pop(client))
        self._dirty.discard(client)
        sid = self.shard_of(client)
        sh = self._shards.get(sid)
        if sh is not None and client in sh:
            self._shard_bytes -= _tree_bytes(sh.pop(client))
        on_disk = self._disk_clients.get(sid)
        if on_disk is not None and client in on_disk:
            on_disk.discard(client)
            self._shard_dirty.add(sid)   # file must shed the moved entry
        self._digests.pop(client, None)
        self._staged.pop(client, None)
