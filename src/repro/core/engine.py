"""Event-driven round engines: pluggable BSP / semi-sync / async synchronization.

``ParrotServer.run_round`` delegates to a :class:`RoundEngine`.  All three
engines speak the same vocabulary — executor *chunks* complete as events on
the shared :class:`~repro.core.clock.VirtualClock`, failures are events,
partials cross the comm layer on the flat wire format — and differ only in
*when the server folds and updates* (DESIGN.md §3):

``bsp``
    The paper's Algorithm 2, bit-exact with the pre-engine loop: every
    executor drains its whole queue, the round barrier collects the K
    partials in executor order, round time is ``max_k Σ T̂``.  Failures
    re-run the dead executor's remaining clients on the survivors and
    shrink K (elastic membership); speculative backup tasks duplicate the
    predicted-slowest tail.

``semi-sync``
    Over-selects clients, derives a virtual-time deadline from the fitted
    workload model, folds whatever chunk partials have landed by the
    deadline and carries unfinished tasks into the next round's pool —
    stragglers lose work share instead of gating the round.

``async``
    No barrier at all: executors emit a partial per chunk as they complete;
    the server folds each one as it lands, discounted by the bounded-
    staleness weight γ = 1/(1+λ·s) where s is the number of server updates
    since the chunk's payload was broadcast.  A model update fires every
    ``goal`` folded clients; idle executors steal chunks from the
    predicted-slowest queue.  Round time becomes the virtual span between
    updates — the straggler's tail is hidden, not scheduled around.

The semi-sync and async engines run a deterministic discrete-event
simulation: chunks execute lazily at their virtual dispatch time (every
earlier event has already been processed, so each chunk sees the params
version and queue state a causally-correct parallel run would show it), and
event order is a pure function of the per-chunk virtual durations.

With a :class:`~repro.core.network.NetworkModel` / ``ClientAvailability``
on the server (DESIGN.md §9) the same event queue also carries comm: a
chunk is busy for ``download + compute``, its upload ships as a
``chunk_arrived`` :class:`~repro.core.network.CommEvent` priced
``latency + wire_bytes/uplink`` at the partial's *achieved* (compressed)
size, and folds only when that event pops — uploads overlap the next
chunk, semi-sync deadlines and async staleness include comm delay, and
offline clients drop through each engine's existing re-run path.  Both
default to None, which keeps every code path below bit-exact with the
comm-free engines.
"""
from __future__ import annotations

import concurrent.futures as cf
import math
import time
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.core.aggregation import (merge_partials, scale_partial,
                                    staleness_weight, wire_bytes)
from repro.core.clock import VirtualClock
from repro.core.executor import ExecutorFailure, ExecutorReport
from repro.core.faults import FaultCounters, scale_report
from repro.core.network import CommEvent
from repro.core.scheduler import (ClientTask, Schedule, oracle_makespan,
                                  pick_steal_victim, predict_remaining,
                                  predict_span, prefetch_ids,
                                  rebalance_queues)
from repro.core.workload import RunRecord


def _ship_partial(srv, executor: int, compressed: Dict) -> Dict:
    """One partial across the comm layer: send -> poll (-> blocking recv on
    transports without immediate local delivery) -> decompress.  The copy
    that reaches aggregation is the one that crossed the wire, keeping
    error-feedback residuals in sync — the single definition both the
    comm-free fold path and the network pricer go through."""
    srv.comm.executor_send(executor, compressed, tag="partial")
    wire = srv.comm.poll(executor, tag="partial")
    if wire is None:
        wire = srv.comm.recv_from_executor(executor, tag="partial")
    return srv._maybe_decompress(wire)


def _tasks_of(srv, clients) -> List[ClientTask]:
    """Rebuild ClientTasks from client ids (fault re-run pools carry ids —
    the sample counts come from the population registry, so no client
    batches materialise here)."""
    n_of = srv.population.n_samples
    return [ClientTask(int(c), n_of(int(c))) for c in clients]


def _host_tree(tree):
    """Device arrays -> host numpy for checkpoint blobs; everything else
    (floats, FlatLayout leaves, RunRecords) passes through untouched."""
    if tree is None:
        return None
    return jax.tree.map(
        lambda x: np.asarray(x) if hasattr(x, "sharding") else x, tree)


def _host_report(rep: ExecutorReport) -> ExecutorReport:
    """Host-side copy of an in-flight chunk report (jax.tree.map does not
    descend into the dataclass, so the partial converts explicitly)."""
    return ExecutorReport(
        executor=rep.executor, partial=_host_tree(rep.partial),
        records=list(rep.records), virtual_time=rep.virtual_time,
        wall_time=rep.wall_time, n_tasks=rep.n_tasks,
        completed_clients=list(rep.completed_clients),
        compiles=rep.compiles)


@dataclass
class _ExecState:
    """Per-executor bookkeeping inside the discrete-event simulation."""
    queue: List[ClientTask] = field(default_factory=list)
    t: float = 0.0            # virtual time of the last completed chunk
    busy_until: float = 0.0   # completion time of the in-flight chunk
    inflight: bool = False
    offset: int = 0           # cumulative dispatched-task index (fail_at)
    stopped: bool = False     # semi-sync: hit the deadline, queue carried
    dead: bool = False        # failure event pushed but not yet processed


class _NetSim:
    """Per-round comm/availability pricing (DESIGN.md §9).

    Created only when the server carries a :class:`NetworkModel` or a
    :class:`ClientAvailability` — the engines keep their pre-network code
    paths bit-exactly otherwise.  ``t0`` anchors this round's local event
    times on the server's cumulative virtual axis (``srv.virtual_now``);
    the async engine's clock is already cumulative, so it anchors at 0.
    """

    def __init__(self, srv, t0: float):
        self.srv = srv
        self.net = srv.network
        self.avail = srv.availability
        self.t0 = t0
        self.payload_nbytes = srv._last_payload_nbytes
        self.time_up = 0.0
        self.time_down = 0.0
        self.bytes_up = 0
        self.dropped = 0

    def set_payload(self, payload: Dict) -> None:
        """Size the round's broadcast (what downloads are priced at)."""
        from repro.core.aggregation import payload_bytes
        self.payload_nbytes = payload_bytes(payload)
        self.srv._last_payload_nbytes = self.payload_nbytes

    # -- pricing -----------------------------------------------------------
    def down(self, clients) -> float:
        """Price one model download to a chunk's clients (accounted)."""
        if self.net is None:
            return 0.0
        t = self.net.download_time(clients, self.payload_nbytes)
        self.time_down += t
        return t

    def up(self, clients, nbytes: int) -> float:
        """Price one partial upload at its achieved wire size (accounted)."""
        if self.net is None:
            return 0.0
        t = self.net.upload_time(clients, nbytes)
        self.time_up += t
        self.bytes_up += int(nbytes)
        return t

    def comm_pred(self, clients) -> float:
        """Predicted chunk comm span: broadcast down + upload estimated at
        the compressor's last achieved wire ratio."""
        if self.net is None:
            return 0.0
        return self.net.chunk_comm_time(
            clients, self.payload_nbytes,
            int(self.payload_nbytes * self.srv._wire_ratio))

    def ship(self, executor: int, partial: Dict) -> Tuple[Dict, int]:
        """Compress, measure the achieved wire size (what the upload is
        priced at), update the server's compression ratio for future
        predictions, then cross the wire via ``_ship_partial``."""
        srv = self.srv
        comp = srv._maybe_compress(partial, executor)
        nb = wire_bytes(comp)
        raw = wire_bytes(partial)
        if raw > 0:
            srv._wire_ratio = nb / raw
        return _ship_partial(srv, executor, comp), nb

    def push_chunk(self, clock: VirtualClock, rep: ExecutorReport,
                   start: float, done_data, record, version: int,
                   fi=None, counters: Optional[FaultCounters] = None,
                   overlap_from: Optional[float] = None) -> float:
        """Push one completed chunk's comm-priced event pair: ``chunk_done``
        at download+compute (the executor frees; ``done_data`` is the
        engine's handler payload) and — when the chunk did work — a
        ``chunk_arrived`` :class:`CommEvent` at +upload carrying the wire
        partial.  The single definition both DES engines dispatch through.
        Returns the compute-done time (the executor's ``busy_until``).

        With a :class:`FaultInjector` (``fi``) the upload leg additionally
        sees blackout pauses and the chunk timeout with backed-off re-sends
        (each re-send re-priced through the network model), then mid-upload
        client dropout; a payload lost in transit surfaces as an
        ``upload_lost`` event so each engine routes the clients into its
        own re-run pool.  ``fi=None`` keeps the pricing bit-exact.

        ``overlap_from`` (DESIGN.md §12, ``control.overlap_comm``): the
        virtual time the chunk's payload version was broadcast.  The
        clients' download then overlaps whatever the executor computed
        since — the chunk starts at ``max(start, overlap_from + download)``
        instead of serializing the download into its span.  ``None`` keeps
        the serial pricing bit-exact (the ``down`` read is accounted
        identically either way)."""
        down_s = self.down(rep.completed_clients)
        if overlap_from is None:
            t_c = start + down_s + rep.virtual_time
        else:
            t_c = max(start, overlap_from + down_s) + rep.virtual_time
        clock.push(t_c, "chunk_done", done_data)
        tele = self.srv.telemetry
        if tele is not None and rep.n_tasks:
            # one busy span covers the executor's whole occupancy; the
            # non-compute share (download + in-span wait) rides as down_s
            tele.tracer.span(
                f"exec:{rep.executor}", "chunk", self.t0 + start,
                self.t0 + t_c, cat="busy",
                args={"round": version, "n_tasks": rep.n_tasks,
                      "down_s": max(t_c - rep.virtual_time - start, 0.0)})
            if rep.compiles:
                tele.registry.counter(
                    f"host/exec{rep.executor}/compiles").inc(rep.compiles)
        if rep.n_tasks:
            wirep, nb = self.ship(rep.executor, rep.partial)
            rep.wire_bytes = nb
            b_up0 = self.bytes_up
            up_s = self.up(rep.completed_clients, nb)
            if fi is None:
                t_arr = t_c + up_s
            else:
                # fault queries run on the absolute axis: t0 anchors this
                # round's local event times on srv.virtual_now
                t_abs = fi.price_upload(self.t0 + t_c, up_s, self,
                                        rep.completed_clients, nb, counters,
                                        executor=rep.executor)
                if t_abs is not None and fi.upload_lost(
                        rep.completed_clients, self.t0 + t_c, t_abs):
                    t_abs = None
                if t_abs is None:
                    if tele is not None:
                        tele.tracer.span(
                            f"exec:{rep.executor}:up", "upload",
                            self.t0 + t_c, self.t0 + t_c + up_s, cat="comm",
                            args={"round": version, "wire_bytes": nb,
                                  "billed_bytes": float(self.bytes_up
                                                        - b_up0),
                                  "lost": True})
                    clock.push(t_c, "upload_lost",
                               (rep.executor,
                                tuple(rep.completed_clients)))
                    return t_c
                t_arr = t_abs - self.t0
            if tele is not None:
                # billed_bytes includes timeout re-sends (retries re-bill)
                tele.tracer.span(
                    f"exec:{rep.executor}:up", "upload",
                    self.t0 + t_c, self.t0 + t_arr, cat="comm",
                    args={"round": version, "wire_bytes": nb,
                          "billed_bytes": float(self.bytes_up - b_up0)})
            clock.push(t_arr, "chunk_arrived", CommEvent(
                executor=rep.executor, partial=wirep, record=record,
                n_tasks=rep.n_tasks,
                completed_clients=tuple(rep.completed_clients),
                wire_bytes=nb, version=version, t_sent=t_c))
        return t_c

    # -- availability ------------------------------------------------------
    def split_available(self, tasks: List[ClientTask], start_local: float,
                        pred_dur: float
                        ) -> Tuple[List[ClientTask], List[ClientTask]]:
        """(runnable, dropped) at absolute time ``t0 + start_local``: a
        task drops when its client is offline now, or its remaining window
        is predicted too short for the chunk (mid-chunk expiry)."""
        if self.avail is None:
            return list(tasks), []
        t = self.t0 + start_local
        kept, dropped = [], []
        for task in tasks:
            if (self.avail.available(task.client, t)
                    and self.avail.remaining(task.client, t) >= pred_dur):
                kept.append(task)
            else:
                dropped.append(task)
        self.dropped += len(dropped)
        return kept, dropped

    def extra(self) -> Dict[str, float]:
        """Per-round comm-time/bytes + dropout metrics."""
        return {"comm_time_up": self.time_up,
                "comm_time_down": self.time_down,
                "comm_wire_bytes": float(self.bytes_up),
                "dropped_clients": float(self.dropped)}

    def reset_counters(self) -> None:
        """Start a new accounting window (the async engine keeps ONE pricer
        across rounds: chunks dispatched in a round's tail — after its
        metrics were read — bill the next window instead of vanishing)."""
        self.time_up = self.time_down = 0.0
        self.bytes_up = 0
        self.dropped = 0


class RoundEngine:
    """One synchronization mode.  Engines may keep state across rounds (the
    async engine does); a server owns exactly one engine instance.

    Engines with cross-round state implement ``state_dict`` /
    ``load_state_dict`` (plain-data, host-resident blobs) so the checkpoint
    manager can save and deterministically resume them mid-pipeline."""

    mode: str = "?"

    def run_round(self, srv) -> "RoundMetrics":
        raise NotImplementedError

    def state_dict(self) -> Optional[Dict]:
        return None                 # stateless between rounds (BSP)

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if state:
            raise ValueError(f"engine {self.mode!r} cannot restore state")

    # -- shared plumbing ---------------------------------------------------
    def _netsim(self, srv, t0: float) -> Optional[_NetSim]:
        """The round's comm/availability pricer, or None for the (default)
        comm-transparent configuration — in which case every engine takes
        its pre-network code path bit-exactly."""
        if srv.network is None and srv.availability is None:
            return None
        return _NetSim(srv, t0)

    def _fast_forward_empty(self, srv, reselect):
        """Nobody is selectable right now (availability gap): advance the
        server's virtual clock to the next time any client comes online and
        re-select.  Returns (tasks, idle_seconds)."""
        t_next = srv._next_available_time()
        if not math.isfinite(t_next):
            raise RuntimeError("availability trace leaves no client ever "
                               "available again")
        if t_next <= srv.virtual_now:
            return [], 0.0
        idle = t_next - srv.virtual_now
        srv.virtual_now = t_next
        return reselect(), idle

    def _advance_past_gap(self, srv) -> float:
        """Zero-progress round (every task dropped — offline, or online but
        predicted to expire mid-chunk): advance the server's virtual clock
        past the next availability boundary (window start for offline
        clients, window *end* for online ones) or the next round would
        repeat verbatim.  Returns the idle seconds added (0 if no jump)."""
        t_next = srv._next_available_time()
        if not (math.isfinite(t_next) and t_next > srv.virtual_now):
            t_next = srv._next_availability_change()
        if math.isfinite(t_next) and t_next > srv.virtual_now:
            idle = t_next - srv.virtual_now
            srv.virtual_now = t_next
            return idle
        return 0.0

    def _chunk_size(self, srv, override: Optional[int]) -> int:
        if override:
            return max(1, int(override))
        return max(e.client_block for e in srv.executors.values())

    def _wire(self, srv, executor: int, partial: Dict) -> Dict:
        """Ship one partial through the comm layer (compress → send → poll →
        decompress); see ``_ship_partial``."""
        return _ship_partial(srv, executor,
                             srv._maybe_compress(partial, executor))

    def _chunk_record(self, srv, rnd: int, rep: ExecutorReport
                      ) -> Optional[RunRecord]:
        """Per-chunk timing record (workload.py): one (N_total, T̂) pair per
        chunk — what the engines' chunk-granular predictions consume."""
        if rep.n_tasks == 0:
            return None
        n = sum(srv.population.n_samples(c)
                for c in rep.completed_clients)
        return RunRecord(round=rnd, client=rep.completed_clients[0],
                         executor=rep.executor, n_samples=n,
                         time=rep.virtual_time, n_tasks=rep.n_tasks)

    @staticmethod
    def _ctrl(srv):
        """The server's control plane (DESIGN.md §12), or None — in which
        case every controller hook below is skipped bit-exactly."""
        return getattr(srv, "control", None)

    def _gang_wave(self, srv, rnd: int, states: Dict[int, _ExecState],
                   clock: VirtualClock, payload: Dict, chunk: int,
                   candidates: List[int], mk_done,
                   t_base: float = 0.0) -> Set[int]:
        """SPMD gang dispatch of one aligned DES chunk wave (DESIGN.md §12,
        ``control.gang_waves``): when every idle candidate owns a head chunk
        and the wave gangs (one executor per device, homogeneous block
        signatures — ``run_queues_ganged``'s gates), the wave runs as ONE
        sharded execution and each report is consumed immediately: the
        chunk's ``chunk_done`` event is pushed here, exactly as the serial
        ``_dispatch_next`` would, so later queue mutations (steals,
        failures) can never orphan a pre-executed report.  Returns the
        ganged ids — the caller's serial dispatch loop skips them.  Gated
        to the comm-transparent fault-free configuration; under the
        deterministic tick timer the ganged reports are bit-identical to
        the serial path's."""
        if not (srv.gang_dispatch and srv.placement is not None
                and srv.faults is None):
            return set()
        ready = [k for k in candidates
                 if not states[k].inflight and not states[k].dead
                 and not states[k].stopped and states[k].queue]
        if len(ready) < 2:
            return set()
        from repro.core.executor import run_queues_ganged
        heads = {k: states[k].queue[:chunk] for k in ready}
        reports = run_queues_ganged(srv.executors, rnd, heads, payload,
                                    srv.data_by_client, srv.placement)
        if reports is None:
            return set()
        ganged: Set[int] = set()
        for k in ready:
            es, rep = states[k], reports[k]
            es.queue = es.queue[len(heads[k]):]
            start = max(es.t, clock.now)
            es.offset += len(heads[k])
            es.inflight = True
            if es.queue and srv.algorithm.stateful:
                sm = srv.executors[k].state_manager
                if sm is not None:
                    sm.prefetch(prefetch_ids(es.queue, chunk))
            es.busy_until = start + rep.virtual_time
            clock.push(es.busy_until, "chunk_done", mk_done(k, rep))
            if srv.telemetry is not None and rep.n_tasks:
                srv.telemetry.tracer.span(
                    f"exec:{k}", "chunk", t_base + start,
                    t_base + es.busy_until, cat="busy",
                    args={"round": rnd, "n_tasks": rep.n_tasks,
                          "down_s": 0.0, "ganged": True})
                if rep.compiles:
                    srv.telemetry.registry.counter(
                        f"host/exec{k}/compiles").inc(rep.compiles)
            ganged.add(k)
        return ganged

    def _fail_over(self, srv, states: Dict[int, _ExecState], dead: int,
                   remaining: List[ClientTask]) -> List[int]:
        """Elastic failure as an engine event: drop the dead executor
        (K shrink), append its unfinished tasks round-robin onto the
        survivors' queues.  Tasks assigned to the dead executor *after* its
        failure event was pushed (an async refill can land in between) are
        still parked on its queue and re-home too.  Returns survivor ids."""
        srv._drop_executor(dead)
        dead_state = states.pop(dead, None)
        if dead_state is not None and dead_state.queue:
            remaining = list(remaining) + dead_state.queue
        survivors = sorted(states)
        if not survivors:
            raise RuntimeError("all executors failed")
        for i, t in enumerate(remaining):
            states[survivors[i % len(survivors)]].queue.append(t)
        return survivors

    def _lifecycle(self, srv, t: float, counters: FaultCounters) -> None:
        """Fault-plan executor lifecycle at a round boundary: fire crashes
        that are due at absolute time ``t`` (retiring the executor — its
        state and pin park until the paired restart), then revive executors
        whose restart came due (re-pinned least-loaded via the placement).
        No-op without an active plan."""
        fi = srv.faults
        if fi is None:
            return
        for k in sorted(srv.executors):
            if fi.crash_due(k, t) is not None and fi.fire_crash(k, t):
                srv._drop_executor(k)
                counters.crashes += 1
        for k in fi.restarts_due(t):
            if srv._revive_executor(k):
                counters.restarts += 1
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(f"exec:{k}", "restart", t,
                                                 cat="fault")
        if not srv.executors:
            raise RuntimeError("all executors failed")

    @staticmethod
    def _fault_extra(extra: Dict[str, float],
                     counters: FaultCounters) -> None:
        """Fold the round's fault accounting into ``extra`` under the
        unified schema every engine emits: ``retries``,
        ``corrupt_payloads`` and ``dropped_clients`` are always present
        (merging with any availability dropouts the netsim counted);
        lifecycle/timeout/quorum keys appear when they fired."""
        extra["retries"] = float(counters.retries)
        extra["corrupt_payloads"] = float(counters.corrupt_payloads)
        extra["dropped_clients"] = (extra.get("dropped_clients", 0.0)
                                    + float(counters.dropped_clients))
        if counters.crashes:
            extra["fault_crashes"] = float(counters.crashes)
        if counters.restarts:
            extra["fault_restarts"] = float(counters.restarts)
        if counters.timeouts:
            extra["chunk_timeouts"] = float(counters.timeouts)
        if counters.quorum_commits:
            extra["quorum_commits"] = float(counters.quorum_commits)


def make_engine(mode: str, **opts) -> RoundEngine:
    modes = {"bsp": BSPEngine, "semi-sync": SemiSyncEngine,
             "semi_sync": SemiSyncEngine, "async": AsyncEngine}
    if mode not in modes:
        raise ValueError(f"unknown round engine {mode!r}; "
                         f"choose from {sorted(set(modes))}")
    return modes[mode](**opts)


# ---------------------------------------------------------------------------
# BSP
# ---------------------------------------------------------------------------

class BSPEngine(RoundEngine):
    """Algorithm 2 as an event flow, bit-exact with the pre-engine loop.

    BSP is a barrier: every queue completion lands *at* the barrier, so all
    events carry virtual time 0 and pop in push order — executor-dict order
    for the serial path, completion order for ``parallel_dispatch`` — which
    reproduces the legacy partial/fold order exactly (float summation is not
    associative; order is part of bit-exactness).

    With a network model the barrier waits on comm too: executor k's round
    span becomes ``download(queue) + Σ compute + upload(partial)``, the
    download priced at the broadcast's size over the queue's bottleneck
    downlink and the upload at the partial's *achieved* wire size over the
    bottleneck uplink — the fold order (and therefore the params) stays
    identical to the comm-free path; only the makespan moves.  With an
    availability model, offline clients are filtered at selection and
    clients predicted to leave before their queue position completes are
    dropped at dispatch (their round contribution is lost, as on a real
    deployment).

    Under an active :class:`FaultPlan` (DESIGN.md §10): crashes due at the
    round boundary retire the executor before scheduling; a crash inside a
    queue's computed span discards its report and re-runs the clients on the
    survivors (the existing failure path); slowdown windows stretch report
    spans; corrupted partials are detected after the ship and their clients
    re-run round-robin until the retry budget drains; with a network model
    the upload leg additionally sees blackouts, chunk timeouts with
    backed-off re-sends, and mid-upload dropout — a payload whose every
    re-send is exhausted loses its contribution for the round (BSP has no
    carry pool to re-enter).  Client dropout otherwise filters at selection
    only: BSP queues have no mid-round re-entry point.  Re-runs themselves
    are not fault-checked (one level of recovery per round keeps the
    barrier analysis tractable).  ``quorum_frac < 1.0`` relaxes the
    barrier: when executors die but the surviving reports already cover ≥
    ``quorum_frac`` of the selected weight, the round commits degraded
    instead of re-running the dead queues' clients.
    """

    mode = "bsp"

    def __init__(self, quorum_frac: float = 1.0):
        if not (0.0 < quorum_frac <= 1.0):
            raise ValueError("quorum_frac must be in (0, 1]")
        self.quorum_frac = float(quorum_frac)

    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        rnd = srv.round
        t_wall = time.perf_counter()
        counters = FaultCounters()
        self._lifecycle(srv, srv.virtual_now, counters)
        if srv._next_tasks is not None:
            tasks, srv._next_tasks = srv._next_tasks, None
        else:
            tasks = srv.select_clients()
        netsim = self._netsim(srv, srv.virtual_now)
        idle = 0.0
        if not tasks and netsim is not None:
            tasks, idle = self._fast_forward_empty(srv, srv.select_clients)
            netsim.t0 = srv.virtual_now
            # an overlapped schedule prepared for the pre-jump EMPTY cohort
            # is stale — the reselected clients must be scheduled fresh
            srv._pending_schedule = None

        # compute-comm overlap: the schedule for this round may have been
        # prepared while the previous round's global reduce was in flight.
        # An executor lost since then would still own a queue here — re-map
        # orphaned queues onto the live set (the dropped-clients fix).
        remapped = 0
        if srv._pending_schedule is not None:
            schedule, overlapped = srv._pending_schedule, True
            srv._pending_schedule = None
            remapped = schedule.remap(list(srv.executors))
        else:
            schedule, overlapped = srv.scheduler.schedule(
                rnd, tasks, list(srv.executors),
                comm_cost=srv._sched_comm_cost()), False

        payload = srv.algorithm.broadcast_payload(srv.params,
                                                  srv.server_state)
        if netsim is not None:
            netsim.set_payload(payload)
        skip_map, n_backups = srv._plan_backups(schedule)
        dropped: Set[int] = set()
        if netsim is not None and netsim.avail is not None:
            drop_map, dropped = self._plan_drops(srv, schedule, netsim)
            for k, s in drop_map.items():
                skip_map.setdefault(k, set()).update(s)
        reports, n_failed = self._dispatch(srv, rnd, schedule, payload,
                                           skip_map, netsim, dropped,
                                           counters=counters,
                                           n_total=len(tasks))

        # round span — computed before the overlap selection below, which
        # must see the server's virtual clock at this round's END (or the
        # next cohort's availability would be filtered at its start)
        fi = srv.faults
        ctrl = self._ctrl(srv)
        tele = srv.telemetry
        base = srv.virtual_now        # the barrier's absolute start
        kept = reports
        if netsim is None:
            makespan = max((r.virtual_time for r in reports), default=0.0)
            if tele is not None:
                for r in reports:
                    if r.n_tasks:
                        tele.tracer.span(
                            f"exec:{r.executor}", "chunk", base,
                            base + r.virtual_time, cat="busy",
                            args={"round": rnd, "n_tasks": r.n_tasks,
                                  "down_s": 0.0})
                    if r.compiles:
                        tele.registry.counter(
                            f"host/exec{r.executor}/compiles").inc(
                                r.compiles)
        elif fi is None:
            if ctrl is not None and ctrl.overlap_comm:
                # comm/compute overlap (DESIGN.md §12): the payload exists
                # at the barrier's start, so each client's download runs
                # concurrently with the lane's earlier COMPUTE — task j
                # starts at max(t_{j-1}, down_j) instead of after a serial
                # queue-bottleneck download
                makespan = self._overlap_span(netsim, reports, tele=tele,
                                              base=base, rnd=rnd)
            else:
                # the barrier waits on comm events: each executor's span is
                # broadcast-download + compute + partial-upload (the upload
                # at the achieved wire size measured when the partial
                # shipped).  The explicit loop is float-op-identical to the
                # max-over-genexpr it replaces (same down -> up accounting
                # order per report, same (d + vt) + u grouping; max is
                # exact selection) — telemetry ON stays bit-exact.
                makespan = 0.0
                for r in reports:
                    d = netsim.down(r.completed_clients)
                    u = netsim.up(r.completed_clients, r.wire_bytes)
                    end = d + r.virtual_time + u
                    if tele is not None and r.n_tasks:
                        tele.tracer.span(
                            f"exec:{r.executor}", "chunk", base,
                            base + (d + r.virtual_time), cat="busy",
                            args={"round": rnd, "n_tasks": r.n_tasks,
                                  "down_s": d})
                        tele.tracer.span(
                            f"exec:{r.executor}:up", "upload",
                            base + (d + r.virtual_time), base + end,
                            cat="comm",
                            args={"round": rnd, "wire_bytes": r.wire_bytes,
                                  "billed_bytes": float(r.wire_bytes)})
                    if tele is not None and r.compiles:
                        tele.registry.counter(
                            f"host/exec{r.executor}/compiles").inc(
                                r.compiles)
                    makespan = max(makespan, end)
        else:
            # fault-priced upload leg: blackout pauses + chunk timeout with
            # backed-off re-sends, then mid-upload dropout.  A payload that
            # never lands loses its round contribution (BSP has no carry
            # pool) but its compute still gates the barrier.
            spans: List[float] = []
            lost: Set[int] = set()
            for i, r in enumerate(reports):
                t_c = (netsim.t0 + netsim.down(r.completed_clients)
                       + r.virtual_time)
                b_up0 = netsim.bytes_up
                up_s = netsim.up(r.completed_clients, r.wire_bytes)
                if tele is not None and r.n_tasks:
                    tele.tracer.span(
                        f"exec:{r.executor}", "chunk", base, t_c,
                        cat="busy",
                        args={"round": rnd, "n_tasks": r.n_tasks,
                              "down_s": max(t_c - base - r.virtual_time,
                                            0.0)})
                if tele is not None and r.compiles:
                    tele.registry.counter(
                        f"host/exec{r.executor}/compiles").inc(r.compiles)
                if not r.n_tasks:
                    spans.append(t_c + up_s - netsim.t0)
                    continue
                t_abs = fi.price_upload(t_c, up_s, netsim,
                                        r.completed_clients, r.wire_bytes,
                                        counters, executor=r.executor)
                if t_abs is not None and fi.upload_lost(
                        r.completed_clients, t_c, t_abs):
                    t_abs = None
                if t_abs is None:
                    lost.add(i)
                    counters.dropped_clients += len(r.completed_clients)
                    spans.append(t_c - netsim.t0)
                    if tele is not None:
                        tele.tracer.span(
                            f"exec:{r.executor}:up", "upload", t_c,
                            t_c + up_s, cat="comm",
                            args={"round": rnd, "wire_bytes": r.wire_bytes,
                                  "billed_bytes": float(netsim.bytes_up
                                                        - b_up0),
                                  "lost": True})
                else:
                    spans.append(t_abs - netsim.t0)
                    if tele is not None:
                        tele.tracer.span(
                            f"exec:{r.executor}:up", "upload", t_c, t_abs,
                            cat="comm",
                            args={"round": rnd, "wire_bytes": r.wire_bytes,
                                  "billed_bytes": float(netsim.bytes_up
                                                        - b_up0)})
            makespan = max(spans, default=0.0)
            if lost:
                kept = [r for i, r in enumerate(reports) if i not in lost]
            fi.clear_retries(
                [c for r in kept for c in r.completed_clients])
        srv.virtual_now += makespan

        # overlap: prepare round r+1's schedule "while the reduce is in
        # flight" (before the global_aggregate below consumes the partials)
        if srv.overlap_scheduling:
            srv.estimator.record_many(
                [rec for r in reports for rec in r.records])
            srv._next_tasks = srv.select_clients()
            srv._pending_schedule = srv.scheduler.schedule(
                rnd + 1, srv._next_tasks, list(srv.executors),
                comm_cost=srv._sched_comm_cost())

        partials = [r.partial for r in kept]      # already the wire copies
        ops = srv.algorithm.ops()
        if partials:   # every report lost in transit -> no update this round
            if tele is not None:
                tele.tracer.instant(
                    "server", "global_fold", base + makespan, cat="server",
                    args={"round": rnd, "n_partials": len(partials)})
            agg = srv.global_fold(partials)
            agg["_n_selected"] = sum(r.n_tasks for r in kept)
            srv.params, srv.server_state = srv.algorithm.server_update(
                srv.params, agg, srv.server_state, len(srv.data_by_client))

        records = [rec for r in reports for rec in r.records]
        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 records)
        if not srv.overlap_scheduling:  # overlap path already recorded them
            srv.estimator.record_many(records)
        stats = srv.comm.stats.reset()
        extra = {"backup_tasks": float(n_backups)}
        if ctrl is not None:
            # hindsight-optimal repack of the realized per-task spans (the
            # benchmarks' gap_to_oracle_pct denominator); comm priced per
            # client off the network model, unaccounted
            jobs = []
            for r in reports:
                for rec in r.records:
                    c = 0.0
                    if netsim is not None and netsim.net is not None:
                        c = netsim.net.client_comm_time(
                            rec.client, netsim.payload_nbytes,
                            int(netsim.payload_nbytes * srv._wire_ratio))
                    jobs.append((rec.n_samples, rec.time, rec.executor, c))
            extra["oracle_makespan"] = oracle_makespan(
                jobs, list(srv.executors))
        if remapped:
            extra["remapped_tasks"] = float(remapped)
        if netsim is not None:
            extra.update(netsim.extra())
            if makespan <= 0.0 and not any(r.n_tasks for r in reports):
                idle += self._advance_past_gap(srv)
        if idle:
            extra["idle_time"] = idle
        if srv.faults is not None or counters.quorum_commits:
            self._fault_extra(extra, counters)
        sm_extra = srv._state_manager_extra()
        if sm_extra is not None:
            extra["state_manager"] = sm_extra
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=0.0 if overlapped else schedule.schedule_time_s,
            estimate_time=0.0 if overlapped else schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(srv.executors),
            estimation_error=err, failures=n_failed, extra=extra)
        srv._commit_metrics(metrics, base)
        srv.round += 1
        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics

    # ------------------------------------------------------------------
    @staticmethod
    def _overlap_span(netsim: _NetSim, reports: List[ExecutorReport],
                      tele=None, base: float = 0.0, rnd: int = 0) -> float:
        """Barrier span with per-client downloads overlapping the lane's
        earlier compute (DESIGN.md §12): task j starts at
        ``max(t_{j-1}, down_j)`` — the fold over the report's per-task
        records — then the partial's upload closes the lane.  The serial
        branch's accounted ``netsim.down`` call is preserved once per
        report (the per-client reads here are unaccounted), so
        ``comm_time_down`` matches the serial branch exactly; only the
        makespan moves."""
        span = 0.0
        for r in reports:
            d_acc = netsim.down(r.completed_clients)   # accounting parity
            if r.n_tasks and netsim.net is not None:
                t = 0.0
                for rec in r.records:
                    d = netsim.net.download_time([rec.client],
                                                 netsim.payload_nbytes)
                    t = max(t, d) + rec.time
            else:
                t = d_acc + r.virtual_time
            u = netsim.up(r.completed_clients, r.wire_bytes)
            if tele is not None and r.n_tasks:
                tele.tracer.span(
                    f"exec:{r.executor}", "chunk", base, base + t,
                    cat="busy",
                    args={"round": rnd, "n_tasks": r.n_tasks,
                          "down_s": max(t - r.virtual_time, 0.0)})
                tele.tracer.span(
                    f"exec:{r.executor}:up", "upload", base + t,
                    base + (t + u), cat="comm",
                    args={"round": rnd, "wire_bytes": r.wire_bytes,
                          "billed_bytes": float(r.wire_bytes)})
            if tele is not None and r.compiles:
                tele.registry.counter(
                    f"host/exec{r.executor}/compiles").inc(r.compiles)
            span = max(span, t + u)
        return span

    def _plan_drops(self, srv, schedule: Schedule, netsim: _NetSim
                    ) -> Tuple[Dict[int, Set[int]], Set[int]]:
        """Clients predicted to leave before their queue position completes
        (cumulative span under the fitted model; optimistic during warmup,
        when no model exists).  They are skipped at dispatch via the same
        ``skip_clients`` hook the backup replicas use, and excluded from
        failure re-runs — the round loses their contribution, exactly as a
        real deployment would."""
        models = srv.estimator.last_fit
        avail, t0 = netsim.avail, netsim.t0
        skip: Dict[int, Set[int]] = {}
        dropped: Set[int] = set()
        for k in list(srv.executors):
            queue = schedule.queue(k)
            if not queue:
                continue
            m = models.get(k)
            t_off = 0.0
            if netsim.net is not None:
                t_off = netsim.net.download_time(
                    [t.client for t in queue], netsim.payload_nbytes)
            for task in queue:
                dur = m.predict(task.n_samples) if m is not None else 0.0
                if (not avail.available(task.client, t0)
                        or avail.remaining(task.client, t0) < t_off + dur):
                    skip.setdefault(k, set()).add(task.client)
                    dropped.add(task.client)
                else:
                    t_off += dur
        netsim.dropped += len(dropped)
        return skip, dropped

    # ------------------------------------------------------------------
    def _dispatch(self, srv, rnd: int, schedule: Schedule, payload: Dict,
                  skip_map: Optional[Dict[int, Set[int]]] = None,
                  netsim: Optional[_NetSim] = None,
                  dropped: Optional[Set[int]] = None,
                  counters: Optional[FaultCounters] = None,
                  n_total: int = 0
                  ) -> Tuple[List[ExecutorReport], int]:
        live = list(srv.executors)
        srv.comm.broadcast(payload, live, tag="broadcast")
        clock = VirtualClock()
        reports: List[ExecutorReport] = []
        failed: List[int] = []
        done_clients: set = set()

        def run(k: int) -> ExecutorReport:
            return srv.executors[k].run_queue(
                rnd, schedule.queue(k), payload, srv.data_by_client,
                skip_clients=(skip_map or {}).get(k))

        # SPMD gang dispatch (DESIGN.md §8): under a one-executor-per-device
        # placement, a round whose queues plan into aligned block waves runs
        # each wave as ONE sharded execution across the mesh — per-device
        # threads give real wall-clock overlap even where per-device
        # dispatches serialize (CPU PJRT).  Reports come back in executor
        # order with per-executor content identical to the serial path, so
        # the barrier semantics (and bit-exactness) are unchanged.
        ganged = None
        if srv.gang_dispatch and not srv.parallel_dispatch:
            from repro.core.executor import run_queues_ganged
            ganged = run_queues_ganged(
                srv.executors, rnd, {k: schedule.queue(k) for k in live},
                payload, srv.data_by_client, srv.placement, skip_map)
        # barrier semantics: every outcome lands at t=0; seq order preserves
        # the legacy collection order
        if ganged is not None:
            for k in live:
                clock.push(0.0, "queue_done", ganged[k])
        elif srv.parallel_dispatch:
            with cf.ThreadPoolExecutor(max_workers=len(live)) as pool:
                futs = {pool.submit(run, k): k for k in live}
                for fut in cf.as_completed(futs):
                    k = futs[fut]
                    try:
                        clock.push(0.0, "queue_done", fut.result())
                    except ExecutorFailure:
                        clock.push(0.0, "executor_failed", k)
        else:
            for k in live:
                try:
                    clock.push(0.0, "queue_done", run(k))
                except ExecutorFailure:
                    clock.push(0.0, "executor_failed", k)

        for ev in clock.drain():
            if ev.kind == "queue_done":
                reports.append(ev.data)
            else:
                failed.append(ev.data)
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        f"exec:{ev.data}", "executor_failed",
                        srv.virtual_now, cat="fault")

        # ---- fault plan: slowdown windows + crashes inside the span ------
        fi = srv.faults
        if fi is not None:
            t0 = srv.virtual_now
            surviving: List[ExecutorReport] = []
            for rep in reports:
                scale_report(rep, fi.slowdown(rep.executor, t0))
                hit = (fi.crash_in(rep.executor, t0, t0 + rep.virtual_time)
                       if rep.n_tasks else None)
                if hit is not None:
                    # the executor died mid-queue: its report never reaches
                    # the server — the clients re-run through the existing
                    # failure path below
                    fi.fire_crash(rep.executor, hit[1])
                    if counters is not None:
                        counters.crashes += 1
                    failed.append(rep.executor)
                else:
                    surviving.append(rep)
            reports = surviving

        # ---- fault handling: re-run failed queues on the survivors -------
        if failed:
            for rep in reports:
                done_clients.update(rep.completed_clients)
            survivors = [k for k in live if k not in failed]
            if not survivors:
                raise RuntimeError("all executors failed")
            # dedup by client: with backup duplicates a task can sit in two
            # failed queues at once and must still re-run (and fold) once.
            # Availability-dropped clients never re-run (they're offline).
            leftovers: List[ClientTask] = []
            for k in failed:
                for t in schedule.queue(k):
                    if t.client not in done_clients and \
                            t.client not in (dropped or ()):
                        done_clients.add(t.client)
                        leftovers.append(t)
                srv._drop_executor(k)          # elastic K shrink
            # quorum-degraded commit: when the surviving reports already
            # cover >= quorum_frac of the selected weight, skip the re-runs
            # and commit with what landed (missing weight renormalizes
            # through _n_selected; fold order over survivors is unchanged,
            # hence deterministic)
            if self.quorum_frac < 1.0 and leftovers \
                    and counters is not None:
                folded = sum(r.n_tasks for r in reports)
                if folded >= self.quorum_frac * max(n_total, 1):
                    counters.dropped_clients += len(leftovers)
                    counters.quorum_commits += 1
                    leftovers = []
            for i, t in enumerate(leftovers):  # round-robin retry placement
                k = survivors[i % len(survivors)]
                rep = srv.executors[k].run_queue(
                    rnd, [t], payload, srv.data_by_client)
                reports.append(rep)

        # the partial that reaches aggregation is the one that crossed the
        # wire: compress once, ship, and aggregate the decompressed copy
        # (error-feedback residuals and the aggregated values stay in sync).
        # Under a network model the achieved wire size is measured here —
        # it prices the upload leg of the barrier.
        for rep in reports:
            if netsim is not None:
                rep.partial, rep.wire_bytes = netsim.ship(rep.executor,
                                                          rep.partial)
            else:
                srv.comm.executor_send(
                    rep.executor,
                    srv._maybe_compress(rep.partial, rep.executor),
                    tag="partial")
                rep.partial = srv._maybe_decompress(
                    srv.comm.recv_from_executor(rep.executor, tag="partial"))

        # ---- corruption: detect-and-re-run until the retry budget drains -
        if fi is not None and counters is not None:
            pending, checked, rr = list(reports), [], 0
            while pending:
                rep = pending.pop(0)
                if rep.n_tasks and fi.take_corrupt(
                        rep.executor, srv.virtual_now + rep.virtual_time):
                    counters.corrupt_payloads += 1
                    retryc, give_up = fi.charge_retry(rep.completed_clients)
                    counters.retries += len(retryc)
                    counters.dropped_clients += len(give_up)
                    live_ks = sorted(srv.executors)
                    for c in retryc:   # round-robin re-run, re-ship, re-check
                        k = live_ks[rr % len(live_ks)]
                        rr += 1
                        nrep = srv.executors[k].run_queue(
                            rnd, _tasks_of(srv, [c]), payload,
                            srv.data_by_client)
                        if netsim is not None:
                            nrep.partial, nrep.wire_bytes = netsim.ship(
                                k, nrep.partial)
                        else:
                            nrep.partial = self._wire(srv, k, nrep.partial)
                        pending.append(nrep)
                else:
                    checked.append(rep)
            reports = checked
        return reports, len(failed)


# ---------------------------------------------------------------------------
# semi-sync
# ---------------------------------------------------------------------------

class SemiSyncEngine(RoundEngine):
    """Deadline-bounded rounds with over-selection and task carry-over.

    ``over_select`` inflates the per-round selection (so the deadline cut
    still folds ~``clients_per_round`` results); the deadline is
    ``deadline_frac ×`` the schedule's predicted makespan (∞ during warmup,
    when no workload model exists — the round then degenerates to BSP).
    An executor dispatches its next chunk only if the fitted model predicts
    it lands before the deadline; everything it does not dispatch — plus a
    dead executor's re-homed tasks that miss the deadline on the survivors —
    carries into the next round's selection pool.  Every executor gets its
    first chunk unconditionally, so a round always makes progress.

    Under an active :class:`FaultPlan` every fault routes through the carry
    pool (the engine's native re-run path): crashes at dispatch or inside a
    chunk's span push the executor's failure event; mid-compute dropouts
    leave the chunk before it runs; corrupted / lost-in-transit partials
    charge the clients' retry budget and carry the survivors; slowdown
    windows stretch chunk spans AND the deadline's span predictions.
    ``quorum_frac < 1.0`` commits the round early once ≥ that fraction of
    the selected tasks has folded — remaining queues drain into the carry
    pool and the round's makespan is the commit time.
    """

    mode = "semi-sync"

    def __init__(self, over_select: float = 1.5, deadline_frac: float = 0.75,
                 chunk_size: Optional[int] = None,
                 quorum_frac: float = 1.0):
        if not (0.0 < quorum_frac <= 1.0):
            raise ValueError("quorum_frac must be in (0, 1]")
        self.over_select = float(over_select)
        self.deadline_frac = float(deadline_frac)
        self.chunk_size = chunk_size
        self.quorum_frac = float(quorum_frac)
        self._carry: List[ClientTask] = []
        self._round_steals = 0      # within-round only (ctrl.rebalance)

    # -- checkpointing: the carry pool is the only cross-round state -------
    def state_dict(self) -> Dict:
        return {"mode": self.mode, "carry": list(self._carry)}

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if state.get("mode") != self.mode:
            raise ValueError(f"checkpointed engine state is "
                             f"{state.get('mode')!r}, not {self.mode!r}")
        self._carry = list(state["carry"])

    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        rnd = srv.round
        t_wall = time.perf_counter()
        counters = FaultCounters()
        self._lifecycle(srv, srv.virtual_now, counters)
        fi = srv.faults
        netsim = self._netsim(srv, srv.virtual_now)

        target = max(1, math.ceil(self.over_select * srv.clients_per_round))
        carried, self._carry = self._carry, []
        if netsim is not None and netsim.avail is not None and carried:
            # carried tasks bypass selection, so re-check them here: a
            # client still offline stays in the carry pool for later rounds
            online: List[ClientTask] = []
            for t in carried:
                (online if netsim.avail.available(t.client, srv.virtual_now)
                 else self._carry).append(t)
            carried = online
        n_fresh = max(0, target - len(carried))
        fresh = srv.select_clients(
            n=n_fresh, exclude=[t.client for t in carried])
        tasks = carried + fresh
        idle = 0.0
        if not tasks and netsim is not None:
            # exclude the carry pool: an offline carried client whose window
            # opens at the jump target must not ALSO be selected fresh (its
            # pending task would fold twice — once now, once from the carry)
            tasks, idle = self._fast_forward_empty(
                srv, lambda: srv.select_clients(
                    n=target, exclude=[t.client for t in self._carry]))
            netsim.t0 = srv.virtual_now
        schedule = srv.scheduler.schedule(rnd, tasks, list(srv.executors),
                                          comm_cost=srv._sched_comm_cost())
        payload = srv.algorithm.broadcast_payload(srv.params,
                                                  srv.server_state)
        if netsim is not None:
            netsim.set_payload(payload)
        live = list(srv.executors)
        srv.comm.broadcast(payload, live, tag="broadcast")

        models = dict(srv.estimator.last_fit)
        chunk = self._chunk_size(srv, self.chunk_size)
        # the round's anchor on the server's absolute virtual axis (fault
        # windows are declared in absolute time; local event times add abs0)
        abs0 = srv.virtual_now
        # the deadline lives in the same units the executors accrue: the
        # chunk-granular predicted makespan of this schedule (the per-task
        # Eq.-4 prediction pays one offset b per *task* and would overshoot
        # a chunked round by ~(chunk-1)·b per chunk, leaving the deadline
        # unreachable).  Comm delay joins the prediction when priced.
        # No models yet (warmup) -> ∞ -> a full BSP round.
        comm_pred = netsim.comm_pred if netsim is not None else None
        pm = max((predict_remaining(
                      models.get(k) if fi is None
                      else fi.scaled_model(models.get(k), k, abs0),
                      schedule.queue(k), chunk, comm_pred)
                  for k in live), default=0.0)
        ctrl = self._ctrl(srv)
        frac = self.deadline_frac
        if ctrl is not None and ctrl.deadline is not None:
            # self-tuned deadline fraction (DESIGN.md §12): the controller
            # converges the landed/selected ratio to its target quantile
            frac = ctrl.deadline.current(self.deadline_frac)
        deadline = frac * pm if pm > 0.0 else float("inf")

        clock = VirtualClock()
        states = {k: _ExecState(queue=list(schedule.queue(k))) for k in live}
        partials: List[Dict] = []
        records: List[RunRecord] = []
        oracle_jobs: List[Tuple[float, float, int, float]] = []
        n_landed = 0
        n_failed = 0
        self._round_steals = 0
        committed = False       # quorum reached: queues drained to carry
        quorum_t = 0.0
        t_hi = 0.0              # latest processed event (network makespan)
        ganged: Set[int] = set()
        if ctrl is not None and ctrl.gang_waves and netsim is None:
            # first-wave gang: at round start every first chunk is exempt
            # from the deadline check, matching the serial dispatch exactly
            ganged = self._gang_wave(srv, rnd, states, clock, payload,
                                     chunk, live, lambda k, rep: (k, rep),
                                     t_base=abs0)
        for k in live:
            if k not in ganged:
                self._dispatch_next(srv, rnd, k, states, clock, payload,
                                    models, deadline, chunk, netsim,
                                    counters)
        while clock:
            ev = clock.pop()
            t_hi = max(t_hi, ev.time)
            if ev.kind == "chunk_done":
                k, rep = ev.data
                es = states[k]
                es.t, es.inflight = ev.time, False
                if netsim is None and rep.n_tasks:
                    if committed:
                        # landed after the quorum commit: carry, not fold
                        self._carry.extend(
                            _tasks_of(srv, rep.completed_clients))
                    elif fi is not None and fi.take_corrupt(
                            k, abs0 + ev.time):
                        counters.corrupt_payloads += 1
                        retryc, give_up = fi.charge_retry(
                            rep.completed_clients)
                        counters.retries += len(retryc)
                        counters.dropped_clients += len(give_up)
                        self._carry.extend(_tasks_of(srv, retryc))
                    else:
                        if srv.telemetry is not None:
                            srv.telemetry.tracer.instant(
                                "server", "fold", abs0 + ev.time,
                                cat="server",
                                args={"round": rnd, "executor": k,
                                      "n_tasks": rep.n_tasks})
                        partials.append(self._wire(srv, k, rep.partial))
                        rec = self._chunk_record(srv, rnd, rep)
                        if rec is not None:
                            records.append(rec)
                        n_landed += rep.n_tasks
                        if fi is not None:
                            fi.clear_retries(rep.completed_clients)
                self._dispatch_next(srv, rnd, k, states, clock, payload,
                                    models, deadline, chunk, netsim,
                                    counters)
            elif ev.kind == "chunk_arrived":
                # the chunk's upload landed: fold the wire copy it carried
                ce = ev.data
                if committed:
                    self._carry.extend(_tasks_of(srv, ce.completed_clients))
                elif fi is not None and fi.take_corrupt(
                        ce.executor, abs0 + ev.time):
                    counters.corrupt_payloads += 1
                    retryc, give_up = fi.charge_retry(ce.completed_clients)
                    counters.retries += len(retryc)
                    counters.dropped_clients += len(give_up)
                    self._carry.extend(_tasks_of(srv, retryc))
                else:
                    if srv.telemetry is not None:
                        srv.telemetry.tracer.instant(
                            "server", "fold", abs0 + ev.time, cat="server",
                            args={"round": rnd, "executor": ce.executor,
                                  "n_tasks": ce.n_tasks})
                        srv.telemetry.registry.histogram(
                            "hist/upload_delay").observe(
                                max(ev.time - ce.t_sent, 0.0))
                    partials.append(ce.partial)
                    if ce.record is not None:
                        records.append(ce.record)
                        if ctrl is not None:
                            oracle_jobs.append((
                                ce.record.n_samples, ce.record.time,
                                ce.record.executor,
                                netsim.net.chunk_comm_time(
                                    list(ce.completed_clients),
                                    netsim.payload_nbytes, ce.wire_bytes)
                                if netsim.net is not None else 0.0))
                    n_landed += ce.n_tasks
                    if fi is not None:
                        fi.clear_retries(ce.completed_clients)
            elif ev.kind == "upload_lost":
                # every re-send timed out, or a client dropped mid-upload:
                # the partial never reached the server — charge the budget,
                # carry the clients that may retry
                _k, lost_clients = ev.data
                retryc, give_up = fi.charge_retry(lost_clients)
                counters.retries += len(retryc)
                counters.dropped_clients += len(give_up)
                self._carry.extend(_tasks_of(srv, retryc))
            else:  # executor_failed
                dead, remaining = ev.data
                n_failed += 1
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        f"exec:{dead}", "executor_failed", abs0 + ev.time,
                        cat="fault")
                survivors = self._fail_over(srv, states, dead, remaining)
                for j in survivors:
                    if states[j].stopped:
                        # already past the deadline: re-homed tasks carry
                        # over instead of silently parking on a stopped queue
                        self._carry.extend(states[j].queue)
                        states[j].queue = []
                    elif not states[j].inflight:  # wake finished survivors
                        self._dispatch_next(srv, rnd, j, states, clock,
                                            payload, models, deadline, chunk,
                                            netsim, counters)
            if not committed and self.quorum_frac < 1.0 and tasks \
                    and n_landed >= self.quorum_frac * len(tasks):
                # quorum-degraded commit: enough of the selected weight has
                # folded — the round closes here; everything still queued
                # (or landing later) re-enters through the carry pool
                committed, quorum_t = True, ev.time
                counters.quorum_commits += 1
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        "server", "quorum_commit", abs0 + ev.time,
                        cat="server",
                        args={"round": rnd, "n_landed": n_landed})
                for es in states.values():
                    if es.queue:
                        self._carry.extend(es.queue)
                        es.queue = []
                    es.stopped = True

        ops = srv.algorithm.ops()
        if partials:
            agg = srv.global_fold(partials)
            agg["_n_selected"] = n_landed
            srv.params, srv.server_state = srv.algorithm.server_update(
                srv.params, agg, srv.server_state, len(srv.data_by_client))

        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 records)
        srv.estimator.record_many(records)
        makespan = max((es.t for es in states.values()), default=0.0)
        if netsim is not None:
            # the round is not over until the last counted upload landed
            makespan = max(makespan, t_hi)
        if committed:
            # the round committed at quorum: in-flight stragglers finished
            # after the commit carried over instead of counting
            makespan = quorum_t
        stats = srv.comm.stats.reset()
        extra = {"landed_clients": float(n_landed),
                 "carried_tasks": float(len(self._carry)),
                 "deadline": deadline}
        if ctrl is not None:
            extra["deadline_frac"] = frac
            if netsim is None:
                # comm-transparent folds all land at chunk_done: the round's
                # records ARE the realized jobs (comm = 0)
                oracle_jobs = [(r.n_samples, r.time, r.executor, 0.0)
                               for r in records]
            extra["oracle_makespan"] = oracle_makespan(
                oracle_jobs, list(srv.executors))
            if ctrl.rebalance:
                extra["rebalanced_tasks"] = float(self._round_steals)
            if ctrl.deadline is not None and deadline != float("inf"):
                # one controller step per round, from this round's observed
                # landed/selected ratio (applies from the NEXT round); warmup
                # rounds (no workload model -> deadline ∞ -> everything
                # lands) carry no signal and would bias the EWMA toward
                # tightening, so they are skipped
                new_frac = ctrl.deadline.update(n_landed, len(tasks),
                                                self.deadline_frac,
                                                1.0 / self.over_select)
                note = getattr(ctrl, "note", None)
                if note is not None:
                    note("deadline_frac", new_frac, abs0 + makespan)
        if netsim is not None:
            extra.update(netsim.extra())
            if makespan <= 0.0 and n_landed == 0:
                idle += self._advance_past_gap(srv)
        if idle:
            extra["idle_time"] = idle
        if fi is not None or counters.quorum_commits:
            self._fault_extra(extra, counters)
        sm_extra = srv._state_manager_extra()
        if sm_extra is not None:
            extra["state_manager"] = sm_extra
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=schedule.schedule_time_s,
            estimate_time=schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(srv.executors),
            estimation_error=err, failures=n_failed,
            extra=extra)
        srv._commit_metrics(metrics, abs0)
        srv.virtual_now += makespan
        srv.round += 1
        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics

    # ------------------------------------------------------------------
    def _dispatch_next(self, srv, rnd, k, states, clock, payload, models,
                       deadline, chunk, netsim=None, counters=None) -> None:
        fi = srv.faults
        abs0 = netsim.t0 if netsim is not None else srv.virtual_now
        es = states[k]
        ctrl = self._ctrl(srv)
        while True:
            if not es.queue and not es.stopped and not es.dead \
                    and ctrl is not None and ctrl.rebalance:
                # deadline-aware work stealing (DESIGN.md §12): a drained
                # lane takes the predicted-straggler's tail chunk instead
                # of idling out the deadline; the stolen chunk still faces
                # the per-chunk deadline check below on the thief's clock
                self._steal_next(srv, k, states, models, chunk, netsim,
                                 clock, abs0)
            if not es.queue or es.stopped or es.dead:
                return
            next_chunk = es.queue[:chunk]
            start = max(es.t, clock.now)
            comm_pred = netsim.comm_pred if netsim is not None else None
            model = models.get(k)
            if fi is not None:
                model = fi.scaled_model(model, k, abs0 + start)
            pred = predict_span(model, next_chunk, comm_pred)
            if es.t > 0.0 and start + pred > deadline:
                # predicted to miss the deadline: stop here, carry the rest
                # (first chunk is exempt — a round always makes progress)
                es.stopped = True
                self._carry.extend(es.queue)
                es.queue = []
                return
            es.queue = es.queue[chunk:]
            if fi is not None:
                if fi.crash_due(k, abs0 + start) is not None:
                    # crash due before this chunk dispatches: the executor
                    # is dead now, the chunk and queue re-home
                    fi.fire_crash(k, abs0 + start)
                    if counters is not None:
                        counters.crashes += 1
                    clock.push(start, "executor_failed",
                               (k, next_chunk + es.queue))
                    es.queue = []
                    es.dead = True
                    return
                # mid-compute dropout: clients whose window opens inside
                # the predicted span leave the chunk and carry over
                next_chunk, f_drop = fi.split_up(next_chunk, abs0 + start,
                                                 pred)
                if f_drop:
                    if counters is not None:
                        counters.dropped_clients += len(f_drop)
                    self._carry.extend(f_drop)
                if not next_chunk:
                    continue        # whole chunk dropped: try the next one
            if netsim is not None:
                # availability dropout: offline / predicted-to-expire
                # clients leave the chunk and re-enter through the carry
                # pool (the deadline path's re-run mechanism)
                next_chunk, av_dropped = netsim.split_available(
                    next_chunk, start, pred)
                self._carry.extend(av_dropped)
                if not next_chunk:
                    continue        # whole chunk offline: try the next one
            try:
                rep = srv.executors[k].run_queue(
                    rnd, next_chunk, payload, srv.data_by_client,
                    task_offset=es.offset)
            except ExecutorFailure:
                # the failing chunk never folded: every one of its clients
                # must re-home along with the rest of the queue.  The
                # executor is dead the moment the event is pushed — nothing
                # may dispatch on it while the event waits in the queue.
                clock.push(start, "executor_failed",
                           (k, next_chunk + es.queue))
                es.queue = []
                es.dead = True
                return
            es.offset += len(next_chunk)
            es.inflight = True
            if es.queue and srv.algorithm.stateful:
                # schedule-keyed prefetch: stage the next chunk's state
                # shards while this chunk's span elapses on the virtual
                # clock (pure host-RAM staging — no metric changes)
                sm = srv.executors[k].state_manager
                if sm is not None:
                    sm.prefetch(prefetch_ids(es.queue, chunk))
            if fi is not None:
                scale_report(rep, fi.slowdown(k, abs0 + start))
                # crash inside the chunk's span (download + compute; the
                # download read off the network model UNACCOUNTED — the
                # real billing happens in push_chunk, this is a window
                # bound): the chunk is lost, the queue re-homes at the
                # crash time
                down_un = 0.0
                if netsim is not None and netsim.net is not None \
                        and rep.n_tasks:
                    down_un = netsim.net.download_time(
                        rep.completed_clients, netsim.payload_nbytes)
                hit = fi.crash_in(k, abs0 + start,
                                  abs0 + start + down_un + rep.virtual_time)
                if hit is not None:
                    fi.fire_crash(k, hit[1])
                    if counters is not None:
                        counters.crashes += 1
                    clock.push(hit[1] - abs0, "executor_failed",
                               (k, next_chunk + es.queue))
                    es.queue = []
                    es.dead = True
                    return
            if netsim is None:
                es.busy_until = start + rep.virtual_time
                clock.push(es.busy_until, "chunk_done", (k, rep))
                if srv.telemetry is not None and rep.n_tasks:
                    srv.telemetry.tracer.span(
                        f"exec:{k}", "chunk", abs0 + start,
                        abs0 + es.busy_until, cat="busy",
                        args={"round": rnd, "n_tasks": rep.n_tasks,
                              "down_s": 0.0})
                    if rep.compiles:
                        srv.telemetry.registry.counter(
                            f"host/exec{k}/compiles").inc(rep.compiles)
                return
            # comm-priced chunk: the executor is busy for download +
            # compute, then free — the upload overlaps its next chunk and
            # lands as its own arrival event, which is when the fold counts
            ctrl = self._ctrl(srv)
            es.busy_until = netsim.push_chunk(
                clock, rep, start, (k, rep),
                self._chunk_record(srv, rnd, rep), version=rnd,
                fi=fi, counters=counters,
                # the round's payload was broadcast at local t=0: with
                # overlap_comm on, the download runs concurrently with the
                # lane's earlier chunks instead of serializing into this one
                overlap_from=(0.0 if ctrl is not None and ctrl.overlap_comm
                              else None))
            return

    def _steal_next(self, srv, k, states, models, chunk, netsim,
                    clock, abs0) -> None:
        """Move the predicted-straggler's tail chunk onto drained lane
        ``k`` (``ctrl.rebalance``; same victim policy as the async engine's
        steal).  Deterministic: victim choice and the moved slice depend
        only on the queues and fitted models."""
        queues = {j: es.queue for j, es in states.items()
                  if not es.stopped and not es.dead}
        avail = {j: max(states[j].t, states[j].busy_until) for j in queues}
        victim = pick_steal_victim(
            queues, avail, models, k, chunk,
            netsim.comm_pred if netsim is not None else None)
        if victim is None:
            return
        vq = states[victim].queue
        take = max(1, min(chunk, len(vq)))
        states[k].queue = vq[-take:]
        states[victim].queue = vq[:-take]
        self._round_steals += 1
        if srv.telemetry is not None:
            srv.telemetry.tracer.instant(
                f"exec:{k}", "steal", abs0 + clock.now, cat="sched",
                args={"victim": victim, "n_tasks": take})


# ---------------------------------------------------------------------------
# async (bounded staleness)
# ---------------------------------------------------------------------------

class AsyncEngine(RoundEngine):
    """Continuous bounded-staleness federation.

    The engine persists across ``run_round`` calls: executor virtual clocks,
    queues and in-flight chunks carry over, so "round r" is just the span
    between server updates r and r+1 on the shared virtual axis.  Each
    folded chunk is discounted by γ = 1/(1+λ·s) where s counts the server
    updates since the chunk's dispatch; the server updates after ``goal``
    (default ``clients_per_round``) clients have folded, then broadcasts the
    new payload, re-schedules a fresh selection on the live executors with
    the current workload models, and wakes any idle executor.  An executor
    with an empty queue steals the tail chunk of the predicted-slowest
    queue before going idle.
    """

    mode = "async"

    def __init__(self, staleness_lambda: float = 0.5,
                 chunk_size: Optional[int] = None,
                 pipeline_depth: float = 2.0,
                 goal: Optional[int] = None):
        self.staleness_lambda = float(staleness_lambda)
        self.chunk_size = chunk_size
        self.pipeline_depth = float(pipeline_depth)
        self.goal = goal
        self._states: Optional[Dict[int, _ExecState]] = None
        self._pricer: Optional[_NetSim] = None   # persists across rounds
        self._clock = VirtualClock()
        self._in_system: Set[int] = set()
        self._last_update_t = 0.0
        self._last_sched: Optional[Schedule] = None
        # virtual time the live payload version was broadcast (the comm
        # overlap anchor: a chunk's download can start no earlier)
        self._payload_t = 0.0
        self._reset_window()

    def _reset_window(self) -> None:
        """Clear the per-update accumulators (one 'round' = one window)."""
        self._buffer: Optional[Dict] = None
        self._n_folded = 0
        self._records: List[RunRecord] = []
        self._n_failed = 0
        self._steals = 0
        self._stale_folds = 0
        self._stale_sum = 0.0
        self._counters = FaultCounters()
        # control-plane accumulators (inert without a control plane): the
        # window's realized (n, t, executor, comm) jobs for the oracle, and
        # tasks moved by the commit-tail queue rebalance
        self._oracle_jobs: List[Tuple[float, float, int, float]] = []
        self._rebalance_moved = 0

    # -- checkpointing of the in-flight pipeline ---------------------------
    # The engine persists across rounds, so a checkpoint taken at an update
    # boundary still has a live pipeline: undispatched queues, in-flight
    # chunk completions sitting in the clock (their partials already
    # computed and folded into nothing yet), the payload version executors
    # are training against, and the window accumulators.  All of it is
    # serialised host-side (device arrays -> numpy) as plain data; restore
    # rebuilds the clock heap with the exact (time, seq) ordering, so the
    # resumed run pops the same events in the same order and stays
    # bit-deterministic.  (Client states and the server blob ride the
    # normal checkpoint path; the executor topology must match on restore.)
    # Known gap: params/makespans are bit-exact, but the first resumed
    # round's comm_bytes metric omits the round-end broadcast that the
    # original process sent just before the checkpoint (comm stats are not
    # part of the blob) — metrics accounting only, no effect on training.
    def state_dict(self) -> Dict:
        if self._states is None:
            return {"mode": self.mode, "initialized": False}
        clock = self._clock.state_dict()

        def host_event(kind, data):
            if kind == "chunk_done":
                return (data[0], _host_report(data[1]), data[2])
            if kind == "chunk_arrived":    # in-flight upload (CommEvent)
                return replace(data, partial=_host_tree(data.partial))
            return data

        clock["events"] = [(t, seq, kind, host_event(kind, data))
                           for (t, seq, kind, data) in clock["events"]]
        return {
            "mode": self.mode, "initialized": True,
            "states": {k: dict(queue=list(es.queue), t=es.t,
                               busy_until=es.busy_until, inflight=es.inflight,
                               offset=es.offset, stopped=es.stopped,
                               dead=es.dead)
                       for k, es in self._states.items()},
            "clock": clock,
            "in_system": sorted(self._in_system),
            "last_update_t": self._last_update_t,
            "payload": _host_tree(self._payload),
            "buffer": _host_tree(self._buffer),
            "n_folded": self._n_folded,
            "records": list(self._records),
            "n_failed": self._n_failed,
            "steals": self._steals,
            "stale_folds": self._stale_folds,
            "stale_sum": self._stale_sum,
            "counters": vars(self._counters).copy(),
            "last_sched": self._last_sched,
            "payload_t": self._payload_t,
            "oracle_jobs": [tuple(j) for j in self._oracle_jobs],
            "rebalance_moved": self._rebalance_moved,
        }

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if state.get("mode") != self.mode:
            raise ValueError(f"checkpointed engine state is "
                             f"{state.get('mode')!r}, not {self.mode!r}")
        if not state.get("initialized"):
            return
        self._states = {k: _ExecState(**es)
                        for k, es in state["states"].items()}
        self._clock = VirtualClock.from_state_dict(state["clock"])
        self._in_system = set(state["in_system"])
        self._last_update_t = state["last_update_t"]
        self._payload = state["payload"]
        self._buffer = state["buffer"]
        self._n_folded = state["n_folded"]
        self._records = list(state["records"])
        self._n_failed = state["n_failed"]
        self._steals = state["steals"]
        self._stale_folds = state["stale_folds"]
        self._stale_sum = state["stale_sum"]
        self._counters = FaultCounters(**state.get("counters", {}))
        self._last_sched = state["last_sched"]
        # control-plane state (absent in pre-control checkpoints)
        self._payload_t = state.get("payload_t", 0.0)
        self._oracle_jobs = [tuple(j) for j in state.get("oracle_jobs", [])]
        self._rebalance_moved = state.get("rebalance_moved", 0)

    # ------------------------------------------------------------------
    def _ensure_init(self, srv, netsim: Optional[_NetSim] = None) -> None:
        if self._states is not None:
            return
        srv.virtual_now = self._clock.now
        self._payload = srv.algorithm.broadcast_payload(srv.params,
                                                        srv.server_state)
        if netsim is not None:
            netsim.set_payload(self._payload)
        live = list(srv.executors)
        srv.comm.broadcast(self._payload, live, tag="broadcast")
        self._payload_t = self._clock.now
        n0 = max(1, math.ceil(self.pipeline_depth * srv.clients_per_round))
        tasks = srv.select_clients(n=n0)
        schedule = srv.scheduler.schedule(srv.round, tasks, live,
                                          comm_cost=srv._sched_comm_cost())
        self._last_sched = schedule
        self._states = {k: _ExecState(queue=list(schedule.queue(k)))
                        for k in live}
        self._in_system = {t.client for t in tasks}
        ctrl = self._ctrl(srv)
        ganged: Set[int] = set()
        if ctrl is not None and ctrl.gang_waves and netsim is None:
            chunk = self._chunk_size(srv, self.chunk_size)
            ganged = self._gang_wave(
                srv, srv.round, self._states, self._clock, self._payload,
                chunk, live, lambda k, rep: (k, rep, srv.round))
        for k in live:
            if k not in ganged:
                self._dispatch_next(srv, k, netsim)

    def _refill(self, srv) -> None:
        """Top the pool back up with a fresh selection, re-scheduled onto
        the live executors under the *current* workload models (clients
        already in the system are excluded — a client must fold before it
        can be picked again, which keeps stateful algorithms race-free)."""
        # an executor whose failure event is still in flight gets no new
        # work (it would only need re-homing when the event pops)
        live = [k for k in srv.executors if not self._states[k].dead]
        srv.virtual_now = self._clock.now   # availability filter anchor
        fresh = srv.select_clients(n=srv.clients_per_round,
                                   exclude=self._in_system)
        if not fresh or not live:
            return
        schedule = srv.scheduler.schedule(srv.round, fresh, live,
                                          comm_cost=srv._sched_comm_cost())
        self._last_sched = schedule
        for k in live:
            # offset is NOT reset: fail_at's task index counts tasks
            # dispatched by this executor cumulatively, so every index is
            # reachable and no (round, index) coordinate repeats
            self._states[k].queue.extend(schedule.queue(k))
        self._in_system.update(t.client for t in fresh)

    # ------------------------------------------------------------------
    def _lambda(self, srv) -> float:
        """The staleness λ folds discount with: the controller's current
        value when an :class:`AsyncLambdaController` is attached (DESIGN.md
        §12), else the engine's static ``staleness_lambda`` — which is also
        the controller's fallback before its first update."""
        ctrl = self._ctrl(srv)
        if ctrl is not None and ctrl.async_lambda is not None:
            return ctrl.async_lambda.current(self.staleness_lambda)
        return self.staleness_lambda

    def _dispatch_next(self, srv, k: int,
                       netsim: Optional[_NetSim] = None) -> None:
        es = self._states[k]
        if es.dead:
            return
        fi = srv.faults    # async clock is absolute: fault times are local
        chunk = self._chunk_size(srv, self.chunk_size)
        comm_pred = netsim.comm_pred if netsim is not None else None
        while True:
            if not es.queue:
                # work stealing: grab the tail chunk of the predicted-
                # slowest queue (its owner was never going to reach it soon
                # anyway)
                victim = pick_steal_victim(
                    {j: s.queue for j, s in self._states.items()},
                    {j: (s.busy_until if s.inflight else s.t)
                     for j, s in self._states.items()},
                    srv.estimator.last_fit, k, chunk, comm_pred)
                if victim is None:
                    return        # nothing anywhere: idle until refill
                vq = self._states[victim].queue
                es.queue, self._states[victim].queue = \
                    vq[-chunk:], vq[:-chunk]
                self._steals += 1
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        f"exec:{k}", "steal", self._clock.now, cat="sched",
                        args={"victim": victim, "n_tasks": len(es.queue)})
            tasks, es.queue = es.queue[:chunk], es.queue[chunk:]
            start = max(es.t, self._clock.now)
            if fi is not None and fi.crash_due(k, start) is not None:
                # crash due before this chunk dispatches: dead now, the
                # chunk and queue re-home through the failure event
                fi.fire_crash(k, start)
                self._counters.crashes += 1
                self._clock.push(start, "executor_failed",
                                 (k, tasks + es.queue))
                es.queue = []
                es.dead = True
                return
            if netsim is not None or fi is not None:
                model = srv.estimator.last_fit.get(k)
                if fi is not None:
                    model = fi.scaled_model(model, k, start)
                pred = predict_span(model, tasks, comm_pred)
            if fi is not None:
                # mid-compute dropout: dropped clients leave the system so
                # a later refill can re-select them once their window ends
                tasks, f_drop = fi.split_up(tasks, start, pred)
                if f_drop:
                    self._counters.dropped_clients += len(f_drop)
                    self._in_system.difference_update(
                        t.client for t in f_drop)
                if not tasks:
                    continue      # whole chunk dropped: try the next one
            if netsim is not None:
                # availability dropout: dropped clients leave the system so
                # a later refill can re-select them once they're back — the
                # async re-run path
                tasks, av_dropped = netsim.split_available(tasks, start,
                                                           pred)
                self._in_system.difference_update(
                    t.client for t in av_dropped)
                if not tasks:
                    continue      # whole chunk offline: try the next one
            rnd = srv.round
            try:
                rep = srv.executors[k].run_queue(
                    rnd, tasks, self._payload, srv.data_by_client,
                    task_offset=es.offset)
            except ExecutorFailure:
                self._clock.push(start, "executor_failed",
                                 (k, tasks + es.queue))
                es.queue = []
                es.dead = True   # no re-dispatch while the event is in flight
                return
            es.offset += len(tasks)
            es.inflight = True
            if es.queue and srv.algorithm.stateful:
                # schedule-keyed prefetch: the next chunk's state shards
                # stage while this chunk's span elapses on the virtual clock
                sm = srv.executors[k].state_manager
                if sm is not None:
                    sm.prefetch(prefetch_ids(es.queue, chunk))
            if fi is not None:
                scale_report(rep, fi.slowdown(k, start))
                down_un = 0.0   # unaccounted read: push_chunk does billing
                if netsim is not None and netsim.net is not None \
                        and rep.n_tasks:
                    down_un = netsim.net.download_time(
                        rep.completed_clients, netsim.payload_nbytes)
                hit = fi.crash_in(k, start,
                                  start + down_un + rep.virtual_time)
                if hit is not None:
                    # died inside the chunk's span: chunk lost, queue
                    # re-homes at the crash time
                    fi.fire_crash(k, hit[1])
                    self._counters.crashes += 1
                    self._clock.push(hit[1], "executor_failed",
                                     (k, tasks + es.queue))
                    es.queue = []
                    es.dead = True
                    return
            if netsim is None:
                es.busy_until = start + rep.virtual_time
                self._clock.push(es.busy_until, "chunk_done", (k, rep, rnd))
                if srv.telemetry is not None and rep.n_tasks:
                    srv.telemetry.tracer.span(
                        f"exec:{k}", "chunk", start, es.busy_until,
                        cat="busy",
                        args={"round": rnd, "n_tasks": rep.n_tasks,
                              "down_s": 0.0})
                    if rep.compiles:
                        srv.telemetry.registry.counter(
                            f"host/exec{k}/compiles").inc(rep.compiles)
                return
            # comm-priced chunk: busy for download + compute; the upload
            # overlaps the next chunk and folds when its arrival event pops
            # (staleness then counts server updates across the comm delay)
            ctrl = self._ctrl(srv)
            es.busy_until = netsim.push_chunk(
                self._clock, rep, start, (k, rep, rnd),
                self._chunk_record(srv, rnd, rep), version=rnd,
                fi=fi, counters=self._counters,
                # the live payload was broadcast at _payload_t: with
                # overlap_comm on, the download overlaps the lane's earlier
                # compute instead of serializing into this chunk's span
                overlap_from=(self._payload_t
                              if ctrl is not None and ctrl.overlap_comm
                              else None))
            return

    # ------------------------------------------------------------------
    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        t_wall = time.perf_counter()
        # ONE pricer for the engine's whole life (the pipeline crosses
        # round boundaries, so tail dispatches must bill the next window);
        # the async clock is already absolute, so it anchors at t0=0
        if self._pricer is None:
            self._pricer = self._netsim(srv, 0.0)
        netsim = self._pricer
        # fault lifecycle at the window boundary: revive executors whose
        # restart came due (crashes fire at dispatch granularity inside
        # _dispatch_next — the async clock never jumps a round at a time)
        fi = srv.faults
        if fi is not None:
            for k in fi.restarts_due(self._clock.now):
                if srv._revive_executor(k):
                    self._counters.restarts += 1
                    if srv.telemetry is not None:
                        srv.telemetry.tracer.instant(
                            f"exec:{k}", "restart", self._clock.now,
                            cat="fault")
                    if self._states is not None:
                        self._states[k] = _ExecState(t=self._clock.now)
        self._ensure_init(srv, netsim)
        rnd = srv.round
        goal = self.goal or srv.clients_per_round

        futile_wakes = 0   # boundary-jumps without a single dispatch
        while self._n_folded < goal:
            if not self._clock:
                if self._n_folded > 0:
                    break          # drained: update with what we have
                self._refill(srv)
                for k in list(self._states):
                    if not self._states[k].inflight:
                        self._dispatch_next(srv, k, netsim)
                if not self._clock:
                    if netsim is not None and netsim.avail is not None:
                        # nobody dispatchable: sleep until the next client
                        # comes online — or, if clients are online but every
                        # dispatch predicted a mid-chunk expiry, until an
                        # availability window flips (waking "now" would spin
                        # the select/drop cycle nanosecond by nanosecond)
                        t_next = srv._next_available_time(
                            exclude=self._in_system)
                        if t_next <= self._clock.now:
                            t_next = srv._next_availability_change(
                                exclude=self._in_system)
                        futile_wakes += 1
                        if math.isfinite(t_next) and futile_wakes <= 256:
                            self._clock.push(
                                max(t_next, self._clock.now + 1e-9),
                                "wake", None)
                            continue
                        if futile_wakes > 256:
                            raise RuntimeError(
                                "async engine starved: every availability "
                                "window is predicted too short for a chunk "
                                "(256 futile window-boundary jumps)")
                    raise RuntimeError("async engine starved: no runnable "
                                       "clients on any executor")
                continue
            ev = self._clock.pop()
            srv.virtual_now = self._clock.now
            if ev.kind != "wake":
                futile_wakes = 0          # real progress resets the bound
            if ev.kind == "chunk_done":
                k, rep, version = ev.data
                es = self._states[k]
                es.t, es.inflight = ev.time, False
                if netsim is None and rep.n_tasks:
                    if fi is not None and fi.take_corrupt(k, ev.time):
                        # corrupted partial: discard; clients with retry
                        # budget left leave the system so the next refill
                        # re-selects them (the async re-run path)
                        self._counters.corrupt_payloads += 1
                        retryc, give_up = fi.charge_retry(
                            rep.completed_clients)
                        self._counters.retries += len(retryc)
                        self._counters.dropped_clients += len(give_up)
                        fi.clear_retries(give_up)
                        self._in_system.difference_update(
                            rep.completed_clients)
                    else:
                        wire = self._wire(srv, k, rep.partial)
                        s = srv.round - version
                        if srv.telemetry is not None:
                            srv.telemetry.tracer.instant(
                                "server", "fold", ev.time, cat="server",
                                args={"round": srv.round, "executor": k,
                                      "n_tasks": rep.n_tasks,
                                      "staleness": s})
                            srv.telemetry.registry.histogram(
                                "hist/staleness").observe(s)
                        gamma = staleness_weight(s, self._lambda(srv))
                        self._buffer = merge_partials(
                            self._buffer, scale_partial(wire, gamma))
                        self._n_folded += rep.n_tasks
                        if s > 0:
                            self._stale_folds += 1
                        self._stale_sum += s
                        rec = self._chunk_record(srv, version, rep)
                        if rec is not None:
                            self._records.append(rec)
                            if self._ctrl(srv) is not None:
                                self._oracle_jobs.append(
                                    (rec.n_samples, rec.time,
                                     rec.executor, 0.0))
                        self._in_system.difference_update(
                            rep.completed_clients)
                        if fi is not None:
                            fi.clear_retries(rep.completed_clients)
                self._dispatch_next(srv, k, netsim)
            elif ev.kind == "chunk_arrived":
                # the upload landed: fold it, discounted by the staleness
                # accrued across compute AND comm delay
                ce = ev.data
                if fi is not None and fi.take_corrupt(ce.executor, ev.time):
                    self._counters.corrupt_payloads += 1
                    retryc, give_up = fi.charge_retry(ce.completed_clients)
                    self._counters.retries += len(retryc)
                    self._counters.dropped_clients += len(give_up)
                    fi.clear_retries(give_up)
                    self._in_system.difference_update(ce.completed_clients)
                else:
                    s = srv.round - ce.version
                    if srv.telemetry is not None:
                        srv.telemetry.tracer.instant(
                            "server", "fold", ev.time, cat="server",
                            args={"round": srv.round,
                                  "executor": ce.executor,
                                  "n_tasks": ce.n_tasks, "staleness": s})
                        srv.telemetry.registry.histogram(
                            "hist/staleness").observe(s)
                        srv.telemetry.registry.histogram(
                            "hist/upload_delay").observe(
                                max(ev.time - ce.t_sent, 0.0))
                    gamma = staleness_weight(s, self._lambda(srv))
                    self._buffer = merge_partials(
                        self._buffer, scale_partial(ce.partial, gamma))
                    self._n_folded += ce.n_tasks
                    if s > 0:
                        self._stale_folds += 1
                    self._stale_sum += s
                    if ce.record is not None:
                        self._records.append(ce.record)
                        if self._ctrl(srv) is not None:
                            self._oracle_jobs.append((
                                ce.record.n_samples, ce.record.time,
                                ce.record.executor,
                                netsim.net.chunk_comm_time(
                                    list(ce.completed_clients),
                                    netsim.payload_nbytes, ce.wire_bytes)
                                if netsim.net is not None else 0.0))
                    self._in_system.difference_update(ce.completed_clients)
                    if fi is not None:
                        fi.clear_retries(ce.completed_clients)
            elif ev.kind == "upload_lost":
                # every re-send timed out, or a client dropped mid-upload:
                # charge the budget and release the clients so a later
                # refill can re-select the retryable ones
                _k, lost_clients = ev.data
                retryc, give_up = fi.charge_retry(lost_clients)
                self._counters.retries += len(retryc)
                self._counters.dropped_clients += len(give_up)
                fi.clear_retries(give_up)
                self._in_system.difference_update(lost_clients)
            elif ev.kind == "wake":
                self._refill(srv)
                for k in list(self._states):
                    if not self._states[k].inflight:
                        self._dispatch_next(srv, k, netsim)
            else:  # executor_failed
                dead, remaining = ev.data
                self._n_failed += 1
                if srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        f"exec:{dead}", "executor_failed", ev.time,
                        cat="fault")
                survivors = self._fail_over(srv, self._states, dead,
                                            remaining)
                for j in survivors:
                    if not self._states[j].inflight:
                        self._dispatch_next(srv, j, netsim)

        # ---- server update (one bounded-staleness window == one round) ---
        ops = srv.algorithm.ops()
        agg = srv.global_fold([self._buffer])
        agg["_n_selected"] = self._n_folded
        srv.params, srv.server_state = srv.algorithm.server_update(
            srv.params, agg, srv.server_state, len(srv.data_by_client))

        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 self._records)
        srv.estimator.record_many(self._records)
        win0 = self._last_update_t    # the window's absolute start
        makespan = self._clock.now - self._last_update_t
        self._last_update_t = self._clock.now
        srv.virtual_now = self._clock.now
        stats = srv.comm.stats.reset()
        sched = self._last_sched
        n_folds = max(len(self._records), 1)
        extra = {"steals": float(self._steals),
                 "stale_folds": float(self._stale_folds),
                 "mean_staleness": self._stale_sum / n_folds,
                 "in_system": float(len(self._in_system))}
        ctrl = self._ctrl(srv)
        if ctrl is not None:
            extra["oracle_makespan"] = oracle_makespan(
                self._oracle_jobs, list(srv.executors))
            extra["staleness_lambda"] = self._lambda(srv)
            if self._rebalance_moved:
                extra["rebalanced_tasks"] = float(self._rebalance_moved)
            if ctrl.async_lambda is not None:
                # one controller step per commit, from the closed window's
                # mean observed staleness (applies from the next fold on)
                new_lam = ctrl.async_lambda.update(
                    self._stale_sum / n_folds)
                note = getattr(ctrl, "note", None)
                if note is not None:
                    note("staleness_lambda", new_lam, self._clock.now)
        if netsim is not None:
            extra.update(netsim.extra())
            # tail dispatches below happen after this window's metrics were
            # read: their comm bills the NEXT window on the shared pricer
            netsim.reset_counters()
        if fi is not None:
            self._fault_extra(extra, self._counters)
        sm_extra = srv._state_manager_extra()
        if sm_extra is not None:
            extra["state_manager"] = sm_extra
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=sched.schedule_time_s if sched else 0.0,
            estimate_time=sched.estimate_time_s if sched else 0.0,
            predicted_makespan=(sched.predicted_makespan if sched
                                else float("nan")),
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=self._n_folded, n_executors=len(srv.executors),
            estimation_error=err, failures=self._n_failed,
            extra=extra)
        if srv.telemetry is not None:
            srv.telemetry.tracer.instant(
                "server", "commit", self._clock.now, cat="server",
                args={"round": rnd, "n_folded": self._n_folded,
                      "mean_staleness": self._stale_sum / n_folds})
            for k in sorted(self._states):
                srv.telemetry.registry.histogram(
                    "hist/queue_depth").observe(len(self._states[k].queue))
        srv._commit_metrics(metrics, win0)
        srv.round += 1
        self._reset_window()

        # new version: broadcast Θ^{r+1} (counted in the next window's comm
        # stats), top the pool up, wake idle executors
        self._payload = srv.algorithm.broadcast_payload(srv.params,
                                                        srv.server_state)
        if netsim is not None:
            netsim.set_payload(self._payload)
        srv.comm.broadcast(self._payload, list(srv.executors),
                           tag="broadcast")
        self._payload_t = self._clock.now
        self._refill(srv)
        if ctrl is not None and ctrl.rebalance and srv.estimator.last_fit:
            # Pollen-style commit-tail rebalance (DESIGN.md §12): pool every
            # undispatched task and re-pack LPT under the CURRENT models,
            # seeding each lane with its busy horizon — in-flight chunks
            # never move, so nothing double-executes
            live_r = [k for k in srv.executors if not self._states[k].dead]
            if len(live_r) >= 2:
                horizons = {
                    k: (self._states[k].busy_until
                        if self._states[k].inflight
                        else max(self._states[k].t, self._clock.now))
                    for k in live_r}
                reb = (srv.placement.rebalance if srv.placement is not None
                       else rebalance_queues)
                assignment, moved = reb(
                    {k: self._states[k].queue for k in live_r}, horizons,
                    srv.estimator.last_fit, srv._sched_comm_cost())
                for k in live_r:
                    self._states[k].queue = assignment[k]
                self._rebalance_moved += moved
                if moved and srv.telemetry is not None:
                    srv.telemetry.tracer.instant(
                        "server", "rebalance", self._clock.now,
                        cat="sched", args={"moved": moved})
        ganged: Set[int] = set()
        if ctrl is not None and ctrl.gang_waves and netsim is None:
            chunk = self._chunk_size(srv, self.chunk_size)
            ganged = self._gang_wave(
                srv, srv.round, self._states, self._clock, self._payload,
                chunk, list(self._states), lambda k, rep: (k, rep, srv.round))
        for k in list(self._states):
            if k not in ganged and not self._states[k].inflight:
                self._dispatch_next(srv, k, netsim)

        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics
