"""Event-driven round engines: pluggable BSP / semi-sync / async synchronization.

``ParrotServer.run_round`` delegates to a :class:`RoundEngine`.  All three
engines speak the same vocabulary — executor *chunks* complete as events on
the shared :class:`~repro.core.clock.VirtualClock`, failures are events,
partials cross the comm layer on the flat wire format — and differ only in
*when the server folds and updates* (DESIGN.md §3):

``bsp``
    The paper's Algorithm 2, bit-exact with the pre-engine loop: every
    executor drains its whole queue, the round barrier collects the K
    partials in executor order, round time is ``max_k Σ T̂``.  Failures
    re-run the dead executor's remaining clients on the survivors and
    shrink K (elastic membership); speculative backup tasks duplicate the
    predicted-slowest tail.

``semi-sync``
    Over-selects clients, derives a virtual-time deadline from the fitted
    workload model, folds whatever chunk partials have landed by the
    deadline and carries unfinished tasks into the next round's pool —
    stragglers lose work share instead of gating the round.

``async``
    No barrier at all: executors emit a partial per chunk as they complete;
    the server folds each one as it lands, discounted by the bounded-
    staleness weight γ = 1/(1+λ·s) where s is the number of server updates
    since the chunk's payload was broadcast.  A model update fires every
    ``goal`` folded clients; idle executors steal chunks from the
    predicted-slowest queue.  Round time becomes the virtual span between
    updates — the straggler's tail is hidden, not scheduled around.

The semi-sync and async engines run a deterministic discrete-event
simulation: chunks execute lazily at their virtual dispatch time (every
earlier event has already been processed, so each chunk sees the params
version and queue state a causally-correct parallel run would show it), and
event order is a pure function of the per-chunk virtual durations.
"""
from __future__ import annotations

import concurrent.futures as cf
import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.core.aggregation import (merge_partials, scale_partial,
                                    staleness_weight)
from repro.core.clock import VirtualClock
from repro.core.executor import ExecutorFailure, ExecutorReport
from repro.core.scheduler import (ClientTask, Schedule, pick_steal_victim,
                                  predict_remaining, predict_span)
from repro.core.workload import RunRecord


def _host_tree(tree):
    """Device arrays -> host numpy for checkpoint blobs; everything else
    (floats, FlatLayout leaves, RunRecords) passes through untouched."""
    if tree is None:
        return None
    return jax.tree.map(
        lambda x: np.asarray(x) if hasattr(x, "sharding") else x, tree)


def _host_report(rep: ExecutorReport) -> ExecutorReport:
    """Host-side copy of an in-flight chunk report (jax.tree.map does not
    descend into the dataclass, so the partial converts explicitly)."""
    return ExecutorReport(
        executor=rep.executor, partial=_host_tree(rep.partial),
        records=list(rep.records), virtual_time=rep.virtual_time,
        wall_time=rep.wall_time, n_tasks=rep.n_tasks,
        completed_clients=list(rep.completed_clients))


@dataclass
class _ExecState:
    """Per-executor bookkeeping inside the discrete-event simulation."""
    queue: List[ClientTask] = field(default_factory=list)
    t: float = 0.0            # virtual time of the last completed chunk
    busy_until: float = 0.0   # completion time of the in-flight chunk
    inflight: bool = False
    offset: int = 0           # cumulative dispatched-task index (fail_at)
    stopped: bool = False     # semi-sync: hit the deadline, queue carried
    dead: bool = False        # failure event pushed but not yet processed


class RoundEngine:
    """One synchronization mode.  Engines may keep state across rounds (the
    async engine does); a server owns exactly one engine instance.

    Engines with cross-round state implement ``state_dict`` /
    ``load_state_dict`` (plain-data, host-resident blobs) so the checkpoint
    manager can save and deterministically resume them mid-pipeline."""

    mode: str = "?"

    def run_round(self, srv) -> "RoundMetrics":
        raise NotImplementedError

    def state_dict(self) -> Optional[Dict]:
        return None                 # stateless between rounds (BSP)

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if state:
            raise ValueError(f"engine {self.mode!r} cannot restore state")

    # -- shared plumbing ---------------------------------------------------
    def _chunk_size(self, srv, override: Optional[int]) -> int:
        if override:
            return max(1, int(override))
        return max(e.client_block for e in srv.executors.values())

    def _wire(self, srv, executor: int, partial: Dict) -> Dict:
        """Ship one partial through the comm layer (compress → send → poll →
        decompress): the copy that reaches aggregation is the one that
        crossed the wire, keeping error-feedback residuals in sync."""
        srv.comm.executor_send(executor, srv._maybe_compress(partial),
                               tag="partial")
        wire = srv.comm.poll(executor, tag="partial")
        if wire is None:      # transport without immediate local delivery
            wire = srv.comm.recv_from_executor(executor, tag="partial")
        return srv._maybe_decompress(wire)

    def _chunk_record(self, srv, rnd: int, rep: ExecutorReport
                      ) -> Optional[RunRecord]:
        """Per-chunk timing record (workload.py): one (N_total, T̂) pair per
        chunk — what the engines' chunk-granular predictions consume."""
        if rep.n_tasks == 0:
            return None
        n = sum(srv.data_by_client[c].n_samples
                for c in rep.completed_clients)
        return RunRecord(round=rnd, client=rep.completed_clients[0],
                         executor=rep.executor, n_samples=n,
                         time=rep.virtual_time, n_tasks=rep.n_tasks)

    def _fail_over(self, srv, states: Dict[int, _ExecState], dead: int,
                   remaining: List[ClientTask]) -> List[int]:
        """Elastic failure as an engine event: drop the dead executor
        (K shrink), append its unfinished tasks round-robin onto the
        survivors' queues.  Tasks assigned to the dead executor *after* its
        failure event was pushed (an async refill can land in between) are
        still parked on its queue and re-home too.  Returns survivor ids."""
        srv._drop_executor(dead)
        dead_state = states.pop(dead, None)
        if dead_state is not None and dead_state.queue:
            remaining = list(remaining) + dead_state.queue
        survivors = sorted(states)
        if not survivors:
            raise RuntimeError("all executors failed")
        for i, t in enumerate(remaining):
            states[survivors[i % len(survivors)]].queue.append(t)
        return survivors


def make_engine(mode: str, **opts) -> RoundEngine:
    modes = {"bsp": BSPEngine, "semi-sync": SemiSyncEngine,
             "semi_sync": SemiSyncEngine, "async": AsyncEngine}
    if mode not in modes:
        raise ValueError(f"unknown round engine {mode!r}; "
                         f"choose from {sorted(set(modes))}")
    return modes[mode](**opts)


# ---------------------------------------------------------------------------
# BSP
# ---------------------------------------------------------------------------

class BSPEngine(RoundEngine):
    """Algorithm 2 as an event flow, bit-exact with the pre-engine loop.

    BSP is a barrier: every queue completion lands *at* the barrier, so all
    events carry virtual time 0 and pop in push order — executor-dict order
    for the serial path, completion order for ``parallel_dispatch`` — which
    reproduces the legacy partial/fold order exactly (float summation is not
    associative; order is part of bit-exactness).
    """

    mode = "bsp"

    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        rnd = srv.round
        t_wall = time.perf_counter()
        if srv._next_tasks is not None:
            tasks, srv._next_tasks = srv._next_tasks, None
        else:
            tasks = srv.select_clients()

        # compute-comm overlap: the schedule for this round may have been
        # prepared while the previous round's global reduce was in flight.
        # An executor lost since then would still own a queue here — re-map
        # orphaned queues onto the live set (the dropped-clients fix).
        remapped = 0
        if srv._pending_schedule is not None:
            schedule, overlapped = srv._pending_schedule, True
            srv._pending_schedule = None
            remapped = schedule.remap(list(srv.executors))
        else:
            schedule, overlapped = srv.scheduler.schedule(
                rnd, tasks, list(srv.executors)), False

        payload = srv.algorithm.broadcast_payload(srv.params,
                                                  srv.server_state)
        skip_map, n_backups = srv._plan_backups(schedule)
        reports, n_failed = self._dispatch(srv, rnd, schedule, payload,
                                           skip_map)

        # overlap: prepare round r+1's schedule "while the reduce is in
        # flight" (before the global_aggregate below consumes the partials)
        if srv.overlap_scheduling:
            srv.estimator.record_many(
                [rec for r in reports for rec in r.records])
            srv._next_tasks = srv.select_clients()
            srv._pending_schedule = srv.scheduler.schedule(
                rnd + 1, srv._next_tasks, list(srv.executors))

        partials = [r.partial for r in reports]   # already the wire copies
        ops = srv.algorithm.ops()
        agg = srv.global_fold(partials)
        agg["_n_selected"] = sum(r.n_tasks for r in reports)
        srv.params, srv.server_state = srv.algorithm.server_update(
            srv.params, agg, srv.server_state, len(srv.data_by_client))

        records = [rec for r in reports for rec in r.records]
        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 records)
        if not srv.overlap_scheduling:  # overlap path already recorded them
            srv.estimator.record_many(records)
        makespan = max((r.virtual_time for r in reports), default=0.0)
        stats = srv.comm.stats.reset()
        extra = {"backup_tasks": float(n_backups)}
        if remapped:
            extra["remapped_tasks"] = float(remapped)
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=0.0 if overlapped else schedule.schedule_time_s,
            estimate_time=0.0 if overlapped else schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(srv.executors),
            estimation_error=err, failures=n_failed, extra=extra)
        srv.history.append(metrics)
        srv.round += 1
        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics

    # ------------------------------------------------------------------
    def _dispatch(self, srv, rnd: int, schedule: Schedule, payload: Dict,
                  skip_map: Optional[Dict[int, Set[int]]] = None
                  ) -> Tuple[List[ExecutorReport], int]:
        live = list(srv.executors)
        srv.comm.broadcast(payload, live, tag="broadcast")
        clock = VirtualClock()
        reports: List[ExecutorReport] = []
        failed: List[int] = []
        done_clients: set = set()

        def run(k: int) -> ExecutorReport:
            return srv.executors[k].run_queue(
                rnd, schedule.queue(k), payload, srv.data_by_client,
                skip_clients=(skip_map or {}).get(k))

        # SPMD gang dispatch (DESIGN.md §8): under a one-executor-per-device
        # placement, a round whose queues plan into aligned block waves runs
        # each wave as ONE sharded execution across the mesh — per-device
        # threads give real wall-clock overlap even where per-device
        # dispatches serialize (CPU PJRT).  Reports come back in executor
        # order with per-executor content identical to the serial path, so
        # the barrier semantics (and bit-exactness) are unchanged.
        ganged = None
        if srv.gang_dispatch and not srv.parallel_dispatch:
            from repro.core.executor import run_queues_ganged
            ganged = run_queues_ganged(
                srv.executors, rnd, {k: schedule.queue(k) for k in live},
                payload, srv.data_by_client, srv.placement, skip_map)
        # barrier semantics: every outcome lands at t=0; seq order preserves
        # the legacy collection order
        if ganged is not None:
            for k in live:
                clock.push(0.0, "queue_done", ganged[k])
        elif srv.parallel_dispatch:
            with cf.ThreadPoolExecutor(max_workers=len(live)) as pool:
                futs = {pool.submit(run, k): k for k in live}
                for fut in cf.as_completed(futs):
                    k = futs[fut]
                    try:
                        clock.push(0.0, "queue_done", fut.result())
                    except ExecutorFailure:
                        clock.push(0.0, "executor_failed", k)
        else:
            for k in live:
                try:
                    clock.push(0.0, "queue_done", run(k))
                except ExecutorFailure:
                    clock.push(0.0, "executor_failed", k)

        for ev in clock.drain():
            if ev.kind == "queue_done":
                reports.append(ev.data)
            else:
                failed.append(ev.data)

        # ---- fault handling: re-run failed queues on the survivors -------
        if failed:
            for rep in reports:
                done_clients.update(rep.completed_clients)
            survivors = [k for k in live if k not in failed]
            if not survivors:
                raise RuntimeError("all executors failed")
            # dedup by client: with backup duplicates a task can sit in two
            # failed queues at once and must still re-run (and fold) once
            leftovers: List[ClientTask] = []
            for k in failed:
                for t in schedule.queue(k):
                    if t.client not in done_clients:
                        done_clients.add(t.client)
                        leftovers.append(t)
                srv._drop_executor(k)          # elastic K shrink
            for i, t in enumerate(leftovers):  # round-robin retry placement
                k = survivors[i % len(survivors)]
                rep = srv.executors[k].run_queue(
                    rnd, [t], payload, srv.data_by_client)
                reports.append(rep)

        # the partial that reaches aggregation is the one that crossed the
        # wire: compress once, ship, and aggregate the decompressed copy
        # (error-feedback residuals and the aggregated values stay in sync)
        for rep in reports:
            srv.comm.executor_send(rep.executor,
                                   srv._maybe_compress(rep.partial),
                                   tag="partial")
            rep.partial = srv._maybe_decompress(
                srv.comm.recv_from_executor(rep.executor, tag="partial"))
        return reports, len(failed)


# ---------------------------------------------------------------------------
# semi-sync
# ---------------------------------------------------------------------------

class SemiSyncEngine(RoundEngine):
    """Deadline-bounded rounds with over-selection and task carry-over.

    ``over_select`` inflates the per-round selection (so the deadline cut
    still folds ~``clients_per_round`` results); the deadline is
    ``deadline_frac ×`` the schedule's predicted makespan (∞ during warmup,
    when no workload model exists — the round then degenerates to BSP).
    An executor dispatches its next chunk only if the fitted model predicts
    it lands before the deadline; everything it does not dispatch — plus a
    dead executor's re-homed tasks that miss the deadline on the survivors —
    carries into the next round's selection pool.  Every executor gets its
    first chunk unconditionally, so a round always makes progress.
    """

    mode = "semi-sync"

    def __init__(self, over_select: float = 1.5, deadline_frac: float = 0.75,
                 chunk_size: Optional[int] = None):
        self.over_select = float(over_select)
        self.deadline_frac = float(deadline_frac)
        self.chunk_size = chunk_size
        self._carry: List[ClientTask] = []

    # -- checkpointing: the carry pool is the only cross-round state -------
    def state_dict(self) -> Dict:
        return {"mode": self.mode, "carry": list(self._carry)}

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if state.get("mode") != self.mode:
            raise ValueError(f"checkpointed engine state is "
                             f"{state.get('mode')!r}, not {self.mode!r}")
        self._carry = list(state["carry"])

    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        rnd = srv.round
        t_wall = time.perf_counter()

        target = max(1, math.ceil(self.over_select * srv.clients_per_round))
        carried, self._carry = self._carry, []
        n_fresh = max(0, target - len(carried))
        fresh = srv.select_clients(
            n=n_fresh, exclude=[t.client for t in carried])
        tasks = carried + fresh
        schedule = srv.scheduler.schedule(rnd, tasks, list(srv.executors))
        payload = srv.algorithm.broadcast_payload(srv.params,
                                                  srv.server_state)
        live = list(srv.executors)
        srv.comm.broadcast(payload, live, tag="broadcast")

        models = dict(srv.estimator.last_fit)
        chunk = self._chunk_size(srv, self.chunk_size)
        # the deadline lives in the same units the executors accrue: the
        # chunk-granular predicted makespan of this schedule (the per-task
        # Eq.-4 prediction pays one offset b per *task* and would overshoot
        # a chunked round by ~(chunk-1)·b per chunk, leaving the deadline
        # unreachable).  No models yet (warmup) -> ∞ -> a full BSP round.
        pm = max((predict_remaining(models.get(k), schedule.queue(k), chunk)
                  for k in live), default=0.0)
        deadline = self.deadline_frac * pm if pm > 0.0 else float("inf")

        clock = VirtualClock()
        states = {k: _ExecState(queue=list(schedule.queue(k))) for k in live}
        partials: List[Dict] = []
        records: List[RunRecord] = []
        n_landed = 0
        n_failed = 0
        for k in live:
            self._dispatch_next(srv, rnd, k, states, clock, payload, models,
                                deadline, chunk)
        while clock:
            ev = clock.pop()
            if ev.kind == "chunk_done":
                k, rep = ev.data
                es = states[k]
                es.t, es.inflight = ev.time, False
                if rep.n_tasks:
                    partials.append(self._wire(srv, k, rep.partial))
                    rec = self._chunk_record(srv, rnd, rep)
                    if rec is not None:
                        records.append(rec)
                    n_landed += rep.n_tasks
                self._dispatch_next(srv, rnd, k, states, clock, payload,
                                    models, deadline, chunk)
            else:  # executor_failed
                dead, remaining = ev.data
                n_failed += 1
                survivors = self._fail_over(srv, states, dead, remaining)
                for j in survivors:
                    if states[j].stopped:
                        # already past the deadline: re-homed tasks carry
                        # over instead of silently parking on a stopped queue
                        self._carry.extend(states[j].queue)
                        states[j].queue = []
                    elif not states[j].inflight:  # wake finished survivors
                        self._dispatch_next(srv, rnd, j, states, clock,
                                            payload, models, deadline, chunk)

        ops = srv.algorithm.ops()
        if partials:
            agg = srv.global_fold(partials)
            agg["_n_selected"] = n_landed
            srv.params, srv.server_state = srv.algorithm.server_update(
                srv.params, agg, srv.server_state, len(srv.data_by_client))

        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 records)
        srv.estimator.record_many(records)
        makespan = max((es.t for es in states.values()), default=0.0)
        stats = srv.comm.stats.reset()
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=schedule.schedule_time_s,
            estimate_time=schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(srv.executors),
            estimation_error=err, failures=n_failed,
            extra={"landed_clients": float(n_landed),
                   "carried_tasks": float(len(self._carry)),
                   "deadline": deadline})
        srv.history.append(metrics)
        srv.round += 1
        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics

    # ------------------------------------------------------------------
    def _dispatch_next(self, srv, rnd, k, states, clock, payload, models,
                       deadline, chunk) -> None:
        es = states[k]
        if not es.queue or es.stopped or es.dead:
            return
        next_chunk = es.queue[:chunk]
        pred = predict_span(models.get(k), next_chunk)
        start = max(es.t, clock.now)
        if es.t > 0.0 and start + pred > deadline:
            # predicted to miss the deadline: stop here, carry the rest
            # (first chunk is exempt — a round always makes progress)
            es.stopped = True
            self._carry.extend(es.queue)
            es.queue = []
            return
        es.queue = es.queue[chunk:]
        try:
            rep = srv.executors[k].run_queue(
                rnd, next_chunk, payload, srv.data_by_client,
                task_offset=es.offset)
        except ExecutorFailure:
            # the failing chunk never folded: every one of its clients must
            # re-home along with the rest of the queue.  The executor is
            # dead the moment the event is pushed — nothing may dispatch on
            # it while the event waits in the queue.
            clock.push(start, "executor_failed", (k, next_chunk + es.queue))
            es.queue = []
            es.dead = True
            return
        es.offset += len(next_chunk)
        es.inflight = True
        es.busy_until = start + rep.virtual_time
        clock.push(es.busy_until, "chunk_done", (k, rep))


# ---------------------------------------------------------------------------
# async (bounded staleness)
# ---------------------------------------------------------------------------

class AsyncEngine(RoundEngine):
    """Continuous bounded-staleness federation.

    The engine persists across ``run_round`` calls: executor virtual clocks,
    queues and in-flight chunks carry over, so "round r" is just the span
    between server updates r and r+1 on the shared virtual axis.  Each
    folded chunk is discounted by γ = 1/(1+λ·s) where s counts the server
    updates since the chunk's dispatch; the server updates after ``goal``
    (default ``clients_per_round``) clients have folded, then broadcasts the
    new payload, re-schedules a fresh selection on the live executors with
    the current workload models, and wakes any idle executor.  An executor
    with an empty queue steals the tail chunk of the predicted-slowest
    queue before going idle.
    """

    mode = "async"

    def __init__(self, staleness_lambda: float = 0.5,
                 chunk_size: Optional[int] = None,
                 pipeline_depth: float = 2.0,
                 goal: Optional[int] = None):
        self.staleness_lambda = float(staleness_lambda)
        self.chunk_size = chunk_size
        self.pipeline_depth = float(pipeline_depth)
        self.goal = goal
        self._states: Optional[Dict[int, _ExecState]] = None
        self._clock = VirtualClock()
        self._in_system: Set[int] = set()
        self._last_update_t = 0.0
        self._last_sched: Optional[Schedule] = None
        self._reset_window()

    def _reset_window(self) -> None:
        """Clear the per-update accumulators (one 'round' = one window)."""
        self._buffer: Optional[Dict] = None
        self._n_folded = 0
        self._records: List[RunRecord] = []
        self._n_failed = 0
        self._steals = 0
        self._stale_folds = 0
        self._stale_sum = 0.0

    # -- checkpointing of the in-flight pipeline ---------------------------
    # The engine persists across rounds, so a checkpoint taken at an update
    # boundary still has a live pipeline: undispatched queues, in-flight
    # chunk completions sitting in the clock (their partials already
    # computed and folded into nothing yet), the payload version executors
    # are training against, and the window accumulators.  All of it is
    # serialised host-side (device arrays -> numpy) as plain data; restore
    # rebuilds the clock heap with the exact (time, seq) ordering, so the
    # resumed run pops the same events in the same order and stays
    # bit-deterministic.  (Client states and the server blob ride the
    # normal checkpoint path; the executor topology must match on restore.)
    # Known gap: params/makespans are bit-exact, but the first resumed
    # round's comm_bytes metric omits the round-end broadcast that the
    # original process sent just before the checkpoint (comm stats are not
    # part of the blob) — metrics accounting only, no effect on training.
    def state_dict(self) -> Dict:
        if self._states is None:
            return {"mode": self.mode, "initialized": False}
        clock = self._clock.state_dict()
        clock["events"] = [
            (t, seq, kind,
             (data[0], _host_report(data[1]), data[2])
             if kind == "chunk_done" else data)
            for (t, seq, kind, data) in clock["events"]]
        return {
            "mode": self.mode, "initialized": True,
            "states": {k: dict(queue=list(es.queue), t=es.t,
                               busy_until=es.busy_until, inflight=es.inflight,
                               offset=es.offset, stopped=es.stopped,
                               dead=es.dead)
                       for k, es in self._states.items()},
            "clock": clock,
            "in_system": sorted(self._in_system),
            "last_update_t": self._last_update_t,
            "payload": _host_tree(self._payload),
            "buffer": _host_tree(self._buffer),
            "n_folded": self._n_folded,
            "records": list(self._records),
            "n_failed": self._n_failed,
            "steals": self._steals,
            "stale_folds": self._stale_folds,
            "stale_sum": self._stale_sum,
            "last_sched": self._last_sched,
        }

    def load_state_dict(self, state: Optional[Dict]) -> None:
        if not state:
            return
        if state.get("mode") != self.mode:
            raise ValueError(f"checkpointed engine state is "
                             f"{state.get('mode')!r}, not {self.mode!r}")
        if not state.get("initialized"):
            return
        self._states = {k: _ExecState(**es)
                        for k, es in state["states"].items()}
        self._clock = VirtualClock.from_state_dict(state["clock"])
        self._in_system = set(state["in_system"])
        self._last_update_t = state["last_update_t"]
        self._payload = state["payload"]
        self._buffer = state["buffer"]
        self._n_folded = state["n_folded"]
        self._records = list(state["records"])
        self._n_failed = state["n_failed"]
        self._steals = state["steals"]
        self._stale_folds = state["stale_folds"]
        self._stale_sum = state["stale_sum"]
        self._last_sched = state["last_sched"]

    # ------------------------------------------------------------------
    def _ensure_init(self, srv) -> None:
        if self._states is not None:
            return
        self._payload = srv.algorithm.broadcast_payload(srv.params,
                                                        srv.server_state)
        live = list(srv.executors)
        srv.comm.broadcast(self._payload, live, tag="broadcast")
        n0 = max(1, math.ceil(self.pipeline_depth * srv.clients_per_round))
        tasks = srv.select_clients(n=n0)
        schedule = srv.scheduler.schedule(srv.round, tasks, live)
        self._last_sched = schedule
        self._states = {k: _ExecState(queue=list(schedule.queue(k)))
                        for k in live}
        self._in_system = {t.client for t in tasks}
        for k in live:
            self._dispatch_next(srv, k)

    def _refill(self, srv) -> None:
        """Top the pool back up with a fresh selection, re-scheduled onto
        the live executors under the *current* workload models (clients
        already in the system are excluded — a client must fold before it
        can be picked again, which keeps stateful algorithms race-free)."""
        # an executor whose failure event is still in flight gets no new
        # work (it would only need re-homing when the event pops)
        live = [k for k in srv.executors if not self._states[k].dead]
        fresh = srv.select_clients(n=srv.clients_per_round,
                                   exclude=self._in_system)
        if not fresh or not live:
            return
        schedule = srv.scheduler.schedule(srv.round, fresh, live)
        self._last_sched = schedule
        for k in live:
            # offset is NOT reset: fail_at's task index counts tasks
            # dispatched by this executor cumulatively, so every index is
            # reachable and no (round, index) coordinate repeats
            self._states[k].queue.extend(schedule.queue(k))
        self._in_system.update(t.client for t in fresh)

    # ------------------------------------------------------------------
    def _dispatch_next(self, srv, k: int) -> None:
        es = self._states[k]
        if es.dead:
            return
        chunk = self._chunk_size(srv, self.chunk_size)
        if not es.queue:
            # work stealing: grab the tail chunk of the predicted-slowest
            # queue (its owner was never going to reach it soon anyway)
            victim = pick_steal_victim(
                {j: s.queue for j, s in self._states.items()},
                {j: (s.busy_until if s.inflight else s.t)
                 for j, s in self._states.items()},
                srv.estimator.last_fit, k, chunk)
            if victim is None:
                return            # nothing anywhere: idle until refill
            vq = self._states[victim].queue
            es.queue, self._states[victim].queue = vq[-chunk:], vq[:-chunk]
            self._steals += 1
        tasks, es.queue = es.queue[:chunk], es.queue[chunk:]
        start = max(es.t, self._clock.now)
        rnd = srv.round
        try:
            rep = srv.executors[k].run_queue(
                rnd, tasks, self._payload, srv.data_by_client,
                task_offset=es.offset)
        except ExecutorFailure:
            self._clock.push(start, "executor_failed", (k, tasks + es.queue))
            es.queue = []
            es.dead = True   # no re-dispatch while the event is in flight
            return
        es.offset += len(tasks)
        es.inflight = True
        es.busy_until = start + rep.virtual_time
        self._clock.push(es.busy_until, "chunk_done", (k, rep, rnd))

    # ------------------------------------------------------------------
    def run_round(self, srv):
        from repro.core.round import RoundMetrics
        t_wall = time.perf_counter()
        self._ensure_init(srv)
        rnd = srv.round
        goal = self.goal or srv.clients_per_round

        while self._n_folded < goal:
            if not self._clock:
                if self._n_folded > 0:
                    break          # drained: update with what we have
                self._refill(srv)
                for k in list(self._states):
                    if not self._states[k].inflight:
                        self._dispatch_next(srv, k)
                if not self._clock:
                    raise RuntimeError("async engine starved: no runnable "
                                       "clients on any executor")
                continue
            ev = self._clock.pop()
            if ev.kind == "chunk_done":
                k, rep, version = ev.data
                es = self._states[k]
                es.t, es.inflight = ev.time, False
                if rep.n_tasks:
                    wire = self._wire(srv, k, rep.partial)
                    s = srv.round - version
                    gamma = staleness_weight(s, self.staleness_lambda)
                    self._buffer = merge_partials(self._buffer,
                                                  scale_partial(wire, gamma))
                    self._n_folded += rep.n_tasks
                    if s > 0:
                        self._stale_folds += 1
                    self._stale_sum += s
                    rec = self._chunk_record(srv, version, rep)
                    if rec is not None:
                        self._records.append(rec)
                    self._in_system.difference_update(rep.completed_clients)
                self._dispatch_next(srv, k)
            else:  # executor_failed
                dead, remaining = ev.data
                self._n_failed += 1
                survivors = self._fail_over(srv, self._states, dead,
                                            remaining)
                for j in survivors:
                    if not self._states[j].inflight:
                        self._dispatch_next(srv, j)

        # ---- server update (one bounded-staleness window == one round) ---
        ops = srv.algorithm.ops()
        agg = srv.global_fold([self._buffer])
        agg["_n_selected"] = self._n_folded
        srv.params, srv.server_state = srv.algorithm.server_update(
            srv.params, agg, srv.server_state, len(srv.data_by_client))

        err = float("nan")
        if srv.estimator.last_fit:
            err = srv.estimator.estimation_error(srv.estimator.last_fit,
                                                 self._records)
        srv.estimator.record_many(self._records)
        makespan = self._clock.now - self._last_update_t
        self._last_update_t = self._clock.now
        stats = srv.comm.stats.reset()
        sched = self._last_sched
        n_folds = max(len(self._records), 1)
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=sched.schedule_time_s if sched else 0.0,
            estimate_time=sched.estimate_time_s if sched else 0.0,
            predicted_makespan=(sched.predicted_makespan if sched
                                else float("nan")),
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=self._n_folded, n_executors=len(srv.executors),
            estimation_error=err, failures=self._n_failed,
            extra={"steals": float(self._steals),
                   "stale_folds": float(self._stale_folds),
                   "mean_staleness": self._stale_sum / n_folds,
                   "in_system": float(len(self._in_system))})
        srv.history.append(metrics)
        srv.round += 1
        self._reset_window()

        # new version: broadcast Θ^{r+1} (counted in the next window's comm
        # stats), top the pool up, wake idle executors
        self._payload = srv.algorithm.broadcast_payload(srv.params,
                                                        srv.server_state)
        srv.comm.broadcast(self._payload, list(srv.executors),
                           tag="broadcast")
        self._refill(srv)
        for k in list(self._states):
            if not self._states[k].inflight:
                self._dispatch_next(srv, k)

        if srv.checkpoint_manager is not None:
            srv.checkpoint_manager.maybe_save(srv)
        return metrics
