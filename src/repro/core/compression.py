"""Delta compression for the executor→server partials (distributed-
optimization trick for 1000+ node scale; DESIGN.md §7).

The hierarchical scheme already cuts comm from O(s_a·M_p) to O(s_a·K);
compression attacks the remaining s_a factor on the WEIGHTED_AVG entries:

- ``TopKCompressor``: per-executor top-|k| magnitude sparsification with
  error feedback (the residual is added to the next round's partial, so the
  scheme stays unbiased in the long run).
- ``Int8Compressor``: per-chunk symmetric int8 quantisation (4x over fp32).

Both operate on the FLAT partial wire format: an entry occupies one
contiguous span of its group buffer (``core.flat.FlatLayout``), so each
target entry compresses as a single 1-D array — one top-k / one quant scale
over the whole entry instead of one per pytree leaf.  A compressed group
buffer becomes an ordered list of (raw | compressed) segments that
``decompress_partial`` concatenates back into the fp32 buffer.  The legacy
nested {entry: pytree} partial form is still accepted (per-leaf path).

Both compress only the reducible sums (COLLECT entries pass through), and
both report the achieved wire size so the comm benchmarks can account them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import flat_sums, is_flat_sums


@dataclass
class CompressedTensor:
    kind: str
    shape: tuple
    dtype: str
    data: Dict[str, np.ndarray]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.data.values())


class PartialCompressor:
    """Shared compress/decompress plumbing over the flat partial format.

    Subclasses provide ``_compress(a, key) -> CompressedTensor`` and
    ``_decompress(c) -> np.ndarray``; ``entries`` names the target entries
    (everything else rides raw)."""

    entries: Tuple[str, ...] = ("delta",)

    # --- subclass hooks ---------------------------------------------------
    def _compress(self, a: np.ndarray, key: str) -> CompressedTensor:
        raise NotImplementedError

    def _decompress(self, c: CompressedTensor) -> np.ndarray:
        raise NotImplementedError

    # --- flat path --------------------------------------------------------
    def _compress_flat(self, sums: Dict, layout, prefix: str = "") -> Dict:
        buffers = dict(sums["buffers"])
        if layout is None:
            return flat_sums(buffers)
        spans_by_group: Dict[str, List[Tuple[int, int, str]]] = {}
        for name in self.entries:
            span = layout.spans.get(name)
            if span is not None:
                spans_by_group.setdefault(span.group, []).append(
                    (span.offset, span.size, name))
        for g, spans in spans_by_group.items():
            buf = buffers.get(g)
            if buf is None or isinstance(buf, dict):
                continue
            arr = np.asarray(buf, np.float32)
            segments: List[Tuple[str, Any]] = []
            cursor = 0
            for off, size, name in sorted(spans):
                if off > cursor:             # untargeted entries ride raw
                    segments.append(("raw", arr[cursor:off]))
                segments.append(
                    ("comp", self._compress(arr[off:off + size],
                                            f"{prefix}{g}/{name}")))
                cursor = off + size
            if cursor < arr.size:
                segments.append(("raw", arr[cursor:]))
            buffers[g] = {"__compressed__": True, "segments": segments,
                          "size": int(arr.size)}
        return flat_sums(buffers)

    def _decompress_flat(self, sums: Dict) -> Dict:
        buffers = {}
        for g, buf in sums["buffers"].items():
            if isinstance(buf, dict) and buf.get("__compressed__"):
                pieces = [np.asarray(x, np.float32) if kind == "raw"
                          else self._decompress(x).reshape(-1)
                          for kind, x in buf["segments"]]
                buffers[g] = jnp.asarray(
                    pieces[0] if len(pieces) == 1 else np.concatenate(pieces))
            else:
                buffers[g] = buf
        return flat_sums(buffers)

    # --- legacy nested path ----------------------------------------------
    def _compress_nested(self, sums: Dict, prefix: str = "") -> Dict:
        out = dict(sums)
        for name in self.entries:
            if name not in out:
                continue
            leaves, treedef = jax.tree.flatten(out[name])
            comp = [self._compress(np.asarray(l), f"{prefix}{name}/{i}")
                    for i, l in enumerate(leaves)]
            out[name] = {"__compressed__": True, "treedef": treedef,
                         "leaves": comp}
        return out

    def _decompress_nested(self, sums: Dict) -> Dict:
        out = dict(sums)
        for name, v in list(out.items()):
            if isinstance(v, dict) and v.get("__compressed__"):
                leaves = [jnp.asarray(self._decompress(c))
                          for c in v["leaves"]]
                out[name] = jax.tree.unflatten(v["treedef"], leaves)
        return out

    # --- public API -------------------------------------------------------
    def compress_partial(self, partial: Dict,
                         key: Optional[str] = None) -> Dict:
        """``key`` namespaces stateful compressor state (the top-k error-
        feedback residuals): the server passes the sending executor's id,
        so each executor carries its OWN residual stream — residuals are
        only meaningful per sender, and per-executor streams make the
        compressed values independent of the cross-executor compression
        order (the network path compresses at dispatch time, the comm-free
        path at fold time; per-executor state makes both identical)."""
        out = dict(partial)
        sums = partial["sums"]
        prefix = "" if key is None else f"{key}/"
        out["sums"] = (self._compress_flat(sums, partial.get("layout"),
                                           prefix)
                       if is_flat_sums(sums)
                       else self._compress_nested(sums, prefix))
        out["_wire_bytes"] = _wire_bytes(out["sums"])
        return out

    def decompress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = partial["sums"]
        out["sums"] = (self._decompress_flat(sums)
                       if is_flat_sums(sums) else self._decompress_nested(sums))
        return out


class TopKCompressor(PartialCompressor):
    """Magnitude top-k with per-executor error feedback."""

    def __init__(self, fraction: float = 0.01, entries: tuple = ("delta",)):
        self.fraction = fraction
        self.entries = entries
        self._residual: Dict[str, Any] = {}   # keyed by (group/entry) span

    def _compress_array(self, a: np.ndarray, key: str) -> CompressedTensor:
        flat = np.asarray(a, np.float32).reshape(-1)
        res = self._residual.get(key)
        if res is not None and res.shape == flat.shape:
            flat = flat + res
        k = max(1, int(len(flat) * self.fraction))
        idx = np.argpartition(np.abs(flat), -k)[-k:]
        vals = flat[idx]
        new_res = flat.copy()
        new_res[idx] = 0.0                      # error feedback residual
        self._residual[key] = new_res
        return CompressedTensor("topk", tuple(a.shape), str(a.dtype),
                                {"idx": idx.astype(np.int32),
                                 "vals": vals.astype(np.float32)})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        flat = np.zeros(int(np.prod(c.shape)), np.float32)
        flat[c.data["idx"]] = c.data["vals"]
        return flat.reshape(c.shape)

    _compress = _compress_array
    _decompress = _decompress_array


@jax.jit
def _int8_quantize(f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(f)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@jax.jit
def _int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class Int8Compressor(PartialCompressor):
    """Symmetric per-tensor int8 quantisation with fp32 scale.

    Quantize and dequantize are one jitted call per flat segment (compiled
    once per segment shape, cached by jax) — the abs-max reduce, scale,
    round and cast fuse into a single executable instead of the eager numpy
    round-trip's four passes.  The first step toward the ROADMAP "compiled
    compression" item; ``TopKCompressor`` stays eager (its error-feedback
    residual state is host-side by design).
    """

    def __init__(self, entries: tuple = ("delta",)):
        self.entries = entries

    def _compress_array(self, a: np.ndarray) -> CompressedTensor:
        if np.size(a) == 0:
            return CompressedTensor("int8", tuple(np.shape(a)),
                                    str(np.asarray(a).dtype),
                                    {"q": np.zeros(np.shape(a), np.int8),
                                     "scale": np.float32(1.0)})
        q, scale = _int8_quantize(jnp.asarray(a, jnp.float32))
        return CompressedTensor("int8", tuple(np.shape(a)),
                                str(getattr(a, "dtype", q.dtype)),
                                {"q": q, "scale": scale})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        if np.size(c.data["q"]) == 0:
            return np.zeros(c.shape, np.float32)
        return _int8_dequantize(c.data["q"], c.data["scale"])

    def _compress(self, a: np.ndarray, key: str) -> CompressedTensor:
        return self._compress_array(a)

    def _decompress(self, c: CompressedTensor) -> np.ndarray:
        return self._decompress_array(c)


def _wire_bytes(sums: Dict) -> int:
    if is_flat_sums(sums):
        tot = 0
        for buf in sums["buffers"].values():
            if isinstance(buf, dict) and buf.get("__compressed__"):
                tot += sum(int(x.nbytes) for _, x in buf["segments"])
            else:
                tot += int(np.prod(np.shape(buf))) * buf.dtype.itemsize
        return tot
    tot = 0
    for v in sums.values():
        if isinstance(v, dict) and v.get("__compressed__"):
            tot += sum(c.nbytes for c in v["leaves"])
        else:
            # uncompressed leaves ship at their REAL itemsize: a flat 4
            # over-billed bf16/fp16 payloads 2x (python scalars keep the
            # historical 4-byte accounting)
            tot += sum(int(np.prod(np.shape(l)))
                       * np.dtype(getattr(l, "dtype", np.float32)).itemsize
                       for l in jax.tree.leaves(v))
    return tot


def make_compressor(kind: str, arg: float = 0.01):
    if kind == "none" or not kind:
        return None
    if kind == "topk":
        return TopKCompressor(fraction=arg)
    if kind == "int8":
        return Int8Compressor()
    raise ValueError(kind)
