"""Delta compression for the executor→server partials (distributed-
optimization trick for 1000+ node scale; DESIGN.md §7).

The hierarchical scheme already cuts comm from O(s_a·M_p) to O(s_a·K);
compression attacks the remaining s_a factor on the WEIGHTED_AVG entries:

- ``TopKCompressor``: per-executor top-|k| magnitude sparsification with
  error feedback (the residual is added to the next round's partial, so the
  scheme stays unbiased in the long run).
- ``Int8Compressor``: per-chunk symmetric int8 quantisation (4x over fp32).

Both compress only the reducible sums (COLLECT entries pass through), and
both report the achieved wire size so the comm benchmarks can account them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class CompressedTensor:
    kind: str
    shape: tuple
    dtype: str
    data: Dict[str, np.ndarray]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.data.values())


class TopKCompressor:
    """Magnitude top-k with per-executor error feedback."""

    def __init__(self, fraction: float = 0.01, entries: tuple = ("delta",)):
        self.fraction = fraction
        self.entries = entries
        self._residual: Dict[str, Any] = {}   # keyed by (executor-ish) id

    def _compress_array(self, a: np.ndarray, key: str) -> CompressedTensor:
        flat = np.asarray(a, np.float32).reshape(-1)
        res = self._residual.get(key)
        if res is not None and res.shape == flat.shape:
            flat = flat + res
        k = max(1, int(len(flat) * self.fraction))
        idx = np.argpartition(np.abs(flat), -k)[-k:]
        vals = flat[idx]
        new_res = flat.copy()
        new_res[idx] = 0.0                      # error feedback residual
        self._residual[key] = new_res
        return CompressedTensor("topk", tuple(a.shape), str(a.dtype),
                                {"idx": idx.astype(np.int32),
                                 "vals": vals.astype(np.float32)})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        flat = np.zeros(int(np.prod(c.shape)), np.float32)
        flat[c.data["idx"]] = c.data["vals"]
        return flat.reshape(c.shape)

    def compress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = dict(partial["sums"])
        for name in self.entries:
            if name not in sums:
                continue
            leaves, treedef = jax.tree.flatten(sums[name])
            comp = [self._compress_array(np.asarray(l), f"{name}/{i}")
                    for i, l in enumerate(leaves)]
            sums[name] = {"__compressed__": True, "treedef": treedef,
                          "leaves": comp}
        out["sums"] = sums
        out["_wire_bytes"] = _wire_bytes(sums)
        return out

    def decompress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = dict(partial["sums"])
        for name, v in list(sums.items()):
            if isinstance(v, dict) and v.get("__compressed__"):
                leaves = [jnp.asarray(self._decompress_array(c))
                          for c in v["leaves"]]
                sums[name] = jax.tree.unflatten(v["treedef"], leaves)
        out["sums"] = sums
        return out


class Int8Compressor:
    """Symmetric per-tensor int8 quantisation with fp32 scale."""

    def __init__(self, entries: tuple = ("delta",)):
        self.entries = entries

    def _compress_array(self, a: np.ndarray) -> CompressedTensor:
        f = np.asarray(a, np.float32)
        scale = float(np.max(np.abs(f))) / 127.0 if f.size else 1.0
        scale = max(scale, 1e-12)
        q = np.clip(np.round(f / scale), -127, 127).astype(np.int8)
        return CompressedTensor("int8", tuple(a.shape), str(a.dtype),
                                {"q": q, "scale": np.float32(scale)})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        return c.data["q"].astype(np.float32) * c.data["scale"]

    def compress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = dict(partial["sums"])
        for name in self.entries:
            if name not in sums:
                continue
            leaves, treedef = jax.tree.flatten(sums[name])
            comp = [self._compress_array(np.asarray(l)) for l in leaves]
            sums[name] = {"__compressed__": True, "treedef": treedef,
                          "leaves": comp}
        out["sums"] = sums
        out["_wire_bytes"] = _wire_bytes(sums)
        return out

    def decompress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = dict(partial["sums"])
        for name, v in list(sums.items()):
            if isinstance(v, dict) and v.get("__compressed__"):
                leaves = [jnp.asarray(self._decompress_array(c))
                          for c in v["leaves"]]
                sums[name] = jax.tree.unflatten(v["treedef"], leaves)
        out["sums"] = sums
        return out


def _wire_bytes(sums: Dict) -> int:
    tot = 0
    for v in sums.values():
        if isinstance(v, dict) and v.get("__compressed__"):
            tot += sum(c.nbytes for c in v["leaves"])
        else:
            tot += sum(int(np.prod(np.shape(l))) * 4
                       for l in jax.tree.leaves(v))
    return tot


def make_compressor(kind: str, arg: float = 0.01):
    if kind == "none" or not kind:
        return None
    if kind == "topk":
        return TopKCompressor(fraction=arg)
    if kind == "int8":
        return Int8Compressor()
    raise ValueError(kind)
