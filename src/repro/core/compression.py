"""Compiled delta compression for the executor→server partials (DESIGN.md §7).

The hierarchical scheme already cuts comm from O(s_a·M_p) to O(s_a·K);
compression attacks the remaining s_a factor on the reducible entries:

- ``TopKCompressor``: per-executor top-|k| magnitude sparsification with
  error feedback (the residual is added to the next round's partial, so the
  scheme stays unbiased in the long run).
- ``Int8Compressor``: per-entry symmetric int8 quantisation (4x over fp32).
- ``PowerSGDCompressor``: low-rank factorisation by one step of warm-started
  power iteration per round (wire = P + Q instead of the dense buffer).

All three operate on the FLAT partial wire format: an entry occupies one
contiguous span of its group buffer (``core.flat.FlatLayout``), so the span
table of a group is STATIC and each compressor can process every targeted
span of a group buffer in ONE jitted dispatch (``compiled=True``, the
default through ``make_compressor``):

- compress: residual-add → select/quantise/factorise → residual update runs
  as one executable per (group size, span plan); the top-k path calls the
  fused ``kernels/topk_compress`` kernel (Pallas on TPU) per span.  The
  error-feedback state lives DEVICE-RESIDENT in the compressor, keyed per
  (sender, group) — no host round-trip.
- decompress is LAZY: ``decompress_partial`` leaves the buffers in
  compressed wire form and the fold sites (``merge_partials`` /
  ``reduce_flat_partials`` / ``scale_partial``) consume them through the
  stateless ``densify_buffer`` / ``fold_buffer_into`` / ``scale_buffer``
  jits below, scatter-adding segments straight into the accumulator so the
  server never materialises an intermediate dense fp32 copy per partial.

Tie rule (top-k, both paths): the k entries of largest ``|x + residual|``
win; exact magnitude ties go to the LOWER index (``lax.top_k`` stability /
stable argsort in the eager reference) and indices ship sorted ascending —
compiled and eager wire bytes are bit-identical.

Eager per-segment compress/decompress (``compiled=False``, the pre-compiled
behaviour) is kept as the reference path, as is the legacy nested
{entry: pytree} partial form (per-leaf).  Compressors expose
``state_dict``/``load_state_dict`` so the checkpoint blob carries residuals
and PowerSGD warm starts across a resume (``checkpoint/manager.py``).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import flat_sums, is_compressed_buffer, is_flat_sums


@dataclass
class CompressedTensor:
    kind: str
    shape: tuple
    dtype: str
    data: Dict[str, np.ndarray]

    @property
    def nbytes(self) -> int:
        return sum(int(a.nbytes) for a in self.data.values())


def _ct_flatten(c: "CompressedTensor"):
    keys = tuple(sorted(c.data))
    return tuple(c.data[k] for k in keys), (c.kind, c.shape, c.dtype, keys)


def _ct_unflatten(aux, children):
    kind, shape, dtype, keys = aux
    return CompressedTensor(kind, shape, dtype, dict(zip(keys, children)))


# Registered as a pytree node so compressed partials survive generic tree
# plumbing: the engines' state_dict host-transfer (in-flight compressed
# CommEvents), jax.block_until_ready over wire partials, payload-byte
# accounting (the data arrays sum to exactly .nbytes).
jax.tree_util.register_pytree_node(CompressedTensor, _ct_flatten,
                                   _ct_unflatten)


_codec_dispatches = 0


def codec_dispatch_count() -> int:
    """Group-level codec dispatches so far (one per jitted compress /
    densify / fold / scale call on a group buffer) — pins the compiled
    path at O(groups), not O(segments), per round."""
    return _codec_dispatches


def reset_codec_dispatch_count() -> None:
    global _codec_dispatches
    _codec_dispatches = 0


def _bump() -> None:
    global _codec_dispatches
    _codec_dispatches += 1


def _colocate(x: Any, like: Any) -> Any:
    from repro.core.placement import colocate
    return colocate(x, like)


# ---------------------------------------------------------------------------
# stateless compressed-buffer consumers (the fused decompress-into-fold)
# ---------------------------------------------------------------------------
#
# A compressed group buffer is {"__compressed__": True, "segments": [...],
# "size": n} with ordered ("raw", array) | ("comp", CompressedTensor)
# segments covering [0, n).  The helpers below walk that structure ONCE to
# build a static signature, then run one cached jit over the flattened
# segment arrays.

def _segments_sig(segments) -> tuple:
    sig: List[tuple] = []
    for kind, x in segments:
        if kind == "raw":
            sig.append(("raw", int(np.prod(np.shape(x)))))
        elif x.kind == "topk":
            sig.append(("topk", int(np.prod(x.shape)),
                        int(np.shape(x.data["idx"])[0])))
        elif x.kind == "int8":
            sig.append(("int8", int(np.prod(x.shape))))
        elif x.kind == "powersgd":
            sig.append(("powersgd", int(np.prod(x.shape)),
                        tuple(int(d) for d in np.shape(x.data["p"])),
                        tuple(int(d) for d in np.shape(x.data["q"]))))
        else:
            raise ValueError(f"unknown compressed kind: {x.kind}")
    return tuple(sig)


def _segments_parts(segments) -> tuple:
    parts: List[Any] = []
    for kind, x in segments:
        if kind == "raw":
            parts.append(jnp.asarray(x, jnp.float32))
        elif x.kind == "topk":
            parts += [jnp.asarray(x.data["idx"], jnp.int32),
                      jnp.asarray(x.data["vals"], jnp.float32)]
        elif x.kind == "int8":
            parts += [jnp.asarray(x.data["q"], jnp.int8),
                      jnp.asarray(x.data["scale"], jnp.float32)]
        else:  # powersgd
            parts += [jnp.asarray(x.data["p"], jnp.float32),
                      jnp.asarray(x.data["q"], jnp.float32)]
    return tuple(parts)


def _walk(sig, parts, out, off, combine):
    """Shared decode walk: ``combine(out, off, n, dense_segment)`` applies a
    dense f32 segment; topk segments go through the sparse fast path."""
    i = 0
    for s in sig:
        n = s[1]
        if s[0] == "raw":
            if n:
                out = combine(out, off, n, parts[i])
            i += 1
        elif s[0] == "topk":
            idx, vals = parts[i], parts[i + 1]
            i += 2
            if n and s[2]:
                out = out.at[off + idx].add(vals)
        elif s[0] == "int8":
            q, scale = parts[i], parts[i + 1]
            i += 2
            if n:
                out = combine(out, off, n, q.astype(jnp.float32) * scale)
        else:  # powersgd
            p, q = parts[i], parts[i + 1]
            i += 2
            out = combine(out, off, n, (p @ q.T).reshape(-1)[:n])
        off += n
    return out


_DENSIFY_CACHE: Dict[tuple, Any] = {}
_FOLD_CACHE: Dict[tuple, Any] = {}
_SCALE_CACHE: Dict[tuple, Any] = {}


def _densify_fn(size: int, sig: tuple):
    fn = _DENSIFY_CACHE.get((size, sig))
    if fn is None:
        def run(parts):
            def set_seg(out, off, n, seg):
                return jax.lax.dynamic_update_slice(out, seg, (off,))
            return _walk(sig, parts, jnp.zeros((size,), jnp.float32), 0,
                         set_seg)
        fn = jax.jit(run)
        _DENSIFY_CACHE[(size, sig)] = fn
    return fn


def _fold_fn(size: int, sig: tuple):
    fn = _FOLD_CACHE.get((size, sig))
    if fn is None:
        def run(acc, parts):
            def add_seg(out, off, n, seg):
                cur = jax.lax.dynamic_slice(out, (off,), (n,))
                return jax.lax.dynamic_update_slice(out, cur + seg, (off,))
            return _walk(sig, parts, acc.astype(jnp.float32), 0, add_seg)
        fn = jax.jit(run)
        _FOLD_CACHE[(size, sig)] = fn
    return fn


def _scale_fn(sig: tuple):
    fn = _SCALE_CACHE.get(sig)
    if fn is None:
        def run(parts, gamma):
            out = []
            i = 0
            for s in sig:
                if s[0] == "raw":
                    out.append(parts[i] * gamma)
                    i += 1
                elif s[0] == "topk":
                    out += [parts[i], parts[i + 1] * gamma]
                    i += 2
                elif s[0] == "int8":
                    out += [parts[i], parts[i + 1] * gamma]
                    i += 2
                else:  # powersgd: P carries the scale, Q stays orthonormal-ish
                    out += [parts[i] * gamma, parts[i + 1]]
                    i += 2
            return tuple(out)
        fn = jax.jit(run)
        _SCALE_CACHE[sig] = fn
    return fn


def densify_buffer(buf: Dict[str, Any]) -> jnp.ndarray:
    """Decode a compressed group buffer to its dense (n,) fp32 form in one
    dispatch (bit-identical to the eager per-segment concatenation)."""
    segs = buf["segments"]
    _bump()
    return _densify_fn(int(buf["size"]), _segments_sig(segs))(
        _segments_parts(segs))


def fold_buffer_into(acc: Any, buf: Dict[str, Any]) -> jnp.ndarray:
    """Fused decompress-into-fold: add a compressed group buffer straight
    into the dense accumulator — raw/int8/low-rank segments add as slices,
    top-k segments scatter-add — with no intermediate dense copy."""
    segs = buf["segments"]
    sig = _segments_sig(segs)
    acc_j = jnp.asarray(acc, jnp.float32)
    parts = tuple(_colocate(p, acc_j) for p in _segments_parts(segs))
    _bump()
    return _fold_fn(int(acc_j.shape[0]), sig)(acc_j, parts)


def scale_buffer(buf: Dict[str, Any], gamma: float) -> Dict[str, Any]:
    """Scale a compressed group buffer by ``gamma`` WITHOUT decoding it
    (async staleness discounts): raw segments and top-k values scale
    directly, int8 folds gamma into the scale, PowerSGD into P."""
    segs = buf["segments"]
    sig = _segments_sig(segs)
    _bump()
    new = _scale_fn(sig)(_segments_parts(segs), jnp.float32(gamma))
    out_segs: List[Tuple[str, Any]] = []
    i = 0
    for (kind, x), s in zip(segs, sig):
        if kind == "raw":
            out_segs.append(("raw", new[i]))
            i += 1
        elif x.kind == "topk":
            out_segs.append(("comp", CompressedTensor(
                "topk", x.shape, x.dtype,
                {"idx": new[i], "vals": new[i + 1]})))
            i += 2
        elif x.kind == "int8":
            out_segs.append(("comp", CompressedTensor(
                "int8", x.shape, x.dtype,
                {"q": new[i], "scale": new[i + 1]})))
            i += 2
        else:
            out_segs.append(("comp", CompressedTensor(
                "powersgd", x.shape, x.dtype,
                {"p": new[i], "q": new[i + 1]})))
            i += 2
    return {"__compressed__": True, "segments": out_segs,
            "size": int(buf["size"])}


# ---------------------------------------------------------------------------
# compressors
# ---------------------------------------------------------------------------

class PartialCompressor:
    """Shared compress/decompress plumbing over the flat partial format.

    Subclasses provide ``_compress(a, key) -> CompressedTensor`` and
    ``_decompress(c) -> np.ndarray`` (the eager reference), and — when
    ``compiled`` — ``_group_compress(group, buf, plan, prefix)`` processing
    a whole group buffer in one dispatch.  ``entries`` names the target
    entries (everything else rides raw)."""

    entries: Tuple[str, ...] = ("delta",)
    compiled: bool = False

    # --- subclass hooks ---------------------------------------------------
    def _compress(self, a: np.ndarray, key: str) -> CompressedTensor:
        raise NotImplementedError

    def _decompress(self, c: CompressedTensor) -> np.ndarray:
        raise NotImplementedError

    def _group_compress(self, group: str, buf: Any, plan: tuple,
                        prefix: str) -> Dict[str, Any]:
        raise NotImplementedError

    # --- checkpointable state --------------------------------------------
    def state_dict(self) -> Optional[Dict[str, Any]]:
        return None

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        pass

    # --- flat path --------------------------------------------------------
    def _span_plans(self, layout) -> Dict[str, tuple]:
        """Per-group STATIC segment plan: ordered ("raw"|"comp", off, size,
        entry|None) tuples covering [0, group_size) — the comp spans are the
        targeted entries, everything between rides raw.  Static per layout,
        so it doubles as the jit cache key for the group codecs."""
        spans_by_group: Dict[str, List[Tuple[int, int, str]]] = {}
        for name in self.entries:
            span = layout.spans.get(name)
            if span is not None:
                spans_by_group.setdefault(span.group, []).append(
                    (span.offset, span.size, name))
        plans: Dict[str, tuple] = {}
        for g, spans in spans_by_group.items():
            total = int(layout.group_sizes[g])
            plan: List[tuple] = []
            cursor = 0
            for off, size, name in sorted(spans):
                if off > cursor:             # untargeted entries ride raw
                    plan.append(("raw", cursor, off - cursor, None))
                plan.append(("comp", off, size, name))
                cursor = off + size
            if cursor < total:
                plan.append(("raw", cursor, total - cursor, None))
            plans[g] = tuple(plan)
        return plans

    def _compress_flat(self, sums: Dict, layout, prefix: str = "") -> Dict:
        buffers = dict(sums["buffers"])
        if layout is None:
            return flat_sums(buffers)
        for g, plan in self._span_plans(layout).items():
            buf = buffers.get(g)
            if buf is None or isinstance(buf, dict):
                continue
            if self.compiled:
                buffers[g] = self._group_compress(g, buf, plan, prefix)
                continue
            arr = np.asarray(buf, np.float32)
            segments: List[Tuple[str, Any]] = []
            for kind, off, sz, name in plan:
                if kind == "raw":
                    segments.append(("raw", arr[off:off + sz]))
                else:
                    segments.append(
                        ("comp", self._compress(arr[off:off + sz],
                                                f"{prefix}{g}/{name}")))
            buffers[g] = {"__compressed__": True, "segments": segments,
                          "size": int(arr.size)}
        return flat_sums(buffers)

    def _decompress_flat(self, sums: Dict) -> Dict:
        buffers = {}
        for g, buf in sums["buffers"].items():
            if is_compressed_buffer(buf):
                pieces = [np.asarray(x, np.float32) if kind == "raw"
                          else self._decompress(x).reshape(-1)
                          for kind, x in buf["segments"]]
                buffers[g] = jnp.asarray(
                    pieces[0] if len(pieces) == 1 else np.concatenate(pieces))
            else:
                buffers[g] = buf
        return flat_sums(buffers)

    # --- legacy nested path ----------------------------------------------
    def _compress_nested(self, sums: Dict, prefix: str = "") -> Dict:
        out = dict(sums)
        for name in self.entries:
            if name not in out:
                continue
            leaves, treedef = jax.tree.flatten(out[name])
            comp = [self._compress(np.asarray(l), f"{prefix}{name}/{i}")
                    for i, l in enumerate(leaves)]
            out[name] = {"__compressed__": True, "treedef": treedef,
                         "leaves": comp}
        return out

    def _decompress_nested(self, sums: Dict) -> Dict:
        out = dict(sums)
        for name, v in list(out.items()):
            if isinstance(v, dict) and v.get("__compressed__") \
                    and "leaves" in v:
                leaves = [jnp.asarray(self._decompress(c))
                          for c in v["leaves"]]
                out[name] = jax.tree.unflatten(v["treedef"], leaves)
        return out

    # --- public API -------------------------------------------------------
    def compress_partial(self, partial: Dict,
                         key: Optional[str] = None) -> Dict:
        """``key`` namespaces stateful compressor state (error-feedback
        residuals, PowerSGD warm starts): the server passes the sending
        executor's id, so each executor carries its OWN state stream —
        residuals are only meaningful per sender, and per-executor streams
        make the compressed values independent of the cross-executor
        compression order (the network path compresses at dispatch time,
        the comm-free path at fold time; per-executor state makes both
        identical)."""
        out = dict(partial)
        sums = partial["sums"]
        prefix = "" if key is None else f"{key}/"
        out["sums"] = (self._compress_flat(sums, partial.get("layout"),
                                           prefix)
                       if is_flat_sums(sums)
                       else self._compress_nested(sums, prefix))
        out["_wire_bytes"] = _wire_bytes(out["sums"])
        return out

    def decompress_partial(self, partial: Dict) -> Dict:
        out = dict(partial)
        sums = partial["sums"]
        if is_flat_sums(sums):
            # compiled codecs decompress LAZILY: the buffers stay in
            # compressed wire form and ride to the fold, which consumes the
            # segments straight into the accumulator (densify_buffer /
            # fold_buffer_into above) — no dense per-partial intermediate.
            out["sums"] = sums if self.compiled else \
                self._decompress_flat(sums)
        else:
            out["sums"] = self._decompress_nested(sums)
        return out


_TOPK_GROUP_CACHE: Dict[tuple, Any] = {}


def _topk_group_fn(n: int, plan: tuple, ks: tuple):
    """One executable per (group size, span plan, k vector): for every
    targeted span, residual-add → fused top-k (kernels/topk_compress) →
    residual scatter-zero; raw spans slice through untouched."""
    key = (n, plan, ks)
    fn = _TOPK_GROUP_CACHE.get(key)
    if fn is None:
        from repro.kernels import topk_compress as tkc

        def run(arr, res):
            outs = []
            new_res = res
            for (kind, off, sz), k in zip(plan, ks):
                if kind == "raw":
                    outs.append(jax.lax.dynamic_slice(arr, (off,), (sz,)))
                    continue
                if k <= 0:
                    outs.append((jnp.zeros((0,), jnp.int32),
                                 jnp.zeros((0,), jnp.float32)))
                    continue
                x = jax.lax.dynamic_slice(arr, (off,), (sz,))
                r = jax.lax.dynamic_slice(res, (off,), (sz,))
                idx, vals, seg_res = tkc.topk_with_residual(x, r, k)
                new_res = jax.lax.dynamic_update_slice(new_res, seg_res,
                                                       (off,))
                outs.append((idx, vals))
            return outs, new_res

        fn = jax.jit(run)
        _TOPK_GROUP_CACHE[key] = fn
    return fn


class TopKCompressor(PartialCompressor):
    """Magnitude top-k with per-sender error feedback.

    ``compiled=True`` (the ``make_compressor`` default) holds the residual
    as one DEVICE-RESIDENT (n,) array per (sender, group) and compresses
    every targeted span of a group buffer in one dispatch; ``compiled=False``
    is the eager per-span numpy reference (host residual dict).  Both obey
    the same tie rule (largest |x+res|, ties to the lower index, indices
    ascending) so their wire bytes are bit-identical."""

    def __init__(self, fraction: float = 0.01, entries: tuple = ("delta",),
                 compiled: bool = False):
        self.fraction = float(fraction)
        self.entries = tuple(entries)
        self.compiled = bool(compiled)
        # eager: span-keyed host residuals; compiled: group-keyed
        # device-resident residuals
        self._residual: Dict[str, Any] = {}

    def _k_of(self, n: int) -> int:
        return max(1, int(n * self.fraction)) if n else 0

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": "topk",
                "residual": {k: np.asarray(v)
                             for k, v in self._residual.items()}}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        self._residual = {} if not state else \
            {k: np.asarray(v) for k, v in state.get("residual", {}).items()}

    # --- eager reference --------------------------------------------------
    def _compress_array(self, a: np.ndarray, key: str) -> CompressedTensor:
        flat = np.asarray(a, np.float32).reshape(-1)
        res = self._residual.get(key)
        if res is not None and np.shape(res) == flat.shape:
            flat = flat + np.asarray(res, np.float32)
        k = self._k_of(flat.size)
        # stable sort on -|f|: largest magnitudes first, ties -> lower index
        # (the lax.top_k rule the fused kernel uses)
        order = np.argsort(-np.abs(flat), kind="stable")[:k]
        idx = np.sort(order).astype(np.int32)
        vals = flat[idx]
        new_res = flat.copy()
        new_res[idx] = 0.0                      # error feedback residual
        self._residual[key] = new_res
        return CompressedTensor("topk", tuple(np.shape(a)),
                                str(np.asarray(a).dtype),
                                {"idx": idx, "vals": vals})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        flat = np.zeros(int(np.prod(c.shape)), np.float32)
        flat[np.asarray(c.data["idx"])] = np.asarray(c.data["vals"])
        return flat.reshape(c.shape)

    _compress = _compress_array
    _decompress = _decompress_array

    # --- compiled group path ---------------------------------------------
    def _group_compress(self, g: str, buf: Any, plan: tuple,
                        prefix: str) -> Dict[str, Any]:
        arr = jnp.asarray(buf, jnp.float32).reshape(-1)
        n = int(arr.shape[0])
        skey = f"{prefix}{g}"
        res = self._residual.get(skey)
        if res is None or tuple(np.shape(res)) != (n,):
            res = jnp.zeros((n,), jnp.float32)
        res = _colocate(jnp.asarray(res, jnp.float32), arr)
        shape_plan = tuple((kind, off, sz) for kind, off, sz, _ in plan)
        ks = tuple(self._k_of(sz) if kind == "comp" else 0
                   for kind, off, sz, _ in plan)
        _bump()
        outs, new_res = _topk_group_fn(n, shape_plan, ks)(arr, res)
        self._residual[skey] = new_res     # stays device-resident
        segments: List[Tuple[str, Any]] = []
        i = 0
        for kind, off, sz, _name in plan:
            if kind == "raw":
                segments.append(("raw", outs[i]))
            else:
                idx, vals = outs[i]
                segments.append(("comp", CompressedTensor(
                    "topk", (sz,), "float32", {"idx": idx, "vals": vals})))
            i += 1
        return {"__compressed__": True, "segments": segments, "size": n}


@jax.jit
def _int8_quantize(f: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(f)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(f / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


@jax.jit
def _int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


_INT8_GROUP_CACHE: Dict[tuple, Any] = {}


def _int8_group_fn(n: int, plan: tuple):
    key = (n, plan)
    fn = _INT8_GROUP_CACHE.get(key)
    if fn is None:
        def run(arr):
            outs = []
            for kind, off, sz in plan:
                x = jax.lax.dynamic_slice(arr, (off,), (sz,))
                if kind == "raw":
                    outs.append(x)
                elif sz == 0:
                    outs.append((jnp.zeros((0,), jnp.int8),
                                 jnp.float32(1.0)))
                else:
                    # same ops as _int8_quantize, fused across the group
                    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
                    q = jnp.clip(jnp.round(x / scale), -127, 127) \
                        .astype(jnp.int8)
                    outs.append((q, scale.astype(jnp.float32)))
            return outs

        fn = jax.jit(run)
        _INT8_GROUP_CACHE[key] = fn
    return fn


class Int8Compressor(PartialCompressor):
    """Symmetric per-entry int8 quantisation with fp32 scale.

    ``compiled=True`` quantises every targeted span of a group buffer in one
    jitted dispatch and decompresses lazily into the fold; ``compiled=False``
    keeps the PR 5 one-jit-per-segment behaviour (the reference)."""

    def __init__(self, entries: tuple = ("delta",), compiled: bool = False):
        self.entries = tuple(entries)
        self.compiled = bool(compiled)

    def _compress_array(self, a: np.ndarray) -> CompressedTensor:
        if np.size(a) == 0:
            return CompressedTensor("int8", tuple(np.shape(a)),
                                    str(np.asarray(a).dtype),
                                    {"q": np.zeros(np.shape(a), np.int8),
                                     "scale": np.float32(1.0)})
        q, scale = _int8_quantize(jnp.asarray(a, jnp.float32))
        return CompressedTensor("int8", tuple(np.shape(a)),
                                str(getattr(a, "dtype", q.dtype)),
                                {"q": q, "scale": scale})

    def _decompress_array(self, c: CompressedTensor) -> np.ndarray:
        if np.size(c.data["q"]) == 0:
            return np.zeros(c.shape, np.float32)
        return _int8_dequantize(c.data["q"], c.data["scale"])

    def _compress(self, a: np.ndarray, key: str) -> CompressedTensor:
        return self._compress_array(a)

    def _decompress(self, c: CompressedTensor) -> np.ndarray:
        return self._decompress_array(c)

    def _group_compress(self, g: str, buf: Any, plan: tuple,
                        prefix: str) -> Dict[str, Any]:
        arr = jnp.asarray(buf, jnp.float32).reshape(-1)
        n = int(arr.shape[0])
        shape_plan = tuple((kind, off, sz) for kind, off, sz, _ in plan)
        _bump()
        outs = _int8_group_fn(n, shape_plan)(arr)
        segments: List[Tuple[str, Any]] = []
        for (kind, off, sz, _name), out in zip(plan, outs):
            if kind == "raw":
                segments.append(("raw", out))
            else:
                q, scale = out
                segments.append(("comp", CompressedTensor(
                    "int8", (sz,), "float32", {"q": q, "scale": scale})))
        return {"__compressed__": True, "segments": segments, "size": n}


def _psgd_shape(n: int, rank: int) -> Tuple[int, int, int]:
    """Near-square (rows, cols) factorisation of a flat span plus the
    effective rank (clipped so P/Q stay skinny)."""
    cols = max(1, int(math.ceil(math.sqrt(max(n, 1)))))
    rows = -(-n // cols)
    r = max(1, min(int(rank), rows, cols))
    return rows, cols, r


_PSGD_GROUP_CACHE: Dict[tuple, Any] = {}


def _psgd_group_fn(n: int, plan: tuple, shapes: tuple):
    """One power-iteration step per targeted span, batched over the group:
    M = reshape(x + res); P = orth(M @ Q); Q' = Mᵀ P; residual = x+res −
    unravel(P Q'ᵀ).  Q' warm-starts the next round (subspace iteration:
    repeated rounds converge Q toward the top singular subspace)."""
    key = (n, plan, shapes)
    fn = _PSGD_GROUP_CACHE.get(key)
    if fn is None:
        def run(arr, states):
            outs = []
            new_states = []
            si = 0
            for kind, off, sz in plan:
                seg = jax.lax.dynamic_slice(arr, (off,), (sz,))
                if kind == "raw":
                    outs.append(seg)
                    continue
                rows, cols, _r = shapes[si]
                q0, res = states[si]
                si += 1
                f = seg + res
                m = f if rows * cols == sz else \
                    jnp.pad(f, (0, rows * cols - sz))
                m = m.reshape(rows, cols)
                p = jnp.linalg.qr(m @ q0)[0]       # orthonormalise P
                q1 = m.T @ p
                approx = (p @ q1.T).reshape(-1)[:sz]
                outs.append((p, q1))
                new_states.append((q1, f - approx))
            return outs, new_states

        fn = jax.jit(run)
        _PSGD_GROUP_CACHE[key] = fn
    return fn


class PowerSGDCompressor(PartialCompressor):
    """PowerSGD-style low-rank compression of the flat group buffers.

    Each targeted span reshapes to a near-square (rows, cols) matrix M of
    the residual-corrected update; one warm-started power-iteration step
    gives ``P = orth(M Q)`` (rows×r) and ``Q' = Mᵀ P`` (cols×r), and the
    wire carries P and Q' — O((rows+cols)·r) instead of O(rows·cols).  The
    decoded update is ``P Q'ᵀ``; the approximation error feeds back into the
    next round's residual, and Q' warm-starts the next iteration so the
    factors track the top singular subspace across rounds.  State (Q, res)
    is keyed per (sender, group, entry) like the top-k residuals.  Always
    compiled: every span of a group runs in one jitted dispatch."""

    def __init__(self, rank: int = 4, entries: tuple = ("delta",),
                 seed: int = 0):
        self.rank = int(max(1, rank))
        self.entries = tuple(entries)
        self.seed = int(seed)
        self.compiled = True
        self._state: Dict[str, Dict[str, Any]] = {}

    def _init_q(self, skey: str, cols: int, r: int) -> jnp.ndarray:
        # deterministic per span-key: a resume-from-scratch re-derives the
        # identical init, and distinct senders/entries decorrelate
        k = jax.random.PRNGKey((zlib.crc32(skey.encode()) ^ self.seed)
                               & 0x7FFFFFFF)
        return jax.random.normal(k, (cols, r), jnp.float32)

    def state_dict(self) -> Dict[str, Any]:
        return {"kind": "powersgd",
                "state": {k: {"q": np.asarray(v["q"]),
                              "res": np.asarray(v["res"])}
                          for k, v in self._state.items()}}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        self._state = {} if not state else \
            {k: {"q": np.asarray(v["q"]), "res": np.asarray(v["res"])}
             for k, v in state.get("state", {}).items()}

    # --- eager per-array reference (legacy nested path) -------------------
    def _compress(self, a: np.ndarray, key: str) -> CompressedTensor:
        flat = jnp.asarray(a, jnp.float32).reshape(-1)
        n = int(flat.shape[0])
        if n == 0:
            return CompressedTensor("powersgd", tuple(np.shape(a)),
                                    "float32",
                                    {"p": np.zeros((0, 1), np.float32),
                                     "q": np.zeros((0, 1), np.float32)})
        rows, cols, r = _psgd_shape(n, self.rank)
        st = self._state.get(key)
        if st is None or tuple(np.shape(st["q"])) != (cols, r):
            st = {"q": self._init_q(key, cols, r),
                  "res": jnp.zeros((n,), jnp.float32)}
        fn = _psgd_group_fn(n, (("comp", 0, n),), ((rows, cols, r),))
        _bump()
        outs, new_states = fn(flat, ((jnp.asarray(st["q"], jnp.float32),
                                      jnp.asarray(st["res"], jnp.float32)),))
        p, q = outs[0]
        self._state[key] = {"q": new_states[0][0], "res": new_states[0][1]}
        return CompressedTensor("powersgd", tuple(np.shape(a)), "float32",
                                {"p": p, "q": q})

    def _decompress(self, c: CompressedTensor) -> np.ndarray:
        p = np.asarray(c.data["p"], np.float32)
        q = np.asarray(c.data["q"], np.float32)
        n = int(np.prod(c.shape))
        return (p @ q.T).reshape(-1)[:n].reshape(c.shape)

    # --- compiled group path ---------------------------------------------
    def _group_compress(self, g: str, buf: Any, plan: tuple,
                        prefix: str) -> Dict[str, Any]:
        arr = jnp.asarray(buf, jnp.float32).reshape(-1)
        n = int(arr.shape[0])
        # degrade empty targeted spans to raw: nothing to factorise
        plan = tuple(("raw", off, sz, None) if kind == "comp" and sz == 0
                     else (kind, off, sz, name)
                     for kind, off, sz, name in plan)
        shapes: List[tuple] = []
        states: List[tuple] = []
        for kind, off, sz, name in plan:
            if kind != "comp":
                continue
            rows, cols, r = _psgd_shape(sz, self.rank)
            shapes.append((rows, cols, r))
            skey = f"{prefix}{g}/{name}"
            st = self._state.get(skey)
            if st is None or tuple(np.shape(st["q"])) != (cols, r):
                st = {"q": self._init_q(skey, cols, r),
                      "res": jnp.zeros((sz,), jnp.float32)}
            states.append((_colocate(jnp.asarray(st["q"], jnp.float32), arr),
                           _colocate(jnp.asarray(st["res"], jnp.float32),
                                     arr)))
        shape_plan = tuple((kind, off, sz) for kind, off, sz, _ in plan)
        _bump()
        outs, new_states = _psgd_group_fn(n, shape_plan, tuple(shapes))(
            arr, tuple(states))
        segments: List[Tuple[str, Any]] = []
        i = 0
        si = 0
        for kind, off, sz, name in plan:
            if kind == "raw":
                segments.append(("raw", outs[i]))
            else:
                p, q = outs[i]
                self._state[f"{prefix}{g}/{name}"] = \
                    {"q": new_states[si][0], "res": new_states[si][1]}
                si += 1
                segments.append(("comp", CompressedTensor(
                    "powersgd", (sz,), "float32", {"p": p, "q": q})))
            i += 1
        return {"__compressed__": True, "segments": segments, "size": n}


def _wire_bytes(sums: Dict) -> int:
    if is_flat_sums(sums):
        tot = 0
        for buf in sums["buffers"].values():
            if is_compressed_buffer(buf):
                tot += sum(int(x.nbytes) for _, x in buf["segments"])
            else:
                # flat buffers are normally fp32 arrays, but hand-built
                # partials may carry python lists/scalars — bill those at
                # the fp32 default like the nested path below
                tot += int(np.prod(np.shape(buf))) * int(np.dtype(
                    getattr(buf, "dtype", np.float32)).itemsize)
        return tot
    tot = 0
    for v in sums.values():
        if isinstance(v, dict) and v.get("__compressed__"):
            tot += sum(c.nbytes for c in v["leaves"])
        else:
            # uncompressed leaves ship at their REAL itemsize: a flat 4
            # over-billed bf16/fp16 payloads 2x (python scalars keep the
            # historical 4-byte accounting)
            tot += sum(int(np.prod(np.shape(l)))
                       * np.dtype(getattr(l, "dtype", np.float32)).itemsize
                       for l in jax.tree.leaves(v))
    return tot


def make_compressor(kind: str, arg: Optional[float] = None, *,
                    entries: tuple = ("delta",),
                    rank: Optional[int] = None,
                    compiled: bool = True, seed: int = 0):
    """Build a compressor by name.

    ``arg`` keeps its historical meaning (top-k fraction, default 0.01; for
    "powersgd" it doubles as the rank when ``rank=`` is not given).
    ``entries=`` targets extra reducible entries beyond "delta" (e.g.
    SCAFFOLD's control variates: ``entries=("delta", "delta_c")``).
    ``compiled=False`` selects the eager per-segment reference paths for
    topk/int8 (PowerSGD is only implemented compiled)."""
    if not kind or kind == "none":
        return None
    if kind == "topk":
        return TopKCompressor(fraction=0.01 if arg is None else float(arg),
                              entries=entries, compiled=compiled)
    if kind == "int8":
        return Int8Compressor(entries=entries, compiled=compiled)
    if kind == "powersgd":
        r = int(rank if rank is not None else (arg if arg else 4))
        return PowerSGDCompressor(rank=r, entries=entries, seed=seed)
    raise ValueError(kind)
