"""Executor → device placement and the sharded cross-device global fold.

Parrot's scaling claim is "1000+ simulated clients across flexible GPU
counts": K executors must actually *occupy* K local devices, not time-share
device 0.  A :class:`DevicePlacement` pins each executor to one local JAX
device (round-robin when K exceeds the device count); the executor then
compiles its client-step executables for that device
(``client_step.engine_for(algorithm, device)``), keeps its
``LocalAggregator`` accumulator and staged buffers resident there, and ships
device-resident flat partials through the comm layer with no host round-trip.

The server-side fold of the K per-device partials is the one point where
devices must meet.  ``global_fold`` keeps it device-native:

* **psum path** — when each partial sits on its own device (the one-executor-
  per-device case the benchmarks run), the per-device ``(n,)`` group buffers
  are assembled *in place* into one ``(K, n)`` array sharded ``P("data",
  None)`` over the placement's mesh (``jax.make_array_from_single_device_
  arrays`` — zero copy, no gather) and reduced with a single
  ``shard_map``/``psum`` per weight group.  On CPU host devices (and TPU ICI)
  the rank-ordered psum is bit-identical to the host path's left-fold
  ``b0+b1+…`` — the K-device parity tests rely on this.
* **colocate path** — any other shape (K not equal to the mesh size, partials
  sharing devices, legacy nested partials): buffers are copied device-to-
  device onto the fold device and left-folded exactly like the host path,
  preserving bit-exactness trivially.

Failure handling mirrors the engines' elastic membership: ``release`` drops a
dead executor's pin, and ``fail_device`` re-pins every executor that was
living on a dead device onto the remaining live devices (the executor's
device-resident caches are invalidated via ``SequentialExecutor.set_device``).

Tests run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so all
of this exercises real multi-device semantics on CPU.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

try:                                    # jax <= 0.5
    from jax.experimental.shard_map import shard_map
except ImportError:                     # jax >= 0.6
    shard_map = jax.shard_map


def local_devices(backend: Optional[str] = None) -> List[Any]:
    """The devices a placement may pin executors to (process-local)."""
    return list(jax.local_devices(backend=backend))


def _device_of(x) -> Optional[Any]:
    """The single device a committed array lives on, else None."""
    sh = getattr(x, "sharding", None)
    if sh is None:
        return None
    try:
        devs = list(sh.device_set)
    except Exception:
        return None
    return devs[0] if len(devs) == 1 else None


class DevicePlacement:
    """Executor id → local device map (+ the cross-device fold mesh).

    ``devices=None`` takes every local device; a single-device placement is
    valid (and degenerates to today's behaviour everywhere).  ``server``
    names the device where folded aggregates land (default: the first
    placement device, which is also where un-pinned server work runs).
    """

    def __init__(self, executor_ids: Sequence[int],
                 devices: Optional[Sequence[Any]] = None,
                 server: Optional[Any] = None):
        devices = list(devices) if devices is not None else local_devices()
        if not devices:
            raise ValueError("DevicePlacement needs at least one device")
        self._devices = devices
        self._map: Dict[int, Any] = {
            k: devices[i % len(devices)]
            for i, k in enumerate(sorted(executor_ids))}
        self.server_device = server if server is not None else devices[0]
        self._mesh_cache: Optional[Mesh] = None
        # steady-state gang-wave costs, shared by the ganged executors
        # (executor.run_queues_ganged): (sig, B_pad, K) -> seconds
        self._gang_cost: Dict[Tuple, float] = {}

    @classmethod
    def from_pins(cls, pins: Dict[int, Any],
                  server: Optional[Any] = None) -> "DevicePlacement":
        """Adopt an existing executor→device map (executors constructed
        with explicit ``device=`` pins)."""
        self = cls.__new__(cls)
        devs, seen = [], set()
        for k in sorted(pins):
            d = pins[k]
            if d.id not in seen:
                seen.add(d.id)
                devs.append(d)
        self._devices = devs
        self._map = dict(pins)
        self.server_device = server if server is not None else devs[0]
        self._mesh_cache = None
        self._gang_cost = {}
        return self

    # ------------------------------------------------------------------
    def device(self, executor: int) -> Any:
        return self._map[executor]

    def executors(self) -> List[int]:
        return sorted(self._map)

    def devices(self) -> List[Any]:
        """Distinct live devices, in first-pinned order."""
        seen, out = set(), []
        for k in sorted(self._map):
            d = self._map[k]
            if d.id not in seen:
                seen.add(d.id)
                out.append(d)
        return out

    @property
    def n_devices(self) -> int:
        return len({d.id for d in self._map.values()})

    def assign(self, executors: Sequence[Any]) -> None:
        """Pin a set of ``SequentialExecutor``s to their mapped devices."""
        for ex in executors:
            ex.set_device(self._map[ex.id])

    # ------------------------------------------------------------------
    def release(self, executor: int) -> None:
        """Drop a dead executor's pin (elastic K shrink)."""
        self._map.pop(executor, None)
        self._mesh_cache = None

    def pin(self, executor: int) -> Any:
        """Pin a (re)joining executor to the least-loaded live device
        (ties break on placement order — deterministic, so a crashed
        executor's restart re-pin is reproducible on resume).  Returns the
        chosen device; the caller pushes it into the executor via
        ``SequentialExecutor.set_device``."""
        if not self._devices:
            raise RuntimeError("no live devices to pin onto")
        load = {d.id: 0 for d in self._devices}
        for d in self._map.values():
            load[d.id] = load.get(d.id, 0) + 1
        dev = min(self._devices, key=lambda d: load[d.id])
        self._map[executor] = dev
        self._mesh_cache = None
        return dev

    def rebalance(self, queues, horizons, models, comm_cost=None):
        """Pollen-style throughput-driven re-pinning at queue granularity:
        re-pack every undispatched task across the executor set from the
        CURRENT fitted per-device workload models, seeding each lane with
        its busy horizon (``scheduler.rebalance_queues``).  Returns
        ``(assignment, moved)``."""
        from repro.core.scheduler import rebalance_queues
        return rebalance_queues(queues, horizons, models, comm_cost)

    def fail_device(self, device: Any) -> List[int]:
        """A device died: re-pin its executors round-robin onto the live
        devices.  Returns the re-pinned executor ids (the caller must push
        the new pin into each executor via ``set_device`` / ``assign``)."""
        dead_id = getattr(device, "id", device)
        live = [d for d in self._devices if d.id != dead_id]
        if not live:
            raise RuntimeError("no live devices left")
        self._devices = live
        moved = sorted(k for k, d in self._map.items() if d.id == dead_id)
        for i, k in enumerate(moved):
            self._map[k] = live[i % len(live)]
        self._mesh_cache = None
        return moved

    # ------------------------------------------------------------------
    def mesh(self) -> Mesh:
        """``("data", "model")`` host mesh over the placement's live
        devices, in pinned executor order (``launch.mesh.make_host_mesh``
        with ``model_axis=1`` — the fold mesh ``global_fold`` reduces over
        its data-parallel axes, which ``sharding.specs.dp_axes`` names)."""
        from repro.launch.mesh import make_host_mesh
        devs = self.devices()
        if self._mesh_cache is None or \
                [d.id for d in self._mesh_cache.devices.flat] != \
                [d.id for d in devs]:
            self._mesh_cache = make_host_mesh(devices=devs)
        return self._mesh_cache

    # ------------------------------------------------------------------
    def global_fold(self, partials: List[Dict[str, Any]],
                    ops: Dict[str, Any]) -> Dict[str, Any]:
        """``GlobalAggregate`` over device-resident partials.

        Flat partials whose buffers each sit on their own distinct device
        (in partial order matching the fold mesh) reduce with ONE
        ``shard_map``/``psum`` per weight group; anything else colocates
        onto the fold device and left-folds — both orders are bit-identical
        to the host path's ``b0+b1+…``.  The returned aggregate lands on
        ``server_device``."""
        from repro.core.aggregation import (global_aggregate,
                                            reduce_flat_partials)
        from repro.core.flat import is_flat_partial

        if not partials or not all(is_flat_partial(p) for p in partials):
            out = global_aggregate(partials, ops)
            return _put_tree(out, self.server_device)

        reduce_fn = self._make_reduce(partials)
        out = reduce_flat_partials(partials, ops, reduce_fn)
        return _put_tree(out, self.server_device)

    # below this per-group element count the colocating left-fold beats the
    # sharded psum: a multi-device SPMD dispatch costs ~10ms of host time on
    # CPU, far more than D2D-copying a few KB (the collective pays for
    # itself on real model sizes — and always on TPU ICI)
    psum_min_elements: int = 1 << 16

    def _make_reduce(self, partials: List[Dict[str, Any]]):
        mesh = self.mesh()
        mesh_ids = [d.id for d in mesh.devices.flat]

        def reduce_group(bufs: List[jnp.ndarray]) -> jnp.ndarray:
            devs = [_device_of(b) for b in bufs]
            ids = [getattr(d, "id", None) for d in devs]
            if (len(bufs) == len(mesh_ids) > 1 and ids == mesh_ids
                    and bufs[0].size >= self.psum_min_elements):
                # land the replicated psum output on the server device at
                # once: every downstream op (entry slicing, the per-OP
                # divisions, the server update) would otherwise run as an
                # SPMD eager dispatch over the whole mesh — an order of
                # magnitude more host overhead per op than the
                # single-device path
                return jax.device_put(_psum_rows(mesh, bufs),
                                      self.server_device)
            # colocate path: D2D copies onto the fold device, then the
            # host path's exact left fold
            target = self.server_device
            total = jax.device_put(bufs[0], target)
            for b in bufs[1:]:
                total = total + jax.device_put(b, target)
            return total

        return reduce_group


# the traced+compiled psum reduce, cached per (mesh identity, row count):
# rebuilding the shard_map closure per call would re-trace (and re-compile)
# every round
_REDUCE_CACHE: Dict[Tuple, Any] = {}


def _psum_reducer(mesh: Mesh, k: int):
    from repro.sharding.specs import dp_axes
    key = (tuple(d.id for d in mesh.devices.flat), k)
    fn = _REDUCE_CACHE.get(key)
    if fn is None:
        dp = dp_axes(mesh)

        @jax.jit
        @partial(shard_map, mesh=mesh, in_specs=P(dp, None), out_specs=P())
        def fn(x):
            return jax.lax.psum(jnp.squeeze(x, 0), dp)

        _REDUCE_CACHE[key] = fn
    return fn


def _psum_rows(mesh: Mesh, bufs: List[jnp.ndarray]) -> jnp.ndarray:
    """One rank-ordered psum over per-device ``(n,)`` buffers: each buffer
    becomes its own ``(1, n)`` shard of a ``(K, n)`` array laid out over
    the mesh's data-parallel axes — assembled zero-copy from the
    single-device pieces, no host gather — and one collective reduces
    them."""
    from repro.sharding.specs import dp_axes, stacked_partial_spec
    dp = dp_axes(mesh)
    n = bufs[0].shape[0]
    sharding = NamedSharding(mesh, stacked_partial_spec(mesh))
    rows = [jnp.reshape(b, (1, n)) for b in bufs]   # on-device reshape
    stacked = jax.make_array_from_single_device_arrays(
        (len(bufs), n), sharding, rows)
    return _psum_reducer(mesh, len(bufs))(stacked)


def _put_tree(tree: Any, device: Any) -> Any:
    """Move every array leaf of an aggregate onto ``device`` (D2D; leaves
    already there are untouched, non-arrays pass through)."""
    def leaf(x):
        if hasattr(x, "sharding"):
            if _device_of(x) is device:
                return x
            return jax.device_put(x, device)
        return x
    return jax.tree.map(leaf, tree)


def colocate(x: Any, like: Any) -> Any:
    """Return ``x`` placed so it can combine with ``like`` (device-to-device
    copy when their single-device shardings differ; no-op otherwise)."""
    sh = getattr(like, "sharding", None)
    xsh = getattr(x, "sharding", None)
    if sh is None or xsh is None or xsh == sh:
        return x
    return jax.device_put(x, sh)
