"""Virtual-time telemetry: span tracer + metrics registry (DESIGN.md §13).

The simulator's virtual clock makes every scheduling claim in the paper an
*observable*: when each executor computed, waited and shipped is a pure
function of the run.  This module turns that event stream into a
measurement instrument:

* :class:`Tracer` — append-only spans and instants on named lanes
  (``exec:{k}`` compute, ``exec:{k}:up`` uploads, ``server`` folds/commits,
  ``rounds`` one span per round, ``control`` controller moves, ``faults``
  planned windows), all on the VIRTUAL axis.  ``export(path)`` writes
  Chrome-trace / Perfetto JSON so a heterogeneous round renders as an
  executor-lane timeline; :func:`validate_trace` checks the documented
  schema (finite non-negative times, spans nest within a lane).

* :class:`MetricsRegistry` — typed counters / gauges / histograms with a
  stable naming scheme.  ``ingest_extra`` absorbs the engines' ad-hoc
  ``RoundMetrics.extra`` keys through :data:`EXTRA_SCHEMA` (cumulative
  counters under ``total/``, per-round gauges under ``round/``), with
  ``extra_last`` / ``extra_total`` as compatibility accessors.  The
  ``host/`` namespace carries host-side cost attribution (wall seconds,
  jit compile counts via the existing ``jax.monitoring`` hook) and is
  explicitly process-local: it is the only namespace excluded from the
  determinism and resume guarantees below.

* :class:`Telemetry` — the bundle a :class:`~repro.core.round.ParrotServer`
  owns (``telemetry=``).  ``on_round`` runs at each round commit: it
  ingests the round's extra, derives per-executor **utilization**
  (busy/comm/idle fractions of the round window — the paper's "computing
  utility" metric) from the spans, and appends the round span.

Zero-overhead off: ``telemetry=None`` (the default) is consulted nowhere —
every engine stays bit-exact (params AND makespans), following the
``network=None`` / ``faults=None`` / ``control=None`` pattern.  When ON,
emission only *reads* already-computed values (no timer calls, no RNG, no
jax ops), so enabling the tracer is bit-exact too.  Tracer and registry
state are plain data and ride the checkpoint blob (key ``"telemetry"``),
so ``auto_resume`` reproduces the uninterrupted run's trace exactly.
"""
from __future__ import annotations

import bisect
import json
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

#: RoundMetrics.extra key -> (kind, doc).  ``counter`` keys accumulate
#: across rounds under ``total/<key>``; ``gauge`` keys keep the round's
#: value under ``round/<key>``.  Nested ``state_manager`` stats flatten to
#: ``state_manager/<key>`` (``*_bytes`` are tier-size gauges, the rest
#: per-round deltas -> counters).  Unknown keys default to counter.
EXTRA_SCHEMA: Dict[str, Tuple[str, str]] = {
    # scheduling / BSP
    "backup_tasks": ("counter", "speculative backup duplicates planned"),
    "remapped_tasks": ("counter", "overlapped-schedule tasks re-homed"),
    "idle_time": ("counter", "virtual seconds fast-forwarded (gaps)"),
    # comm (netsim)
    "comm_time_up": ("counter", "accounted upload seconds"),
    "comm_time_down": ("counter", "accounted download seconds"),
    "comm_wire_bytes": ("counter", "achieved wire bytes uploaded"),
    "dropped_clients": ("counter", "clients lost to availability/faults"),
    # faults
    "retries": ("counter", "client re-runs / upload re-sends"),
    "corrupt_payloads": ("counter", "partials discarded as corrupt"),
    "fault_crashes": ("counter", "executor crashes fired"),
    "fault_restarts": ("counter", "executor restarts fired"),
    "chunk_timeouts": ("counter", "upload attempts that timed out"),
    "quorum_commits": ("counter", "rounds committed degraded at quorum"),
    # semi-sync
    "landed_clients": ("counter", "clients folded before the deadline"),
    "carried_tasks": ("gauge", "carry-pool size at round end"),
    "deadline": ("gauge", "the round's virtual-time deadline"),
    "deadline_frac": ("gauge", "deadline fraction in force"),
    # async
    "steals": ("counter", "work-steal events"),
    "stale_folds": ("counter", "folds with staleness > 0"),
    "mean_staleness": ("gauge", "window mean staleness"),
    "in_system": ("gauge", "clients in flight after the commit"),
    "staleness_lambda": ("gauge", "λ the window folded with"),
    # control plane
    "oracle_makespan": ("gauge", "hindsight-optimal LPT makespan"),
    "rebalanced_tasks": ("counter", "tasks moved by rebalance/steal"),
}


def _extra_kind(key: str) -> str:
    if key.startswith("state_manager/"):
        return "gauge" if key.endswith("_bytes") else "counter"
    return EXTRA_SCHEMA.get(key, ("counter", ""))[0]


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotone cumulative value."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = float(value)

    def inc(self, v: float = 1.0) -> None:
        self.value += float(v)


class Gauge:
    """Last-written value."""

    __slots__ = ("value",)

    def __init__(self, value: float = 0.0):
        self.value = float(value)

    def set(self, v: float) -> None:
        self.value = float(v)


#: default histogram bucket upper bounds (last bucket is +inf)
DEFAULT_BOUNDS: Tuple[float, ...] = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0)


class Histogram:
    """Fixed-bucket histogram with count/total/min/max."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Iterable[float] = DEFAULT_BOUNDS):
        self.bounds = tuple(float(b) for b in bounds)
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def state_dict(self) -> Dict[str, Any]:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "count": self.count, "total": self.total,
                "min": self.vmin, "max": self.vmax}

    @classmethod
    def from_state_dict(cls, st: Dict[str, Any]) -> "Histogram":
        h = cls(st["bounds"])
        h.counts = list(st["counts"])
        h.count = int(st["count"])
        h.total = float(st["total"])
        h.vmin = st["min"]
        h.vmax = st["max"]
        return h


class MetricsRegistry:
    """Typed named metrics.  Names are slash-namespaced:

    ``total/<key>``   cumulative counters absorbed from RoundMetrics.extra
    ``round/<key>``   the last round's gauge values from extra
    ``round/*``       core per-round gauges (makespan, n_clients, ...)
    ``util/exec<k>/*``  busy/comm/idle fractions of the last round window
    ``hist/*``        histograms (async staleness, queue depth, upload delay)
    ``control/<name>``  last controller outputs (ControlPlane.note)
    ``host/*``        host-side cost attribution — PROCESS-LOCAL (wall
                      seconds, compile counts); excluded from determinism /
                      resume equality guarantees
    """

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self.last_extra: Dict[str, Any] = {}

    # -- accessors ---------------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  bounds: Optional[Iterable[float]] = None) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(bounds or DEFAULT_BOUNDS)
        return h

    def value(self, name: str) -> Optional[float]:
        """The metric's scalar value (histograms report their mean)."""
        if name in self._counters:
            return self._counters[name].value
        if name in self._gauges:
            return self._gauges[name].value
        if name in self._hists:
            return self._hists[name].mean
        return None

    # -- the extra[...] compatibility layer --------------------------------
    def ingest_extra(self, extra: Dict[str, Any]) -> None:
        """Absorb one round's ``RoundMetrics.extra`` through
        :data:`EXTRA_SCHEMA`: numeric values route to ``total/`` counters
        or ``round/`` gauges by declared kind; the nested ``state_manager``
        dict flattens with a ``/``."""
        self.last_extra = dict(extra)
        flat: List[Tuple[str, Any]] = []
        for key, val in extra.items():
            if isinstance(val, dict):
                flat.extend((f"{key}/{k}", v) for k, v in val.items())
            else:
                flat.append((key, val))
        for key, val in flat:
            if not isinstance(val, (int, float)) or isinstance(val, bool):
                continue
            v = float(val)
            if _extra_kind(key) == "gauge":
                self.gauge(f"round/{key}").set(v)
            elif math.isfinite(v):
                self.counter(f"total/{key}").inc(v)

    def extra_last(self, key: str, default: float = 0.0) -> float:
        """The last round's value of an extra key (compat accessor)."""
        v = self.last_extra.get(key, default)
        return float(v) if isinstance(v, (int, float)) else default

    def extra_total(self, key: str, default: float = 0.0) -> float:
        """Cumulative total of a counter-kind extra key (compat accessor)."""
        c = self._counters.get(f"total/{key}")
        return c.value if c is not None else default

    # -- snapshots / checkpointing -----------------------------------------
    def snapshot(self, exclude: Tuple[str, ...] = ()) -> Dict[str, Any]:
        """Plain-data view; ``exclude`` drops name prefixes (the resume /
        determinism tests compare snapshots with ``("host/",)``)."""

        def keep(name: str) -> bool:
            return not any(name.startswith(p) for p in exclude)

        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())
                         if keep(n)},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())
                       if keep(n)},
            "histograms": {n: h.state_dict()
                           for n, h in sorted(self._hists.items())
                           if keep(n)},
        }

    def state_dict(self) -> Dict[str, Any]:
        st = self.snapshot()
        st["last_extra"] = dict(self.last_extra)
        return st

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self._counters = {n: Counter(v)
                          for n, v in state.get("counters", {}).items()}
        self._gauges = {n: Gauge(v)
                        for n, v in state.get("gauges", {}).items()}
        self._hists = {n: Histogram.from_state_dict(h)
                       for n, h in state.get("histograms", {}).items()}
        self.last_extra = dict(state.get("last_extra", {}))


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

class Tracer:
    """Append-only virtual-time spans and instants on named lanes.

    A span is ``(lane, name, t0, t1, cat, args)``; an instant is
    ``(lane, name, t, cat, args)``.  Lanes:

    ``exec:{k}``     one ``cat="busy"`` span per chunk, covering the
                     executor's whole occupancy (download/wait + compute);
                     ``args["down_s"]`` is the non-compute share.  Crash /
                     restart / steal / failure instants ride here too.
    ``exec:{k}:up``  ``cat="comm"`` upload spans (may overlap each other —
                     uploads overlap the next chunk by design), with
                     ``wire_bytes`` and ``billed_bytes`` (retries re-bill).
    ``server``       fold / commit / rebalance instants.
    ``rounds``       one ``cat="server"`` span per committed round.
    ``control``      controller-move instants (ControlPlane.note).
    ``faults``       the plan's blackout/slowdown/dropout windows as
                     ``cat="fault"`` spans (emitted once at attach time).

    All times are virtual seconds on the server's absolute axis.  Emission
    is pure recording — callers pass values they already computed.
    """

    def __init__(self):
        self.spans: List[Tuple[str, str, float, float, str,
                               Optional[Dict[str, Any]]]] = []
        self.instants: List[Tuple[str, str, float, str,
                                  Optional[Dict[str, Any]]]] = []

    def span(self, lane: str, name: str, t0: float, t1: float,
             cat: str = "busy",
             args: Optional[Dict[str, Any]] = None) -> None:
        self.spans.append((lane, name, float(t0), float(t1), cat, args))

    def instant(self, lane: str, name: str, t: float, cat: str = "mark",
                args: Optional[Dict[str, Any]] = None) -> None:
        self.instants.append((lane, name, float(t), cat, args))

    def lanes(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in self.spans:
            seen.setdefault(s[0])
        for i in self.instants:
            seen.setdefault(i[0])
        return sorted(seen)

    # -- export ------------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        """Chrome-trace / Perfetto JSON object.  Virtual seconds map to
        microseconds; every lane is a named thread of pid 0.  Up-lanes
        export as async ``b``/``e`` pairs (their spans legitimately
        overlap); every other lane as complete ``X`` events."""
        tids = {lane: i for i, lane in enumerate(self.lanes())}
        events: List[Dict[str, Any]] = [
            {"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
             "args": {"name": lane}} for lane, tid in tids.items()]
        aid = 0
        for lane, name, t0, t1, cat, args in self.spans:
            base = {"name": name, "cat": cat, "pid": 0, "tid": tids[lane],
                    "args": dict(args) if args else {}}
            if lane.endswith(":up"):
                aid += 1
                events.append({**base, "ph": "b", "id": aid,
                               "ts": t0 * 1e6})
                events.append({"ph": "e", "id": aid, "name": name,
                               "cat": cat, "pid": 0, "tid": tids[lane],
                               "ts": t1 * 1e6})
            else:
                events.append({**base, "ph": "X", "ts": t0 * 1e6,
                               "dur": (t1 - t0) * 1e6})
        for lane, name, t, cat, args in self.instants:
            events.append({"ph": "i", "s": "t", "name": name, "cat": cat,
                           "pid": 0, "tid": tids[lane], "ts": t * 1e6,
                           "args": dict(args) if args else {}})
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
        return path

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"spans": [list(s) for s in self.spans],
                "instants": [list(i) for i in self.instants]}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self.spans = [tuple(s) for s in state.get("spans", [])]
        self.instants = [tuple(i) for i in state.get("instants", [])]


def _spans_from(src: Any) -> Tuple[List[Tuple], List[Tuple]]:
    """(spans, instants) from a Tracer, a tracer state_dict, a Chrome-trace
    dict, or a path to an exported trace file."""
    if isinstance(src, Tracer):
        return list(src.spans), list(src.instants)
    if isinstance(src, str):
        with open(src) as f:
            src = json.load(f)
    if not isinstance(src, dict):
        raise TypeError(f"cannot validate {type(src).__name__}")
    if "traceEvents" in src:
        lanes: Dict[int, str] = {}
        for ev in src["traceEvents"]:
            if ev.get("ph") == "M" and ev.get("name") == "thread_name":
                lanes[ev["tid"]] = ev["args"]["name"]
        spans, instants, open_async = [], [], {}
        for ev in src["traceEvents"]:
            lane = lanes.get(ev.get("tid"), f"tid{ev.get('tid')}")
            if ev.get("ph") == "X":
                t0 = ev["ts"] / 1e6
                spans.append((lane, ev["name"], t0,
                              t0 + ev["dur"] / 1e6, ev.get("cat", ""),
                              ev.get("args")))
            elif ev.get("ph") == "b":
                open_async[ev["id"]] = ev
            elif ev.get("ph") == "e":
                b = open_async.pop(ev["id"], None)
                if b is not None:
                    spans.append((lane, b["name"], b["ts"] / 1e6,
                                  ev["ts"] / 1e6, b.get("cat", ""),
                                  b.get("args")))
            elif ev.get("ph") == "i":
                instants.append((lane, ev["name"], ev["ts"] / 1e6,
                                 ev.get("cat", ""), ev.get("args")))
        for b in open_async.values():   # unmatched begin: surfaced as a span
            spans.append((lanes.get(b.get("tid"), "?"), b["name"],
                          b["ts"] / 1e6, float("nan"), b.get("cat", ""),
                          b.get("args")))
        return spans, instants
    return ([tuple(s) for s in src.get("spans", [])],
            [tuple(i) for i in src.get("instants", [])])


def validate_trace(src: Any) -> List[str]:
    """Schema check (DESIGN.md §13).  Returns a list of problems (empty =
    valid): every time finite and non-negative, spans end at or after they
    start, and within each lane the ``busy``/``server`` spans are disjoint
    or properly nested (uploads are exempt: they overlap by design).
    Accepts a :class:`Tracer`, its ``state_dict()``, a Chrome-trace dict,
    or a path to an exported file."""
    spans, instants = _spans_from(src)
    problems: List[str] = []
    for lane, name, t0, t1, cat, args in spans:
        if not (math.isfinite(t0) and math.isfinite(t1)):
            problems.append(f"span {lane}/{name}: non-finite time "
                            f"[{t0}, {t1}]")
        elif t0 < 0.0:
            problems.append(f"span {lane}/{name}: negative start {t0}")
        elif t1 < t0:
            problems.append(f"span {lane}/{name}: ends before it starts "
                            f"[{t0}, {t1}]")
        if args and float(args.get("wire_bytes", 0)) < 0:
            problems.append(f"span {lane}/{name}: negative wire_bytes")
    for lane, name, t, cat, args in instants:
        if not math.isfinite(t) or t < 0.0:
            problems.append(f"instant {lane}/{name}: bad time {t}")
    by_lane: Dict[str, List[Tuple[float, float, str]]] = {}
    for lane, name, t0, t1, cat, args in spans:
        if cat in ("busy", "server") and math.isfinite(t0) \
                and math.isfinite(t1) and t1 >= t0:
            by_lane.setdefault(lane, []).append((t0, t1, name))
    for lane, ss in by_lane.items():
        stack: List[Tuple[float, float, str]] = []
        for t0, t1, name in sorted(ss, key=lambda s: (s[0], -s[1])):
            while stack:
                tol = 1e-9 * (1.0 + abs(stack[-1][1]))
                if t0 >= stack[-1][1] - tol:
                    stack.pop()
                else:
                    break
            if stack:
                tol = 1e-9 * (1.0 + abs(stack[-1][1]))
                if t1 > stack[-1][1] + tol:
                    problems.append(
                        f"lane {lane}: span {name} [{t0}, {t1}] overlaps "
                        f"{stack[-1][2]} [.., {stack[-1][1]}] without "
                        f"nesting")
            stack.append((t0, t1, name))
    return problems


# ---------------------------------------------------------------------------
# the server-side bundle
# ---------------------------------------------------------------------------

class Telemetry:
    """Tracer + registry + the round-commit hook the server drives.

    Attach with ``ParrotServer(telemetry=Telemetry())`` (or
    ``telemetry=True``).  The server wires the same object into the fault
    injector and control plane so their events land on the shared lanes.
    """

    def __init__(self, tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.tracer = tracer or Tracer()
        self.registry = registry or MetricsRegistry()
        # compile-count anchor for host/ attribution (process-local; the
        # jax.monitoring listener in client_step counts jit compiles)
        from repro.core import client_step
        self._compiles_seen = client_step.compile_events()

    # -- emission hooks ----------------------------------------------------
    def control_event(self, name: str, value: float, t: float) -> None:
        """One controller move (``ControlPlane.note``): an instant on the
        ``control`` lane plus a ``control/<name>`` gauge."""
        self.tracer.instant("control", name, t, cat="control",
                            args={"value": float(value)})
        self.registry.gauge(f"control/{name}").set(float(value))

    def trace_plan(self, plan: Any) -> None:
        """Pre-trace a fault plan's windowed events (blackout / slowdown /
        dropout) as spans on the ``faults`` lane — the one-shot events
        (crash, restart, corrupt) are emitted live when they fire."""
        plan = getattr(plan, "plan", plan)    # accept a FaultInjector
        if plan is None:
            return
        for ev in plan:
            if ev.kind == "blackout":
                self.tracer.span("faults", "blackout", ev.time, ev.end,
                                 cat="fault",
                                 args={"executor": (-1 if ev.executor is None
                                                    else ev.executor)})
            elif ev.kind == "slowdown":
                self.tracer.span("faults", "slowdown", ev.time, ev.end,
                                 cat="fault",
                                 args={"executor": ev.executor,
                                       "factor": ev.factor})
            elif ev.kind == "dropout":
                self.tracer.span("faults", "dropout", ev.time, ev.end,
                                 cat="fault", args={"client": ev.client})

    # -- utilization accounting --------------------------------------------
    def utilization(self, t0: float, t1: float,
                    executors: Iterable[int] = ()
                    ) -> Dict[int, Dict[str, float]]:
        """Per-executor busy/comm/idle fractions of the window ``[t0, t1]``
        derived from the spans: a busy span's compute share is its length
        minus ``args["down_s"]`` (download + in-span wait), up-lane spans
        count as comm, both clipped to the window; idle is the remainder.
        Fractions sum to 1 per executor (up to float rounding)."""
        W = t1 - t0
        busy: Dict[int, float] = {}
        comm: Dict[int, float] = {}
        execs = {int(k) for k in executors}
        for lane, name, s0, s1, cat, args in self.tracer.spans:
            if not lane.startswith("exec:"):
                continue
            rest = lane[5:]
            is_up = rest.endswith(":up")
            if is_up:
                rest = rest[:-3]
            try:
                k = int(rest)
            except ValueError:
                continue
            ov = min(s1, t1) - max(s0, t0)
            if ov <= 0.0:
                continue
            execs.add(k)
            if is_up:
                comm[k] = comm.get(k, 0.0) + ov
            elif cat == "busy":
                full = s1 - s0
                f = ov / full if full > 0.0 else 1.0
                d = float(args.get("down_s", 0.0)) if args else 0.0
                d = min(max(d, 0.0), full)
                comm[k] = comm.get(k, 0.0) + d * f
                busy[k] = busy.get(k, 0.0) + (full - d) * f
        out: Dict[int, Dict[str, float]] = {}
        for k in sorted(execs):
            if not (W > 0.0):
                out[k] = {"busy_frac": 0.0, "comm_frac": 0.0,
                          "idle_frac": 1.0}
                continue
            b = min(busy.get(k, 0.0) / W, 1.0)
            c = max(min(comm.get(k, 0.0) / W, 1.0 - b), 0.0)
            out[k] = {"busy_frac": b, "comm_frac": c,
                      "idle_frac": 1.0 - b - c}
        return out

    # -- the round-commit hook (ParrotServer._commit_metrics) --------------
    def on_round(self, srv: Any, metrics: Any, t0: float) -> None:
        """Ingest one committed round: extra -> registry, core gauges,
        host-side attribution, per-executor utilization (attached to
        ``metrics.extra["utilization"]`` BEFORE the metrics join history,
        so checkpointed history carries it too), and the round span."""
        reg = self.registry
        reg.ingest_extra(metrics.extra)
        t1 = t0 + metrics.makespan if math.isfinite(metrics.makespan) else t0
        reg.gauge("round/makespan").set(metrics.makespan)
        reg.gauge("round/n_clients").set(float(metrics.n_clients))
        reg.gauge("round/n_executors").set(float(metrics.n_executors))
        reg.counter("total/rounds").inc(1.0)
        reg.counter("total/virtual_time").inc(metrics.makespan)
        reg.counter("total/comm_bytes").inc(float(metrics.comm_bytes))
        reg.counter("total/failures").inc(float(metrics.failures))
        # host-side cost attribution (PROCESS-LOCAL: wall vs virtual time,
        # jit compiles) — never compared across runs or resumes
        reg.gauge("host/round_wall_s").set(metrics.wall_time)
        reg.counter("host/wall_s").inc(metrics.wall_time)
        from repro.core import client_step
        c = client_step.compile_events()
        reg.counter("host/compiles").inc(float(c - self._compiles_seen))
        self._compiles_seen = c
        util = self.utilization(t0, t1, srv.executors)
        metrics.extra["utilization"] = util
        for k, u in util.items():
            reg.gauge(f"util/exec{k}/busy_frac").set(u["busy_frac"])
            reg.gauge(f"util/exec{k}/comm_frac").set(u["comm_frac"])
            reg.gauge(f"util/exec{k}/idle_frac").set(u["idle_frac"])
        self.tracer.span(
            "rounds", f"round {metrics.round}", t0, t1, cat="server",
            args={"round": metrics.round, "engine": srv.engine.mode,
                  "makespan": metrics.makespan,
                  "n_clients": metrics.n_clients})

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"tracer": self.tracer.state_dict(),
                "registry": self.registry.state_dict()}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self.tracer.load_state_dict(state.get("tracer"))
        self.registry.load_state_dict(state.get("registry"))
        # host/ attribution re-anchors to THIS process's compile counter
        from repro.core import client_step
        self._compiles_seen = client_step.compile_events()
