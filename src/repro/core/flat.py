"""Flatten-once parameter layout for batched multi-client aggregation.

The aggregation hot loop folds every simulated client's multi-entry delta
(a dict of pytrees) into the executor's O(s_a) fp32 partial.  Folding leaf by
leaf dispatches one kernel per pytree leaf per client — pure dispatch and
padding overhead on the simulator's hottest path.  A :class:`FlatLayout`
computes, once per round, the mapping

    entry name -> (group, offset, size)        per communicated entry
    leaf       -> (offset, size, shape, dtype) per pytree leaf

so a client's whole reducible payload becomes ONE contiguous 1-D buffer per
*weight group*:

  ``weighted`` — entries aggregated as Σ w_m x_m (``Op.WEIGHTED_AVG``)
  ``unit``     — entries aggregated with unit weight (``Op.AVG``/``Op.SUM``)

The two groups exist because a single fold applies one scalar weight per
client; WEIGHTED_AVG entries fold at w_m while AVG/SUM entries fold at 1.0.
``Op.COLLECT`` entries are excluded (they cannot be reduced; they ride the
partial as a per-client list exactly as before).

With the layout in hand, ``LocalAggregator`` stages up to B client buffers
and folds them with a single ``agg_weighted_sum`` kernel dispatch at C=B —
one dispatch per micro-batch instead of leaves x clients — and the global
aggregate / compressors / comm paths all move one array per partial instead
of a nested dict of leaves.

The group buffer dtype is ``jnp.result_type`` over the member leaf dtypes:
an all-bf16 delta stays bf16 on the wire into the fold (halving bytes
moved); mixed bf16/fp32 promotes to fp32.  Accumulators and unflattened
aggregates are always fp32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

GROUPS = ("weighted", "unit")


@dataclass(frozen=True)
class LeafSpec:
    """One pytree leaf's home in its group buffer."""
    entry: str
    index: int                 # leaf index within the entry's pytree
    offset: int                # into the group buffer
    size: int
    shape: Tuple[int, ...]
    dtype: Any                 # the leaf's original dtype


@dataclass(frozen=True)
class EntrySpan:
    """One entry's contiguous span in its group buffer (leaves of an entry
    are always laid out contiguously, so compressors can treat the entry as
    a single 1-D array)."""
    group: str
    offset: int
    size: int


def _group_of(op: Any) -> str:
    return "weighted" if getattr(op, "name", None) == "WEIGHTED_AVG" else "unit"


class FlatLayout:
    """Leaf names -> offsets/shapes/dtypes, computed once from the
    algorithm's ops plus one template payload."""

    def __init__(self, specs: Dict[str, Tuple[LeafSpec, ...]],
                 spans: Dict[str, EntrySpan],
                 treedefs: Dict[str, Any],
                 group_sizes: Dict[str, int],
                 group_dtypes: Dict[str, Any],
                 entry_order: Dict[str, Tuple[str, ...]]):
        self.specs = specs                  # group -> LeafSpecs in offset order
        self.spans = spans                  # entry  -> EntrySpan
        self.treedefs = treedefs            # entry  -> pytree treedef
        self.group_sizes = group_sizes      # group  -> total element count
        self.group_dtypes = group_dtypes    # group  -> buffer dtype
        self.entry_order = entry_order      # group  -> entry names in order
        # one jit per layout; executables inside it are keyed by the input
        # shardings, so per-device callers (``device=``) get their own
        # executables out of the same cache — a layout shared across
        # device-pinned executors never cross-wires or thrashes
        self._flatten_jit = None
        self._flatten_batch_jit = None

    # ------------------------------------------------------------------
    @classmethod
    def build(cls, ops: Dict[str, Any], payload: Dict[str, Any]) -> "FlatLayout":
        """Compute the layout from the OP registry and a template payload.
        COLLECT entries and entries absent from the payload are skipped."""
        specs: Dict[str, List[LeafSpec]] = {g: [] for g in GROUPS}
        spans: Dict[str, EntrySpan] = {}
        treedefs: Dict[str, Any] = {}
        order: Dict[str, List[str]] = {g: [] for g in GROUPS}
        cursor = {g: 0 for g in GROUPS}
        for name, value in payload.items():
            op = ops.get(name)
            if op is None or getattr(op, "name", None) == "COLLECT":
                continue
            g = _group_of(op)
            leaves, treedef = jax.tree.flatten(value)
            treedefs[name] = treedef
            order[g].append(name)
            start = cursor[g]
            for i, leaf in enumerate(leaves):
                shape = tuple(jnp.shape(leaf))
                size = int(np.prod(shape)) if shape else 1
                specs[g].append(LeafSpec(name, i, cursor[g], size, shape,
                                         jnp.asarray(leaf).dtype))
                cursor[g] += size
            spans[name] = EntrySpan(g, start, cursor[g] - start)
        sizes = {g: cursor[g] for g in GROUPS if cursor[g]}
        dtypes = {g: jnp.result_type(*[s.dtype for s in specs[g]])
                  for g in sizes}
        return cls({g: tuple(specs[g]) for g in sizes}, spans, treedefs,
                   sizes, dtypes, {g: tuple(order[g]) for g in sizes})

    # ------------------------------------------------------------------
    def _flatten_impl(self, payload: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
        out: Dict[str, jnp.ndarray] = {}
        for g, entries in self.entry_order.items():
            dtype = self.group_dtypes[g]
            parts = []
            for name in entries:
                for leaf in jax.tree.leaves(payload[name]):
                    parts.append(jnp.ravel(jnp.asarray(leaf)).astype(dtype))
            out[g] = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        return out

    @staticmethod
    def _commit(payload: Dict[str, Any], device) -> Dict[str, Any]:
        """Commit every leaf to ``device`` (no-op for leaves already there)
        so the jitted flatten runs — and its output stays — on that device
        rather than silently landing on the process default device."""
        if device is None:
            return payload
        from jax.sharding import SingleDeviceSharding
        home = SingleDeviceSharding(device)   # cheap equality per leaf
        return jax.tree.map(
            lambda x: x if getattr(x, "sharding", None) == home
            else jax.device_put(x, device), payload)

    def flatten(self, payload: Dict[str, Any],
                device=None) -> Dict[str, jnp.ndarray]:
        """One contiguous 1-D buffer per group from a client payload.

        Jit-compiled once per layout (flatten-once): the whole
        ravel/cast/concat chain fuses into a single dispatch per client
        instead of one op per pytree leaf.  ``device`` commits the inputs
        (and therefore the buffers) to a specific device — the executables
        are cached per sharding inside the one jit."""
        if self._flatten_jit is None:
            self._flatten_jit = jax.jit(self._flatten_impl)
        return self._flatten_jit(self._commit(payload, device))

    def flatten_batch(self, payload: Dict[str, Any],
                      device=None) -> Dict[str, jnp.ndarray]:
        """(B, n) group buffers from a payload with a leading client axis —
        the vmapped-client-engine analogue of ``flatten``: one fused
        dispatch flattens a whole block, and the result folds directly with
        a single C=B kernel call (no per-client unflatten/refold).  The
        batched form is literally ``vmap(_flatten_impl)``, so the two paths
        cannot drift apart."""
        if self._flatten_batch_jit is None:
            self._flatten_batch_jit = jax.jit(jax.vmap(self._flatten_impl))
        return self._flatten_batch_jit(self._commit(payload, device))

    def zeros(self, device=None) -> Dict[str, jnp.ndarray]:
        """Fresh fp32 accumulators, one per group (the O(s_a) partial),
        resident on ``device`` when given."""
        out = {g: jnp.zeros((n,), jnp.float32)
               for g, n in self.group_sizes.items()}
        if device is not None:
            out = {g: jax.device_put(b, device) for g, b in out.items()}
        return out

    def entry_slice(self, name: str, buffers: Dict[str, jnp.ndarray]
                    ) -> jnp.ndarray:
        """The entry's contiguous 1-D segment of its group buffer."""
        span = self.spans[name]
        return buffers[span.group][span.offset:span.offset + span.size]

    def unflatten_entry(self, name: str, segment: jnp.ndarray) -> Any:
        """Rebuild one entry's pytree (fp32 leaves) from its 1-D segment."""
        span = self.spans[name]
        leaves = []
        for s in self.specs[span.group]:
            if s.entry != name:
                continue
            rel = s.offset - span.offset
            leaves.append(segment[rel:rel + s.size].reshape(s.shape))
        return jax.tree.unflatten(self.treedefs[name], leaves)

    def unflatten(self, buffers: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
        """entry name -> pytree for every entry present in ``buffers``."""
        return {name: self.unflatten_entry(name, self.entry_slice(name, buffers))
                for name, span in self.spans.items()
                if span.group in buffers}

    def signature(self) -> Tuple:
        """Structural identity: partials folded under equal signatures can be
        combined buffer-wise."""
        return tuple(sorted((name, sp.group, sp.offset, sp.size)
                            for name, sp in self.spans.items()))

    # the compiled flatten is a cache, not state: a layout that crosses a
    # real (pickling) transport re-jits on first use at the far end
    def __getstate__(self):
        state = dict(self.__dict__)
        state["_flatten_jit"] = None
        state["_flatten_batch_jit"] = None
        return state


# ---------------------------------------------------------------------------
# module-level helpers (the partial wire format)
# ---------------------------------------------------------------------------

def flatten(layout: FlatLayout, payload: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
    return layout.flatten(payload)


def unflatten(layout: FlatLayout, buffers: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    return layout.unflatten(buffers)


def flat_sums(buffers: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    """The wire form of a flat partial's sums: one array per group."""
    return {"__flat__": True, "buffers": buffers}


def is_flat_sums(sums: Any) -> bool:
    return isinstance(sums, dict) and bool(sums.get("__flat__"))


def is_flat_partial(partial: Dict[str, Any]) -> bool:
    return isinstance(partial, dict) and is_flat_sums(partial.get("sums"))


def is_compressed_buffer(buf: Any) -> bool:
    """A group buffer in compressed wire form (see core/compression.py):
    ``{"__compressed__": True, "segments": [...], "size": n}`` instead of a
    dense 1-D array.  Compiled codecs ship these all the way to the fold."""
    return isinstance(buf, dict) and bool(buf.get("__compressed__"))


def to_nested_sums(partial: Dict[str, Any]) -> Dict[str, Any]:
    """Degrade a flat partial's sums to the legacy {entry: pytree} form
    (interop with hand-built nested partials)."""
    layout: Optional[FlatLayout] = partial.get("layout")
    if layout is None:
        return {}
    buffers = partial["sums"]["buffers"]
    if any(is_compressed_buffer(b) for b in buffers.values()):
        from repro.core.compression import densify_buffer
        buffers = {g: (densify_buffer(b) if is_compressed_buffer(b) else b)
                   for g, b in buffers.items()}
    return layout.unflatten(buffers)
