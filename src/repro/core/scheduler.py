"""Heterogeneity-aware task scheduling (paper §4.4, Algorithm 3).

Greedy LPT (longest-processing-time-first) assignment minimising the
estimated round makespan

    min_{M_1..M_K}  max_k  Σ_{m in M_k} T_{m,k}            (Eq. 3)

For each task (descending N_m) the executor chosen is

    k* = argmin_k ( w_k + N_m t_k^sample + b_k )            (Eq. 4)

— O(K · M_p) with a linear argmin per task (a heap does not apply directly
because T_{m,k} depends on k through both slope and offset).

Schedulers:
  parrot   — Algorithm 3 with the fitted workload model (warmup: uniform)
  uniform  — uniformly split |M^r| across executors (paper warmup / ablation)
  none     — arrival-order round-robin (emulates unscheduled FA-Dist)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence

from repro.core.workload import DEFAULT_MODEL, WorkloadEstimator, WorkloadModel


@dataclass(frozen=True)
class ClientTask:
    client: int
    n_samples: int


@dataclass
class Schedule:
    assignment: Dict[int, List[ClientTask]]      # executor -> tasks
    predicted_makespan: float
    schedule_time_s: float
    estimate_time_s: float

    def queue(self, executor: int) -> List[ClientTask]:
        return self.assignment.get(executor, [])

    @property
    def max_queue_len(self) -> int:
        return max((len(v) for v in self.assignment.values()), default=0)


def _uniform(tasks: Sequence[ClientTask], executors: Sequence[int]) -> Dict[int, List[ClientTask]]:
    assignment: Dict[int, List[ClientTask]] = {k: [] for k in executors}
    for i, t in enumerate(tasks):
        assignment[executors[i % len(executors)]].append(t)
    return assignment


class ParrotScheduler:
    """Algorithm 3.  Stateless given the estimator — this is what makes
    elastic membership trivial: the executor set is an argument per round."""

    def __init__(self, estimator: WorkloadEstimator, warmup_rounds: int = 1,
                 policy: str = "parrot"):
        self.estimator = estimator
        self.warmup_rounds = warmup_rounds
        self.policy = policy

    def schedule(self, rnd: int, tasks: Sequence[ClientTask],
                 executors: Sequence[int]) -> Schedule:
        t0 = time.perf_counter()
        executors = list(executors)
        if self.policy == "none":
            assignment = _uniform(list(tasks), executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)
        if self.policy == "uniform" or rnd < self.warmup_rounds:
            assignment = _uniform(sorted(tasks, key=lambda t: -t.n_samples),
                                  executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)

        models = self.estimator.fit(rnd)
        est_time = self.estimator.fit_time_s
        t0 = time.perf_counter()
        assignment = {k: [] for k in executors}
        w = {k: 0.0 for k in executors}
        # executors with no history yet (fresh/elastic joiners) default to
        # the fleet average — a pessimistic default would starve them of
        # work forever (found by the hypothesis property suite)
        if models:
            avg = WorkloadModel(
                t_sample=sum(m.t_sample for m in models.values()) / len(models),
                b=sum(m.b for m in models.values()) / len(models))
        else:
            avg = DEFAULT_MODEL
        mdl = {k: models.get(k, avg) for k in executors}
        for task in sorted(tasks, key=lambda t: -t.n_samples):   # LPT order
            best_k, best_w = None, float("inf")
            for k in executors:                                   # Eq. 4
                cand = w[k] + mdl[k].predict(task.n_samples)
                if cand < best_w:
                    best_k, best_w = k, cand
            assignment[best_k].append(task)
            w[best_k] = best_w
        return Schedule(assignment, max(w.values(), default=0.0),
                        time.perf_counter() - t0, est_time)


def makespan(assignment: Dict[int, List[ClientTask]],
             models: Dict[int, WorkloadModel]) -> float:
    """Predicted makespan of an assignment under given workload models."""
    out = 0.0
    for k, q in assignment.items():
        m = models.get(k, DEFAULT_MODEL)
        out = max(out, sum(m.predict(t.n_samples) for t in q))
    return out
