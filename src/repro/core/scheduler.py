"""Heterogeneity-aware task scheduling (paper §4.4, Algorithm 3).

Greedy LPT (longest-processing-time-first) assignment minimising the
estimated round makespan

    min_{M_1..M_K}  max_k  Σ_{m in M_k} T_{m,k}            (Eq. 3)

For each task (descending N_m) the executor chosen is

    k* = argmin_k ( w_k + N_m t_k^sample + b_k )            (Eq. 4)

— O(K · M_p) with a linear argmin per task (a heap does not apply directly
because T_{m,k} depends on k through both slope and offset).

Schedulers:
  parrot   — Algorithm 3 with the fitted workload model (warmup: uniform)
  uniform  — uniformly split |M^r| across executors (paper warmup / ablation)
  none     — arrival-order round-robin (emulates unscheduled FA-Dist)

Chunk granularity (event-driven engines, DESIGN.md §3): the semi-sync and
async engines execute queues in *chunks* of a few tasks and re-schedule at
chunk completion events — :func:`split_chunks` cuts a queue,
:func:`predict_span` prices a chunk under a fitted model, and
:func:`pick_steal_victim` finds the predicted-slowest queue for an idle
executor to steal from.  :meth:`Schedule.remap` re-homes queues that a
pre-computed (overlapped) schedule assigned to an executor that has since
died — without it those clients would silently never run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.workload import (DEFAULT_MODEL, WorkloadEstimator,
                                 WorkloadModel, fleet_average)

#: predicted comm seconds for a chunk's client ids (engines bind a
#: NetworkModel + the round's payload size into one of these; None = the
#: pre-network behaviour, comm is free)
ChunkCommCost = Callable[[Sequence[int]], float]


@dataclass(frozen=True)
class ClientTask:
    client: int
    n_samples: int


@dataclass
class Schedule:
    assignment: Dict[int, List[ClientTask]]      # executor -> tasks
    predicted_makespan: float
    schedule_time_s: float
    estimate_time_s: float

    def queue(self, executor: int) -> List[ClientTask]:
        return self.assignment.get(executor, [])

    @property
    def max_queue_len(self) -> int:
        return max((len(v) for v in self.assignment.values()), default=0)

    def remap(self, live: Sequence[int]) -> int:
        """Re-home queues assigned to executors not in ``live``.

        A schedule computed ahead of time (compute-comm overlap) can outlive
        its executor set: an executor that died after the schedule was built
        still owns a queue here, and the dispatch loop — which iterates live
        executors only — would silently drop those clients.  Orphaned tasks
        are appended round-robin onto the live queues (deterministic: orphan
        ids and live ids both in sorted order).  Returns the number of tasks
        re-homed.
        """
        live = sorted(live)
        orphans = sorted(k for k in self.assignment if k not in set(live))
        if not orphans or not live:
            return 0
        moved = 0
        for dead in orphans:
            for t in self.assignment.pop(dead):
                self.assignment.setdefault(live[moved % len(live)],
                                           []).append(t)
                moved += 1
        return moved


def _uniform(tasks: Sequence[ClientTask], executors: Sequence[int]) -> Dict[int, List[ClientTask]]:
    assignment: Dict[int, List[ClientTask]] = {k: [] for k in executors}
    for i, t in enumerate(tasks):
        assignment[executors[i % len(executors)]].append(t)
    return assignment


class ParrotScheduler:
    """Algorithm 3.  Stateless given the estimator — this is what makes
    elastic membership trivial: the executor set is an argument per round."""

    def __init__(self, estimator: WorkloadEstimator, warmup_rounds: int = 1,
                 policy: str = "parrot"):
        self.estimator = estimator
        self.warmup_rounds = warmup_rounds
        self.policy = policy

    def schedule(self, rnd: int, tasks: Sequence[ClientTask],
                 executors: Sequence[int],
                 comm_cost: Optional[Callable[[ClientTask], float]] = None
                 ) -> Schedule:
        """``comm_cost`` (network-aware runs) prices one task's round-trip
        comm — download the payload, upload the update on the client's link
        (Eq. 4's offset becomes payload- and bandwidth-aware).  The addend
        is executor-independent so it never flips a single argmin, but it
        accumulates into ``w[k]``: an executor whose queue holds slow-link
        clients looks fuller, and later tasks route around it — LPT then
        balances compute *plus* comm."""
        t0 = time.perf_counter()
        executors = list(executors)
        if self.policy == "none":
            assignment = _uniform(list(tasks), executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)
        if self.policy == "uniform" or rnd < self.warmup_rounds:
            assignment = _uniform(sorted(tasks, key=lambda t: -t.n_samples),
                                  executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)

        models = self.estimator.fit(rnd)
        est_time = self.estimator.fit_time_s
        t0 = time.perf_counter()
        assignment = {k: [] for k in executors}
        w = {k: 0.0 for k in executors}
        # executors with no history yet (fresh/elastic joiners) default to
        # the fleet average — a pessimistic default would starve them of
        # work forever (found by the hypothesis property suite)
        avg = fleet_average(models) or DEFAULT_MODEL
        mdl = {k: models.get(k, avg) for k in executors}
        for task in sorted(tasks, key=lambda t: -t.n_samples):   # LPT order
            t_comm = comm_cost(task) if comm_cost is not None else 0.0
            best_k, best_w = None, float("inf")
            for k in executors:                                   # Eq. 4
                cand = w[k] + mdl[k].predict(task.n_samples) + t_comm
                if cand < best_w:
                    best_k, best_w = k, cand
            assignment[best_k].append(task)
            w[best_k] = best_w
        return Schedule(assignment, max(w.values(), default=0.0),
                        time.perf_counter() - t0, est_time)


# ---------------------------------------------------------------------------
# chunk-granular helpers (event-driven engines)
# ---------------------------------------------------------------------------

def split_chunks(tasks: Sequence[ClientTask],
                 chunk_size: int) -> List[List[ClientTask]]:
    """Cut a queue into chunks of at most ``chunk_size`` tasks (queue order
    preserved — chunks are the engines' unit of dispatch, fold and steal)."""
    chunk_size = max(1, int(chunk_size))
    tasks = list(tasks)
    return [tasks[i:i + chunk_size] for i in range(0, len(tasks), chunk_size)]


def prefetch_ids(queue: Sequence[ClientTask], chunk_size: int) -> List[int]:
    """Client ids of a queue's NEXT dispatch chunk — the schedule-keyed
    hint the engines hand to ``ClientStateManager.prefetch`` right after
    dispatching the current chunk, so the following chunk's state shards
    stream into the RAM tier while this one computes."""
    return [t.client for t in queue[:max(1, int(chunk_size))]]


def predict_span(model: Optional[WorkloadModel],
                 tasks: Sequence[ClientTask],
                 comm: Optional[ChunkCommCost] = None) -> float:
    """Predicted virtual duration of one chunk run on an executor: Eq. 2 at
    the chunk's total sample count (chunk records fit b per chunk, so one
    offset per span — not one per task), plus the chunk's predicted comm
    time when a ``comm`` cost is bound (records stay compute-only, so the
    network term is added analytically, never fitted).  No model yet ->
    0.0, i.e. always optimistic during warmup — comm included, otherwise a
    warmup deadline would be pure comm and carry every chunk."""
    if model is None or not tasks:
        return 0.0
    out = model.predict(sum(t.n_samples for t in tasks))
    if comm is not None:
        out += comm([t.client for t in tasks])
    return out


def predict_remaining(model: Optional[WorkloadModel],
                      tasks: Sequence[ClientTask], chunk_size: int,
                      comm: Optional[ChunkCommCost] = None) -> float:
    """Predicted time to drain a queue chunk-by-chunk."""
    return sum(predict_span(model, c, comm)
               for c in split_chunks(tasks, chunk_size))


def pick_steal_victim(queues: Dict[int, List[ClientTask]],
                      avail: Dict[int, float],
                      models: Dict[int, WorkloadModel],
                      thief: int, chunk_size: int,
                      comm: Optional[ChunkCommCost] = None) -> Optional[int]:
    """The executor an idle ``thief`` should steal a chunk from: the one
    whose *predicted completion time* (availability + remaining queue under
    its fitted model, comm included when priced) is largest — the predicted
    straggler.  Ties break on the lower executor id (deterministic).
    Returns None when nobody has stealable work."""
    best_k, best_t = None, -float("inf")
    for k in sorted(queues):
        if k == thief or not queues[k]:
            continue
        done_at = avail.get(k, 0.0) + predict_remaining(
            models.get(k), queues[k], chunk_size, comm)
        if done_at > best_t:
            best_k, best_t = k, done_at
    return best_k


def makespan(assignment: Dict[int, List[ClientTask]],
             models: Dict[int, WorkloadModel]) -> float:
    """Predicted makespan of an assignment under given workload models."""
    out = 0.0
    for k, q in assignment.items():
        m = models.get(k, DEFAULT_MODEL)
        out = max(out, sum(m.predict(t.n_samples) for t in q))
    return out


# ---------------------------------------------------------------------------
# control plane (DESIGN.md §12): hindsight oracle + mid-run queue re-packing
# ---------------------------------------------------------------------------

#: one realized unit of folded work: (n_samples, time, executor, comm_s).
#: BSP collects one per task record, the DES engines one per folded chunk.
OracleJob = Tuple[float, float, int, float]


def oracle_makespan(jobs: Sequence[OracleJob],
                    executors: Sequence[int]) -> float:
    """Hindsight-optimal LPT makespan of the work that actually folded.

    From the realized jobs, derive each executor's *achieved* per-sample
    rate t_k = Σtime / Σn_samples (executors that ran nothing take the mean
    rate — they were available, the oracle may use them), then greedily
    re-pack the same jobs LPT onto the executor set: job ``j`` goes to
    ``argmin_k (w_k + n_j·t_k + comm_j)``.  Comm is executor-independent
    (a client's link doesn't change with placement) and priced serially
    into the lane, so an engine that overlaps comm with compute can beat
    this oracle — the gap can legitimately go negative.

    This is the denominator of the benchmarks' ``gap_to_oracle_pct``: what
    a scheduler with perfect knowledge of the realized spans would have
    achieved, with no estimation error, no deadline misses, and no idle
    lanes.  Deterministic: pure arithmetic over the jobs, no rng."""
    executors = sorted(set(executors))
    if not jobs or not executors:
        return 0.0
    tot_n = {k: 0.0 for k in executors}
    tot_t = {k: 0.0 for k in executors}
    for n, t, k, _c in jobs:
        if k in tot_n:
            tot_n[k] += float(n)
            tot_t[k] += float(t)
    rates = {k: tot_t[k] / tot_n[k] for k in executors if tot_n[k] > 0.0}
    if not rates:
        # every job ran on a since-dead executor: fleet rate from all jobs
        n_all = sum(float(n) for n, *_ in jobs)
        fleet = (sum(float(t) for _n, t, *_ in jobs) / n_all
                 if n_all > 0 else 0.0)
        rates = {}
    else:
        fleet = sum(rates.values()) / len(rates)
    t_k = {k: rates.get(k, fleet) for k in executors}
    w = {k: 0.0 for k in executors}
    order = sorted(range(len(jobs)),
                   key=lambda i: (-float(jobs[i][0]), i))   # LPT, stable
    for i in order:
        n, _t, _k0, comm = jobs[i]
        best_k, best_w = None, float("inf")
        for k in executors:
            cand = w[k] + float(n) * t_k[k] + float(comm)
            if cand < best_w:
                best_k, best_w = k, cand
        w[best_k] = best_w
    return max(w.values(), default=0.0)


def rebalance_queues(queues: Dict[int, List[ClientTask]],
                     horizons: Dict[int, float],
                     models: Dict[int, WorkloadModel],
                     comm_cost: Optional[Callable[[ClientTask], float]] = None
                     ) -> Tuple[Dict[int, List[ClientTask]], int]:
    """Re-pack every *undispatched* task across the executor set.

    The async engine's queues are built incrementally (one refill schedule
    per commit, each against the models of its moment), so under drifting
    device speeds the aggregate backlog goes stale.  This pools all queued
    tasks and re-runs the Eq. 4 LPT argmin over the CURRENT models, seeding
    each executor's load with its busy ``horizon`` (completion time of the
    in-flight chunk) — a busy-slow executor starts deep and sheds work to
    idle-fast ones.  Pollen-style placement at queue granularity:
    in-flight work never moves, so nothing double-executes.

    Deterministic: pool order is (executor, queue position), LPT ties break
    on that order.  Returns the new assignment (same keys as ``queues``)
    and the number of tasks whose executor changed."""
    keys = sorted(queues)
    pool: List[Tuple[int, ClientTask]] = [
        (k, t) for k in keys for t in queues[k]]
    if not pool:
        return {k: [] for k in keys}, 0
    avg = fleet_average(models) or DEFAULT_MODEL
    mdl = {k: models.get(k, avg) for k in keys}
    base = min(horizons.get(k, 0.0) for k in keys)
    w = {k: max(horizons.get(k, 0.0) - base, 0.0) for k in keys}
    assignment: Dict[int, List[ClientTask]] = {k: [] for k in keys}
    moved = 0
    order = sorted(range(len(pool)),
                   key=lambda i: (-pool[i][1].n_samples, i))
    for i in order:
        home, task = pool[i]
        t_comm = comm_cost(task) if comm_cost is not None else 0.0
        best_k, best_w = None, float("inf")
        for k in keys:
            cand = w[k] + mdl[k].predict(task.n_samples) + t_comm
            if cand < best_w:
                best_k, best_w = k, cand
        assignment[best_k].append(task)
        w[best_k] = best_w
        if best_k != home:
            moved += 1
    return assignment, moved
