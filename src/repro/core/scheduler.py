"""Heterogeneity-aware task scheduling (paper §4.4, Algorithm 3).

Greedy LPT (longest-processing-time-first) assignment minimising the
estimated round makespan

    min_{M_1..M_K}  max_k  Σ_{m in M_k} T_{m,k}            (Eq. 3)

For each task (descending N_m) the executor chosen is

    k* = argmin_k ( w_k + N_m t_k^sample + b_k )            (Eq. 4)

— O(K · M_p) with a linear argmin per task (a heap does not apply directly
because T_{m,k} depends on k through both slope and offset).

Schedulers:
  parrot   — Algorithm 3 with the fitted workload model (warmup: uniform)
  uniform  — uniformly split |M^r| across executors (paper warmup / ablation)
  none     — arrival-order round-robin (emulates unscheduled FA-Dist)

Chunk granularity (event-driven engines, DESIGN.md §3): the semi-sync and
async engines execute queues in *chunks* of a few tasks and re-schedule at
chunk completion events — :func:`split_chunks` cuts a queue,
:func:`predict_span` prices a chunk under a fitted model, and
:func:`pick_steal_victim` finds the predicted-slowest queue for an idle
executor to steal from.  :meth:`Schedule.remap` re-homes queues that a
pre-computed (overlapped) schedule assigned to an executor that has since
died — without it those clients would silently never run.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.workload import DEFAULT_MODEL, WorkloadEstimator, WorkloadModel

#: predicted comm seconds for a chunk's client ids (engines bind a
#: NetworkModel + the round's payload size into one of these; None = the
#: pre-network behaviour, comm is free)
ChunkCommCost = Callable[[Sequence[int]], float]


@dataclass(frozen=True)
class ClientTask:
    client: int
    n_samples: int


@dataclass
class Schedule:
    assignment: Dict[int, List[ClientTask]]      # executor -> tasks
    predicted_makespan: float
    schedule_time_s: float
    estimate_time_s: float

    def queue(self, executor: int) -> List[ClientTask]:
        return self.assignment.get(executor, [])

    @property
    def max_queue_len(self) -> int:
        return max((len(v) for v in self.assignment.values()), default=0)

    def remap(self, live: Sequence[int]) -> int:
        """Re-home queues assigned to executors not in ``live``.

        A schedule computed ahead of time (compute-comm overlap) can outlive
        its executor set: an executor that died after the schedule was built
        still owns a queue here, and the dispatch loop — which iterates live
        executors only — would silently drop those clients.  Orphaned tasks
        are appended round-robin onto the live queues (deterministic: orphan
        ids and live ids both in sorted order).  Returns the number of tasks
        re-homed.
        """
        live = sorted(live)
        orphans = sorted(k for k in self.assignment if k not in set(live))
        if not orphans or not live:
            return 0
        moved = 0
        for dead in orphans:
            for t in self.assignment.pop(dead):
                self.assignment.setdefault(live[moved % len(live)],
                                           []).append(t)
                moved += 1
        return moved


def _uniform(tasks: Sequence[ClientTask], executors: Sequence[int]) -> Dict[int, List[ClientTask]]:
    assignment: Dict[int, List[ClientTask]] = {k: [] for k in executors}
    for i, t in enumerate(tasks):
        assignment[executors[i % len(executors)]].append(t)
    return assignment


class ParrotScheduler:
    """Algorithm 3.  Stateless given the estimator — this is what makes
    elastic membership trivial: the executor set is an argument per round."""

    def __init__(self, estimator: WorkloadEstimator, warmup_rounds: int = 1,
                 policy: str = "parrot"):
        self.estimator = estimator
        self.warmup_rounds = warmup_rounds
        self.policy = policy

    def schedule(self, rnd: int, tasks: Sequence[ClientTask],
                 executors: Sequence[int],
                 comm_cost: Optional[Callable[[ClientTask], float]] = None
                 ) -> Schedule:
        """``comm_cost`` (network-aware runs) prices one task's round-trip
        comm — download the payload, upload the update on the client's link
        (Eq. 4's offset becomes payload- and bandwidth-aware).  The addend
        is executor-independent so it never flips a single argmin, but it
        accumulates into ``w[k]``: an executor whose queue holds slow-link
        clients looks fuller, and later tasks route around it — LPT then
        balances compute *plus* comm."""
        t0 = time.perf_counter()
        executors = list(executors)
        if self.policy == "none":
            assignment = _uniform(list(tasks), executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)
        if self.policy == "uniform" or rnd < self.warmup_rounds:
            assignment = _uniform(sorted(tasks, key=lambda t: -t.n_samples),
                                  executors)
            return Schedule(assignment, float("nan"),
                            time.perf_counter() - t0, 0.0)

        models = self.estimator.fit(rnd)
        est_time = self.estimator.fit_time_s
        t0 = time.perf_counter()
        assignment = {k: [] for k in executors}
        w = {k: 0.0 for k in executors}
        # executors with no history yet (fresh/elastic joiners) default to
        # the fleet average — a pessimistic default would starve them of
        # work forever (found by the hypothesis property suite)
        if models:
            avg = WorkloadModel(
                t_sample=sum(m.t_sample for m in models.values()) / len(models),
                b=sum(m.b for m in models.values()) / len(models))
        else:
            avg = DEFAULT_MODEL
        mdl = {k: models.get(k, avg) for k in executors}
        for task in sorted(tasks, key=lambda t: -t.n_samples):   # LPT order
            t_comm = comm_cost(task) if comm_cost is not None else 0.0
            best_k, best_w = None, float("inf")
            for k in executors:                                   # Eq. 4
                cand = w[k] + mdl[k].predict(task.n_samples) + t_comm
                if cand < best_w:
                    best_k, best_w = k, cand
            assignment[best_k].append(task)
            w[best_k] = best_w
        return Schedule(assignment, max(w.values(), default=0.0),
                        time.perf_counter() - t0, est_time)


# ---------------------------------------------------------------------------
# chunk-granular helpers (event-driven engines)
# ---------------------------------------------------------------------------

def split_chunks(tasks: Sequence[ClientTask],
                 chunk_size: int) -> List[List[ClientTask]]:
    """Cut a queue into chunks of at most ``chunk_size`` tasks (queue order
    preserved — chunks are the engines' unit of dispatch, fold and steal)."""
    chunk_size = max(1, int(chunk_size))
    tasks = list(tasks)
    return [tasks[i:i + chunk_size] for i in range(0, len(tasks), chunk_size)]


def prefetch_ids(queue: Sequence[ClientTask], chunk_size: int) -> List[int]:
    """Client ids of a queue's NEXT dispatch chunk — the schedule-keyed
    hint the engines hand to ``ClientStateManager.prefetch`` right after
    dispatching the current chunk, so the following chunk's state shards
    stream into the RAM tier while this one computes."""
    return [t.client for t in queue[:max(1, int(chunk_size))]]


def predict_span(model: Optional[WorkloadModel],
                 tasks: Sequence[ClientTask],
                 comm: Optional[ChunkCommCost] = None) -> float:
    """Predicted virtual duration of one chunk run on an executor: Eq. 2 at
    the chunk's total sample count (chunk records fit b per chunk, so one
    offset per span — not one per task), plus the chunk's predicted comm
    time when a ``comm`` cost is bound (records stay compute-only, so the
    network term is added analytically, never fitted).  No model yet ->
    0.0, i.e. always optimistic during warmup — comm included, otherwise a
    warmup deadline would be pure comm and carry every chunk."""
    if model is None or not tasks:
        return 0.0
    out = model.predict(sum(t.n_samples for t in tasks))
    if comm is not None:
        out += comm([t.client for t in tasks])
    return out


def predict_remaining(model: Optional[WorkloadModel],
                      tasks: Sequence[ClientTask], chunk_size: int,
                      comm: Optional[ChunkCommCost] = None) -> float:
    """Predicted time to drain a queue chunk-by-chunk."""
    return sum(predict_span(model, c, comm)
               for c in split_chunks(tasks, chunk_size))


def pick_steal_victim(queues: Dict[int, List[ClientTask]],
                      avail: Dict[int, float],
                      models: Dict[int, WorkloadModel],
                      thief: int, chunk_size: int,
                      comm: Optional[ChunkCommCost] = None) -> Optional[int]:
    """The executor an idle ``thief`` should steal a chunk from: the one
    whose *predicted completion time* (availability + remaining queue under
    its fitted model, comm included when priced) is largest — the predicted
    straggler.  Ties break on the lower executor id (deterministic).
    Returns None when nobody has stealable work."""
    best_k, best_t = None, -float("inf")
    for k in sorted(queues):
        if k == thief or not queues[k]:
            continue
        done_at = avail.get(k, 0.0) + predict_remaining(
            models.get(k), queues[k], chunk_size, comm)
        if done_at > best_t:
            best_k, best_t = k, done_at
    return best_k


def makespan(assignment: Dict[int, List[ClientTask]],
             models: Dict[int, WorkloadModel]) -> float:
    """Predicted makespan of an assignment under given workload models."""
    out = 0.0
    for k, q in assignment.items():
        m = models.get(k, DEFAULT_MODEL)
        out = max(out, sum(m.predict(t.n_samples) for t in q))
    return out
