"""Compiled client-training engine — the simulator's true hot path.

``FLAlgorithm.client_update`` (the eager reference path, kept and used by
``run_flat_reference``) dispatches one un-jitted op per pytree leaf per SGD
step per client; simulating 1000 clients is then dominated by Python/XLA
dispatch overhead rather than FLOPs.  ``ClientStepEngine`` instead rolls each
algorithm's pure ``(carry, batch, mask) -> carry`` step (see
``FLAlgorithm.local_step``) into ONE ``jax.jit``-compiled ``lax.scan`` over
all tau = local_epochs x n_batches local steps — one dispatch per client —
and additionally ``vmap``s that scan over a block of B same-shape clients —
one dispatch per block — producing stacked ``(B, ...)`` deltas that feed the
flat-buffer aggregator directly (``LocalAggregator.fold_block``), with no
per-client unflatten/refold round-trip through ``ClientResult``.

Shape discipline (bounded compile count): per-client batch counts and block
sizes are padded up to the next power of two — batches with repeats of the
client's first batch plus a 0/1 step mask, blocks with replicas of the first
client whose outputs are sliced off.  A masked step multiplies the update by
zero, so padding is *exact*; jit then caches one executable per (algorithm,
payload shapes, batch bucket[, block bucket]) instead of one per raw
(n_batches, B) pair.  On accelerator backends the stacked-batch and mask
arguments are donated (they are rebuilt per call) and the scan carry is
donated by XLA internally; on CPU donation is skipped (it would only warn).

Clients whose batches are ragged (shapes differ within one client) cannot be
scanned; the engine transparently falls back to the eager reference path for
exactly those clients.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import ClientResult
from repro.core.algorithms import ClientData, FLAlgorithm

Pytree = Any


def _bucket(n: int) -> int:
    """Next power of two >= n (n >= 1) — the scan-length / block bucket."""
    return 1 << max(n - 1, 0).bit_length()


# Process-wide XLA compile counter.  Executors snapshot it around a timed
# block: if it advanced, the block's wall time paid a one-off compile
# (engine scan, flatten_batch, fold — any jit anywhere in the region) and
# the measurement is re-taken from the warm caches so virtual time reflects
# steady-state throughput.
_compile_events = 0


def _on_compile_event(event: str, duration: float, **kw) -> None:
    global _compile_events
    if event.startswith("/jax/core/compile"):
        _compile_events += 1


jax.monitoring.register_event_duration_secs_listener(_on_compile_event)


def compile_events() -> int:
    """Monotonic count of XLA compile events in this process."""
    return _compile_events


def batch_signature(data: ClientData) -> Optional[Tuple]:
    """Hashable grouping key for cross-client blocking: clients with equal
    signatures stack into one vmapped scan.  The batch count enters through
    its power-of-two bucket (mask padding makes unequal counts compatible).
    Returns None when the client's batches are ragged (eager fallback)."""
    bs = data.batches
    if not bs:
        return None
    treedef = jax.tree.structure(bs[0])
    shapes = tuple((tuple(np.shape(l)), str(getattr(l, "dtype", "?")))
                   for l in jax.tree.leaves(bs[0]))
    for b in bs[1:]:
        if jax.tree.structure(b) != treedef:
            return None
        if tuple((tuple(np.shape(l)), str(getattr(l, "dtype", "?")))
                 for l in jax.tree.leaves(b)) != shapes:
            return None
    return (_bucket(len(bs)), treedef, shapes)


def stack_batches(data: ClientData, *, assume_uniform: bool = False
                  ) -> Optional[Tuple[Any, np.ndarray]]:
    """One leading-axis batch pytree + 0/1 step mask for a client, padded to
    the power-of-two bucket with repeats of the first batch (finite data, so
    the masked zero-update is exact).  None when the batches are ragged.

    ``assume_uniform=True`` skips the ragged check when the caller already
    grouped clients by :func:`batch_signature` (the executor's block
    planner) — the signature walk is O(n_batches x n_leaves) per client and
    would otherwise run twice per round on the hot path."""
    if not assume_uniform and batch_signature(data) is None:
        return None
    bs = data.batches
    n, n_pad = len(bs), _bucket(len(bs))
    padded = list(bs) + [bs[0]] * (n_pad - n)
    stacked = jax.tree.map(lambda *xs: np.stack([np.asarray(x) for x in xs]),
                           *padded)
    mask = np.zeros((n_pad,), np.float32)
    mask[:n] = 1.0
    return stacked, mask


class PlacedCache:
    """Single-slot identity-keyed memo of 'host object(s) -> placed copy'.

    Payload placement is memoized in three spots (executor payload pin,
    engine commit, gang replication) — one helper keeps the invalidation
    semantics (same object identity ⇒ same placed copy) in one place."""

    __slots__ = ("_key", "_val")

    def __init__(self):
        self._key = None
        self._val = None

    def get(self, key_objs: Tuple, place: Callable[[], Any]) -> Any:
        if self._key is None or len(self._key) != len(key_objs) or \
                any(a is not b for a, b in zip(self._key, key_objs)):
            self._val = place()
            self._key = tuple(key_objs)
        return self._val

    def clear(self) -> None:
        self._key = self._val = None


class ClientStepEngine:
    """One compiled scan (and its vmapped block form) per (algorithm,
    device).

    jax.jit owns the executable cache: one entry per distinct (payload
    shapes, state shapes, batch bucket) for the single-client scan, plus one
    per block bucket for the vmapped form — cached across rounds and
    clients.  Executors sharing an algorithm instance *and* a device share
    the engine (and therefore the cache) through :func:`engine_for`; a
    device-pinned engine commits its inputs to that device, so its
    executables compile for — and its outputs stay resident on — exactly
    that device (an uncommitted input would silently drag the computation
    onto the process default device, serializing every executor on it).

    Donation: the vmapped block form donates its freshly-stacked (B, ...)
    batch/mask arrays on accelerator backends (rebuilt per call).  The
    single-client form does NOT donate batches — they may come from the
    executor's device-resident stacked-batch cache and must survive the
    call.
    """

    def __init__(self, algorithm: FLAlgorithm, device=None):
        self.algorithm = algorithm
        self.device = device
        self.n_dispatches = 0       # compiled calls issued (bench metric)
        donate = jax.default_backend() in ("tpu", "gpu")
        kw = dict(donate_argnums=(2, 3)) if donate else {}
        self._run_jit = jax.jit(self._run_one)
        self._run_block_jit = jax.jit(
            jax.vmap(self._run_one, in_axes=(None, 0, 0, 0)), **kw)
        # fused on-device block stack for cached (device-resident) preps:
        # one compiled dispatch per (B, shapes) instead of one eager
        # jnp.stack per pytree leaf per block (eager ops re-trace, and at
        # dispatch-bound block sizes that per-block churn dominates)
        self._stack_jit = jax.jit(
            lambda bats, masks: (jax.tree.map(lambda *xs: jnp.stack(xs),
                                              *bats), jnp.stack(masks)))
        self._payload_cache = PlacedCache()
        self._gang_payload_cache = PlacedCache()

    def _commit(self, tree: Any) -> Any:
        """Commit a pytree to the engine's device (no-op copies for leaves
        already resident there; identity when the engine is unpinned)."""
        if self.device is None:
            return tree
        return jax.device_put(tree, self.device)

    def _commit_payload(self, payload: Dict) -> Dict:
        """Commit the broadcast payload once per payload object: callers
        re-use one payload across every block of a round (and the async
        engine across rounds), so the per-leaf device_put walk — pure host
        overhead at dispatch-bound block sizes — must not repeat per call."""
        if self.device is None:
            return payload
        return self._payload_cache.get(
            (payload,), lambda: jax.device_put(payload, self.device))

    # ------------------------------------------------------------------
    def _run_one(self, payload: Dict, state: Optional[Pytree], batches: Any,
                 mask: jnp.ndarray) -> Tuple[Dict[str, Any], Optional[Pytree]]:
        """The whole local update as one traced program: init carry, scan
        tau steps, finalize.  Shapes only — jit/vmap do the rest."""
        algo = self.algorithm
        carry = algo.init_carry(payload, state)

        def step(c, xs):
            b, m = xs
            return algo.local_step(c, b, m), None

        def epoch(c, _):
            c, _ = jax.lax.scan(step, c, (batches, mask))
            return c, None

        # length=0 is a valid no-op scan, matching the eager path's zero
        # local steps for local_epochs=0
        carry, _ = jax.lax.scan(epoch, carry, None, length=algo.local_epochs)
        return algo.finalize(carry, payload, state, batches, mask)

    # ------------------------------------------------------------------
    def run_client(self, payload: Dict, data: ClientData,
                   state: Optional[Pytree] = None, *,
                   assume_uniform: bool = False,
                   prep: Optional[Tuple[Any, Any]] = None
                   ) -> Tuple[ClientResult, Optional[Pytree]]:
        """Compiled drop-in for ``algorithm.client_update``: one dispatch for
        the whole tau-step local update (eager fallback on ragged batches;
        ``assume_uniform=True`` skips the ragged walk when the caller
        already checked the signature).  ``prep`` supplies a pre-stacked
        (batches, mask) pair — typically device-resident from the
        executor's stacked-batch cache — skipping the host stack."""
        if prep is None:
            prep = stack_batches(data, assume_uniform=assume_uniform)
        if prep is None:
            return self.algorithm.client_update(payload, data, state)
        batches, mask = prep
        self.n_dispatches += 1
        # state may be uncommitted (it then follows the committed payload /
        # batches onto the device) — only payload and host-built batches
        # need explicit placement
        on_device = hasattr(jax.tree.leaves(batches)[0], "sharding") \
            if jax.tree.leaves(batches) else False
        if not on_device:
            batches, mask = self._commit(batches), self._commit(
                jnp.asarray(mask))
        out_payload, new_state = self._run_jit(
            self._commit_payload(payload), state, batches,
            jnp.asarray(mask))
        return (ClientResult(out_payload, self.algorithm.ops(),
                             weight=float(data.n_samples)), new_state)

    def run_block(self, payload: Dict, datas: Sequence[ClientData],
                  states: Optional[Sequence[Pytree]] = None,
                  preps: Optional[Sequence[Tuple[Any, Any]]] = None
                  ) -> Tuple[Dict[str, Any], Optional[List[Pytree]]]:
        """One vmapped compiled scan over a block of B same-signature
        clients (the caller groups by :func:`batch_signature`).  Returns the
        stacked result payload (leading B axis, ready for
        ``LocalAggregator.fold_block``) and the per-client new states.

        The block is padded to the power-of-two bucket with replicas of the
        first client; padded rows are sliced off before returning, so the
        caller never sees them.  ``preps`` supplies per-client pre-stacked
        (batches, mask) pairs (the executor's device-resident cache); the
        block stack then runs on the owning device (``jnp.stack``) instead
        of re-staging O(block data) through the host every round."""
        B = len(datas)
        B_pad = _bucket(B)
        try:
            if preps is None:
                preps = [stack_batches(d, assume_uniform=True)
                         for d in datas]
            preps = list(preps) + [preps[0]] * (B_pad - B)
            first = jax.tree.leaves(preps[0][0])
            on_device = bool(first) and hasattr(first[0], "sharding")
            if on_device:
                batches, mask = self._stack_jit([p[0] for p in preps],
                                                [p[1] for p in preps])
            else:
                batches = jax.tree.map(lambda *xs: np.stack(xs),
                                       *[p[0] for p in preps])
                mask = np.stack([p[1] for p in preps])
        except ValueError as e:
            raise ValueError("ragged or mixed-shape client batches cannot "
                             "be blocked; group by batch_signature() first"
                             ) from e
        sstates = None
        if states is not None:
            padded = list(states) + [states[0]] * (B_pad - B)
            sstates = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
        if not on_device:
            batches, mask = self._commit(batches), self._commit(
                jnp.asarray(mask))
        self.n_dispatches += 1
        out_payload, new_states = self._run_block_jit(
            self._commit_payload(payload), sstates, batches,
            jnp.asarray(mask))
        if B_pad > B:
            out_payload = jax.tree.map(lambda x: x[:B], out_payload)
        if states is None:
            return out_payload, None
        return out_payload, [jax.tree.map(lambda x: x[i], new_states)
                             for i in range(B)]

    # ------------------------------------------------------------------
    def run_blocks_sharded(self, payload: Dict, preps, states, mesh
                           ) -> List[Tuple[Dict[str, Any], Any]]:
        """One SPMD dispatch running K same-bucket client blocks, one per
        mesh device (DESIGN.md §8 gang dispatch).

        ``preps``: K pairs of (stacked batches pytree (B, ...), mask
        (B, n)), the k-th committed to the k-th mesh device, all with equal
        B and shapes.  ``states``: K stacked state pytrees (or None).  The
        per-device pieces are assembled zero-copy into global ``(K·B, ...)``
        arrays sharded ``P("data")`` over the mesh, and the SAME vmapped
        scan executable runs them — XLA partitions the vmap axis, so the K
        blocks execute *concurrently*, one per device, in a single
        execution (separate single-device dispatches serialize in the CPU
        PJRT client; SPMD executions fan out per-device threads — this is
        where the CPU device-count speedup physically comes from).

        Returns K ``(stacked result payload, stacked new states)`` pairs,
        each left resident on its own device."""
        devices = list(mesh.devices.flat)
        K = len(devices)
        assert len(preps) == K
        row = NamedSharding(mesh, P("data"))

        def assemble(pieces):
            pieces = [jnp.asarray(p) for p in pieces]
            shape = (K * pieces[0].shape[0],) + pieces[0].shape[1:]
            return jax.make_array_from_single_device_arrays(
                shape, row, pieces)

        batches = jax.tree.map(lambda *xs: assemble(xs),
                               *[p[0] for p in preps])
        mask = assemble([p[1] for p in preps])
        sstates = None
        if states is not None:
            sstates = jax.tree.map(lambda *xs: assemble(xs), *states)
        repl = self._gang_payload_cache.get(
            (payload, mesh),
            lambda: jax.device_put(payload, NamedSharding(mesh, P())))
        self.n_dispatches += 1
        out_payload, new_states = self._run_block_jit(repl, sstates,
                                                      batches, mask)

        def split_tree(tree):
            """tree of (K·B, ...) sharded arrays -> K trees of (B, ...)
            single-device arrays, each still resident on its device
            (addressable shards — no gather, no copy)."""
            leaves, treedef = jax.tree.flatten(tree)
            parts = []
            for leaf in leaves:
                by_dev = {s.device.id: s.data
                          for s in leaf.addressable_shards}
                parts.append([by_dev[d.id] for d in devices])
            return [jax.tree.unflatten(treedef, [p[k] for p in parts])
                    for k in range(K)]

        payloads = split_tree(out_payload)
        state_parts = (split_tree(new_states) if new_states is not None
                       else [None] * K)
        return list(zip(payloads, state_parts))

    # ------------------------------------------------------------------
    def compile_count(self) -> int:
        """Executables compiled so far (scan + vmapped scan caches)."""
        total = 0
        for fn in (self._run_jit, self._run_block_jit):
            size = getattr(fn, "_cache_size", None)
            if callable(size):
                total += size()
        return total


def engine_for(algorithm: FLAlgorithm,
               device=None) -> ClientStepEngine:
    """The algorithm instance's engine for ``device`` (executors sharing
    the algorithm *and* the device share one compile cache).

    The cache is keyed on the device id: a multi-device run gets one engine
    — one set of executables — per device, so executors can never thrash a
    shared cache or be handed an executable compiled (and resident) on
    another executor's device."""
    cache = getattr(algorithm, "_step_engines", None)
    if cache is None:
        cache = algorithm._step_engines = {}
    key = getattr(device, "id", None) if device is not None else None
    eng = cache.get(key)
    if eng is None:
        eng = cache[key] = ClientStepEngine(algorithm, device=device)
    return eng
