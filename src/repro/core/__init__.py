"""Parrot core — the paper's primary contribution:

  scheduler.py / workload.py — heterogeneity-aware task scheduling (Alg. 3)
  aggregation.py             — hierarchical local→global aggregation (§4.2)
  flat.py                    — flatten-once layout for batched folds
  state_manager.py           — client state manager for stateful FL (§3.4)
  algorithms.py              — 6 FL algorithms over generic pytrees (§5.1)
  client_step.py             — compiled client-training engine (jit-scan
                               local SGD, vmapped client blocks)
  executor.py / round.py     — sequential executors + Parrot server (Alg. 2)
  placement.py               — executor→device pinning + sharded global fold
  engine.py / clock.py       — event-driven round engines (BSP / semi-sync /
                               async bounded-staleness) on a shared
                               virtual-time event queue
  network.py                 — trace-driven network & availability
                               simulation (comm-aware virtual clock, §9)
  compression.py             — delta compression (top-k EF / int8)
  faults.py                  — fault injection & recovery (seeded chaos
                               plans, chunk timeouts/retry, §10)
  control.py                 — adaptive control plane (self-tuning λ /
                               deadline controllers, comm overlap, gang
                               waves, oracle-gap tracking, §12)
  telemetry.py               — virtual-time telemetry (span tracer with
                               Chrome-trace/Perfetto export, typed metrics
                               registry, utilization accounting, §13)
"""
from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate)
from repro.core.control import (AsyncLambdaController, ControlPlane,
                                DeadlineController)
from repro.core.flat import FlatLayout
from repro.core.algorithms import (ALGORITHMS, ClientData, FLAlgorithm,
                                   make_algorithm)
from repro.core.client_step import ClientStepEngine, engine_for
from repro.core.clock import TickTimer, VirtualClock
from repro.core.engine import (AsyncEngine, BSPEngine, RoundEngine,
                               SemiSyncEngine, make_engine)
from repro.core.executor import SequentialExecutor
from repro.core.faults import (FaultEvent, FaultInjector, FaultPlan,
                               RetryPolicy)
from repro.core.network import (ClientAvailability, CommEvent, LinkProfile,
                                NetworkModel)
from repro.core.placement import DevicePlacement
from repro.core.round import ParrotServer, RoundMetrics, run_flat_reference
from repro.core.scheduler import (ClientTask, ParrotScheduler, Schedule,
                                  oracle_makespan, rebalance_queues)
from repro.core.state_manager import ClientStateManager, owner_host
from repro.core.telemetry import (MetricsRegistry, Telemetry, Tracer,
                                  validate_trace)
from repro.core.workload import (RunRecord, WorkloadEstimator,
                                 WorkloadModel, fleet_average)

__all__ = [
    "ALGORITHMS", "AsyncEngine", "AsyncLambdaController", "BSPEngine",
    "ClientAvailability",
    "ClientData", "ClientResult",
    "ClientStateManager", "ClientStepEngine", "ClientTask", "CommEvent",
    "ControlPlane", "DeadlineController", "DevicePlacement",
    "FLAlgorithm", "FaultEvent", "FaultInjector", "FaultPlan",
    "FlatLayout", "LinkProfile", "LocalAggregator", "MetricsRegistry",
    "NetworkModel", "Op",
    "ParrotScheduler",
    "ParrotServer", "RetryPolicy",
    "RoundEngine", "RoundMetrics", "RunRecord", "Schedule",
    "SemiSyncEngine", "SequentialExecutor", "Telemetry", "TickTimer",
    "Tracer", "VirtualClock",
    "WorkloadEstimator", "WorkloadModel",
    "engine_for", "flat_aggregate", "fleet_average", "global_aggregate",
    "make_algorithm",
    "make_engine", "oracle_makespan", "owner_host", "rebalance_queues",
    "run_flat_reference", "validate_trace",
]
