"""Parrot core — the paper's primary contribution:

  scheduler.py / workload.py — heterogeneity-aware task scheduling (Alg. 3)
  aggregation.py             — hierarchical local→global aggregation (§4.2)
  flat.py                    — flatten-once layout for batched folds
  state_manager.py           — client state manager for stateful FL (§3.4)
  algorithms.py              — 6 FL algorithms over generic pytrees (§5.1)
  client_step.py             — compiled client-training engine (jit-scan
                               local SGD, vmapped client blocks)
  executor.py / round.py     — sequential executors + round engine (Alg. 2)
  compression.py             — delta compression (top-k EF / int8)
"""
from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate)
from repro.core.flat import FlatLayout
from repro.core.algorithms import (ALGORITHMS, ClientData, FLAlgorithm,
                                   make_algorithm)
from repro.core.client_step import ClientStepEngine, engine_for
from repro.core.executor import SequentialExecutor
from repro.core.round import ParrotServer, RoundMetrics, run_flat_reference
from repro.core.scheduler import ClientTask, ParrotScheduler, Schedule
from repro.core.state_manager import ClientStateManager, owner_host
from repro.core.workload import RunRecord, WorkloadEstimator, WorkloadModel

__all__ = [
    "ALGORITHMS", "ClientData", "ClientResult", "ClientStateManager",
    "ClientStepEngine", "ClientTask", "FLAlgorithm", "FlatLayout",
    "LocalAggregator", "Op", "ParrotScheduler",
    "ParrotServer", "RoundMetrics", "RunRecord", "Schedule",
    "SequentialExecutor", "WorkloadEstimator", "WorkloadModel",
    "engine_for", "flat_aggregate", "global_aggregate", "make_algorithm",
    "owner_host", "run_flat_reference",
]
