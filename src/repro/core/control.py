"""Adaptive control plane: self-tuning scheduler knobs (DESIGN.md §12).

The engines run on fixed knobs — a constant async ``staleness_lambda``, a
constant semi-sync ``deadline_frac``, comm serialized into every span — and
the scheduler only reacts to heterogeneity through the fitted workload
models.  A :class:`ControlPlane` attached to the server
(``ParrotServer(control=...)``) closes the loop:

* :class:`AsyncLambdaController` — instead of a fixed λ, target an
  *effective trust* for stale folds: pick λ so the bounded-staleness weight
  γ = 1/(1+λ·s̄) equals ``target_gamma`` at the EWMA of the observed
  per-window mean staleness.  Updated once per server commit.
* :class:`DeadlineController` — tune semi-sync ``deadline_frac`` from the
  observed landed/selected ratio: folding more of the selection than the
  target quantile means the deadline is looser than it needs to be
  (multiplicative tighten); folding less means carry churn (loosen).  The
  deadline converges to the target-quantile of chunk landings, cutting the
  straggler tail without starving the fold.
* Boolean levers consumed by the engines: ``window_fit`` (selection skips
  clients whose availability window can't fit their predicted span + comm),
  ``overlap_comm`` (payload downloads overlap earlier compute instead of
  serializing into each span), ``gang_waves`` (semi-sync/async dispatch
  aligned chunk waves as one SPMD execution via ``run_queues_ganged``) and
  ``rebalance`` (async re-packs undispatched queues at each commit via
  ``scheduler.rebalance_queues``; semi-sync steals the predicted
  straggler's queue tail into a drained lane via
  ``scheduler.pick_steal_victim`` — stolen chunks still face the deadline
  check, and the round reports ``extra["rebalanced_tasks"]``).

Any non-None control plane also turns on oracle tracking: every engine
collects its realized (n_samples, time, executor, comm) jobs and reports
``extra["oracle_makespan"]`` — the hindsight-optimal LPT schedule of the
work that actually folded (``scheduler.oracle_makespan``).  The benchmarks
derive ``gap_to_oracle_pct`` from it, the PR's acceptance metric.

``ControlPlane.observer()`` is the measurement-only mode: oracle tracking
on, every controller and lever off — behaviour (params AND makespan
history) is bit-identical to ``control=None``, pinned by tests.  Controller
state is plain data and rides the checkpoint blob (``checkpoint/manager.py``
key ``"control"``) so a resumed run replays the same λ / deadline
trajectory bit-exactly.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional


def _ewma(prev: Optional[float], x: float, alpha: float) -> float:
    return x if prev is None else (1.0 - alpha) * prev + alpha * x


class AsyncLambdaController:
    """γ-targeting λ: λ = (1/target_gamma − 1) / EWMA(mean staleness).

    ``current(fallback)`` returns the λ the engine should fold with (the
    engine's static ``staleness_lambda`` until the first update);
    ``update(mean_staleness)`` runs once per server commit with the closed
    window's mean observed staleness.  Clipped to ``[lam_min, lam_max]``
    (at s̄ → 0 any λ gives γ → 1, so the clip is inert where it binds).
    """

    def __init__(self, target_gamma: float = 0.6, alpha: float = 0.3,
                 lam_min: float = 0.05, lam_max: float = 4.0):
        if not (0.0 < target_gamma < 1.0):
            raise ValueError("target_gamma must be in (0, 1)")
        self.target_gamma = float(target_gamma)
        self.alpha = float(alpha)
        self.lam_min = float(lam_min)
        self.lam_max = float(lam_max)
        self.value: Optional[float] = None
        self._ewma: Optional[float] = None

    def current(self, fallback: float) -> float:
        return fallback if self.value is None else self.value

    def update(self, mean_staleness: float) -> float:
        self._ewma = _ewma(self._ewma, float(mean_staleness), self.alpha)
        s = max(self._ewma, 1e-6)
        lam = (1.0 / self.target_gamma - 1.0) / s
        self.value = min(max(lam, self.lam_min), self.lam_max)
        return self.value

    def state_dict(self) -> Dict[str, Any]:
        return {"value": self.value, "ewma": self._ewma}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self.value = state.get("value")
        self._ewma = state.get("ewma")


class DeadlineController:
    """Semi-sync ``deadline_frac`` from the observed landed/selected ratio.

    ``target_ratio=None`` targets ``1/over_select`` (fold exactly the
    nominal cohort's weight, let the over-selected slack absorb the tail) —
    the engine passes that default per update.  The frac moves
    multiplicatively, ``frac ·= exp(−gain · (EWMA(ratio) − target))``,
    clipped to ``[frac_min, frac_max]``: folding above target tightens the
    deadline, folding below loosens it.
    """

    def __init__(self, target_ratio: Optional[float] = None,
                 gain: float = 0.6, alpha: float = 0.4,
                 frac_min: float = 0.3, frac_max: float = 1.0):
        self.target_ratio = None if target_ratio is None \
            else float(target_ratio)
        self.gain = float(gain)
        self.alpha = float(alpha)
        self.frac_min = float(frac_min)
        self.frac_max = float(frac_max)
        self.value: Optional[float] = None
        self._ewma: Optional[float] = None

    def current(self, fallback: float) -> float:
        return fallback if self.value is None else self.value

    def update(self, landed: int, selected: int, fallback_frac: float,
               default_target: float) -> float:
        if selected <= 0:
            return self.current(fallback_frac)
        target = self.target_ratio if self.target_ratio is not None \
            else float(default_target)
        self._ewma = _ewma(self._ewma, landed / selected, self.alpha)
        if self.value is None:
            self.value = float(fallback_frac)
        err = self._ewma - target
        self.value = min(max(self.value * math.exp(-self.gain * err),
                             self.frac_min), self.frac_max)
        return self.value

    def state_dict(self) -> Dict[str, Any]:
        return {"value": self.value, "ewma": self._ewma}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self.value = state.get("value")
        self._ewma = state.get("ewma")


class ControlPlane:
    """The knob bundle the engines consult (``getattr(srv, "control")``).

    Everything defaults off; a bare ``ControlPlane()`` (== ``observer()``)
    only enables oracle tracking and is behaviour-identical to
    ``control=None``.  ``adaptive()`` turns the full control plane on.
    """

    def __init__(self, *,
                 async_lambda: Optional[AsyncLambdaController] = None,
                 deadline: Optional[DeadlineController] = None,
                 window_fit: bool = False,
                 overlap_comm: bool = False,
                 gang_waves: bool = False,
                 rebalance: bool = False):
        self.async_lambda = async_lambda
        self.deadline = deadline
        self.window_fit = bool(window_fit)
        self.overlap_comm = bool(overlap_comm)
        self.gang_waves = bool(gang_waves)
        self.rebalance = bool(rebalance)
        # wired by ParrotServer when telemetry is attached (DESIGN.md §13):
        # controller moves land on the "control" lane via note(); pure
        # recording, never consulted for behaviour and not checkpointed
        # here (the Telemetry bundle owns its own state)
        self.telemetry: Optional[Any] = None

    def note(self, name: str, value: float, t: float) -> None:
        """Record one controller move (an instant on the ``control`` lane
        plus a ``control/<name>`` gauge).  No-op without telemetry."""
        if self.telemetry is not None:
            self.telemetry.control_event(name, value, t)

    @classmethod
    def observer(cls) -> "ControlPlane":
        """Oracle tracking only — bit-identical behaviour to control=None
        (the benchmarks' baseline cells, so both sides report a gap)."""
        return cls()

    @classmethod
    def adaptive(cls, *, target_gamma: float = 0.6,
                 target_ratio: Optional[float] = None,
                 window_fit: bool = True, overlap_comm: bool = True,
                 gang_waves: bool = True,
                 rebalance: bool = True) -> "ControlPlane":
        """Every controller and lever on (the benchmarks' adaptive cells)."""
        return cls(async_lambda=AsyncLambdaController(target_gamma),
                   deadline=DeadlineController(target_ratio),
                   window_fit=window_fit, overlap_comm=overlap_comm,
                   gang_waves=gang_waves, rebalance=rebalance)

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {
            "async_lambda": (self.async_lambda.state_dict()
                             if self.async_lambda is not None else None),
            "deadline": (self.deadline.state_dict()
                         if self.deadline is not None else None),
        }

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        if self.async_lambda is not None:
            self.async_lambda.load_state_dict(state.get("async_lambda"))
        if self.deadline is not None:
            self.deadline.load_state_dict(state.get("deadline"))
