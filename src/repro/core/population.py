"""Client populations — the streamed axis behind ``data_by_client``.

The paper stops at ~1000 clients; the north star is millions.  At that
scale the server cannot hold every client's batches (O(M) dataset RSS),
re-sort the full id set per selection (O(M log M) per round), or keep one
pickle file per client.  This module makes the population a *lazy* axis:

  ``ClientPopulation``   read-only ``Mapping[int, ClientData]`` plus a
                         compact registry view (sorted int64 id array and
                         per-client sample counts) that never requires
                         materialising batches.
  ``EagerPopulation``    wraps the classic dict; the sorted-id registry is
                         built once and reused across rounds (rebuilt only
                         when membership changes), fixing the per-round
                         O(M log M) re-sort for eager populations too.
  ``LazyPopulation``     registry arrays + an ``id -> ClientData`` factory
                         behind a bounded LRU byte cache, so dataset memory
                         is O(cohort), not O(population).

Selection (``ClientPopulation.sample``) is O(cohort): it draws positional
indices with ``rng.choice(pool_len, size, replace=False)`` — numpy's
Generator consumes the bit stream identically for ``choice(pool, size)``
and ``choice(len(pool), size)`` — and rank-adjusts the drawn indices past
excluded positions instead of materialising ``sorted(ids) - exclude``.
The resulting cohorts are rng-identical to the legacy
``rng.choice(sorted_pool, ...)`` path (pinned by tests/test_population.py),
so every engine bit-exactness pin holds unchanged.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from collections.abc import Mapping
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.algorithms import ClientData

__all__ = ["ClientPopulation", "EagerPopulation", "LazyPopulation",
           "as_population"]


def _data_nbytes(data: ClientData) -> int:
    """Approximate host bytes held by one client's batches (for the fetch
    cache's byte budget)."""
    total = 0
    for batch in data.batches:
        leaves = batch if isinstance(batch, (tuple, list)) else (batch,)
        for a in leaves:
            total += int(getattr(a, "nbytes", 64))
    return max(total, 1)


class ClientPopulation(Mapping):
    """Read-only ``Mapping[int, ClientData]`` with a registry fast path.

    Subclasses provide ``ids_array()`` (sorted int64 ids — the compact
    registry), ``n_samples(c)`` (the scheduling signal, no batch
    materialisation), and ``__getitem__`` (batches, possibly synthesized on
    demand).  ``keys/values/items/get`` come from the Mapping mixins, so a
    population drops in anywhere a ``data_by_client`` dict was read.
    """

    def ids_array(self) -> np.ndarray:
        raise NotImplementedError

    def n_samples(self, client: int) -> int:
        raise NotImplementedError

    def __len__(self) -> int:
        return int(self.ids_array().size)

    def __iter__(self) -> Iterator[int]:
        return (int(c) for c in self.ids_array())

    def __contains__(self, client: object) -> bool:
        try:
            c = int(client)  # type: ignore[arg-type]
        except (TypeError, ValueError):
            return False
        ids = self.ids_array()
        i = int(np.searchsorted(ids, c))
        return i < ids.size and int(ids[i]) == c

    # ------------------------------------------------------------------
    def sample(self, rng: np.random.Generator, k: int,
               exclude: Optional[Sequence[int]] = None,
               filters: Sequence[Callable[[int], bool]] = ()) -> List[int]:
        """Draw ``min(k, pool)`` distinct client ids, rng-identical to the
        legacy ``rng.choice(sorted(ids) - exclude, size, replace=False)``.

        Without filters the pool is never materialised: positional indices
        are drawn against the virtual pool length and rank-adjusted past the
        excluded ids' positions in the sorted registry — O(k log k +
        |exclude| log M) per call.  With availability/fault filters each
        candidate is tested individually (in sorted order, exactly like the
        legacy list comprehensions) and survivors pack into an int64 array,
        so the filtered pool costs one machine word per available client,
        not a boxed-int Python list.
        """
        ids = self.ids_array()
        if filters:
            excl = {int(c) for c in exclude} if exclude else None
            pool = np.fromiter(
                (c for c in ids
                 if (excl is None or int(c) not in excl)
                 and all(f(int(c)) for f in filters)),
                dtype=np.int64)
            size = min(int(k), int(pool.size))
            if size <= 0:
                return []
            idx = rng.choice(pool.size, size=size, replace=False)
            return [int(c) for c in pool[np.asarray(idx, dtype=np.int64)]]

        P = np.empty(0, dtype=np.int64)
        if exclude:
            ex = np.unique(np.asarray([int(c) for c in exclude],
                                      dtype=np.int64))
            pos = np.searchsorted(ids, ex)
            ok = pos < ids.size
            ok[ok] = ids[pos[ok]] == ex[ok]
            P = pos[ok].astype(np.int64)
        pool_len = int(ids.size - P.size)
        size = min(int(k), pool_len)
        if size <= 0:
            return []
        idx = np.asarray(rng.choice(pool_len, size=size, replace=False),
                         dtype=np.int64)
        if P.size:
            # j-th element of (ids minus excluded) sits at original position
            # j + |{p in P : p - rank(p) <= j}| — a searchsorted over the
            # rank-shifted excluded positions recovers it without building
            # the pool.
            idx = idx + np.searchsorted(P - np.arange(P.size, dtype=np.int64),
                                        idx, side="right")
        return [int(c) for c in ids[idx]]


class EagerPopulation(ClientPopulation):
    """The classic ``{id: ClientData}`` dict, with the sorted-id registry
    cached across rounds (the legacy selection re-sorted the population
    every call).  The cache revalidates only when the dict's size changes —
    the only membership edits the engines ever make."""

    def __init__(self, data_by_client: Dict[int, ClientData]):
        self._data = data_by_client
        self._ids: Optional[np.ndarray] = None
        self._ids_len = -1

    def ids_array(self) -> np.ndarray:
        if self._ids is None or self._ids_len != len(self._data):
            self._ids = np.sort(np.fromiter(self._data.keys(), dtype=np.int64,
                                            count=len(self._data)))
            self._ids_len = len(self._data)
        return self._ids

    def invalidate(self) -> None:
        """Force a registry rebuild (same-size membership edits)."""
        self._ids = None
        self._ids_len = -1

    def n_samples(self, client: int) -> int:
        return self._data[int(client)].n_samples

    def __getitem__(self, client: int) -> ClientData:
        return self._data[int(client)]

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, client: object) -> bool:
        try:
            return int(client) in self._data  # type: ignore[arg-type]
        except (TypeError, ValueError):
            return False


class LazyPopulation(ClientPopulation):
    """Registry-backed streamed population.

    ``n_samples`` is an O(M)-words array (the whole registry for 1M clients
    is ~8 MB); batches come from ``factory(client_id)`` on demand through a
    bounded LRU byte cache (``fetch_cache_bytes``), so only the active
    cohort's data is resident.  Cached ``ClientData`` objects keep a stable
    identity while resident, which is what the executors' weakref-keyed
    batch/signature caches key on; an evicted + re-fetched client simply
    re-enters those caches.

    ``ids=None`` means clients are ``0..M-1`` (the common case — no explicit
    id array is stored).  ``signature``/``meta`` carry optional registry
    annotations (batch signature, availability/link keys) for schedulers
    that want them; they are never required.
    """

    def __init__(self, n_samples: Sequence[int],
                 factory: Callable[[int], ClientData], *,
                 ids: Optional[Sequence[int]] = None,
                 fetch_cache_bytes: int = 256 << 20,
                 signature: Any = None,
                 meta: Optional[Dict[str, Any]] = None):
        sizes = np.asarray(n_samples, dtype=np.int64)
        if ids is None:
            self._explicit_ids: Optional[np.ndarray] = None
        else:
            arr = np.asarray(ids, dtype=np.int64)
            if arr.shape != sizes.shape:
                raise ValueError("ids and n_samples length mismatch")
            order = np.argsort(arr, kind="stable")
            arr = arr[order]
            if arr.size and np.any(arr[1:] == arr[:-1]):
                raise ValueError("duplicate client ids")
            self._explicit_ids = arr
            sizes = sizes[order]
        self._sizes = sizes
        self._factory = factory
        self.signature = signature
        self.meta = dict(meta or {})
        self.fetch_cache_bytes = int(fetch_cache_bytes)
        self._cache: "OrderedDict[int, Any]" = OrderedDict()
        self._cache_nbytes: Dict[int, int] = {}
        self._cache_bytes = 0
        self._ids_cache: Optional[np.ndarray] = None
        self._lock = threading.RLock()
        self.stats = {"fetches": 0, "cache_hits": 0, "evictions": 0}

    # -- registry ------------------------------------------------------
    def ids_array(self) -> np.ndarray:
        if self._explicit_ids is not None:
            return self._explicit_ids
        if self._ids_cache is None:
            self._ids_cache = np.arange(self._sizes.size, dtype=np.int64)
        return self._ids_cache

    def _pos(self, client: int) -> int:
        if self._explicit_ids is None:
            if 0 <= client < self._sizes.size:
                return client
            raise KeyError(client)
        i = int(np.searchsorted(self._explicit_ids, client))
        if i < self._explicit_ids.size and int(self._explicit_ids[i]) == client:
            return i
        raise KeyError(client)

    def n_samples(self, client: int) -> int:
        return int(self._sizes[self._pos(int(client))])

    def __len__(self) -> int:
        return int(self._sizes.size)

    def __contains__(self, client: object) -> bool:
        try:
            self._pos(int(client))  # type: ignore[arg-type]
            return True
        except (KeyError, TypeError, ValueError):
            return False

    # -- bounded fetch cache -------------------------------------------
    @property
    def cache_bytes(self) -> int:
        return self._cache_bytes

    def __getitem__(self, client: int) -> ClientData:
        c = int(client)
        with self._lock:
            data = self._cache.get(c)
            if data is not None:
                self._cache.move_to_end(c)
                self.stats["cache_hits"] += 1
                return data
        self._pos(c)                      # KeyError for unknown ids
        data = self._factory(c)           # synthesize outside the lock
        nbytes = _data_nbytes(data)
        with self._lock:
            cur = self._cache.get(c)
            if cur is not None:           # raced fetch: keep the first
                self._cache.move_to_end(c)
                self.stats["cache_hits"] += 1
                return cur
            self.stats["fetches"] += 1
            self._cache[c] = data
            self._cache_nbytes[c] = nbytes
            self._cache_bytes += nbytes
            while (self.fetch_cache_bytes > 0
                   and self._cache_bytes > self.fetch_cache_bytes
                   and len(self._cache) > 1):
                old, _ = self._cache.popitem(last=False)
                self._cache_bytes -= self._cache_nbytes.pop(old)
                self.stats["evictions"] += 1
        return data

    def materialize(self) -> Dict[int, ClientData]:
        """Build the equivalent eager dict straight from the factory (fresh
        objects, cache untouched) — the eager twin for parity tests."""
        return {int(c): self._factory(int(c)) for c in self.ids_array()}


def as_population(data: Any) -> ClientPopulation:
    """Adopt a ``ClientPopulation`` as-is; wrap a plain dict eagerly."""
    if isinstance(data, ClientPopulation):
        return data
    return EagerPopulation(data)
