"""FL algorithms (paper §5.1): FedAvg, FedProx, FedNova, Mime (stateless);
SCAFFOLD, FedDyn (stateful clients).

Each algorithm declares OP types for everything it communicates (paper §3.2)
and plugs into the Parrot round engine unchanged — the engine neither knows
nor cares which algorithm runs; it only schedules tasks, folds OP-typed
payloads and moves client state through the state manager.

The algorithms are generic over the model: they receive a ``grad_fn(params,
batch) -> (loss, grads)`` and operate on parameter pytrees, so the same code
trains a logistic regression in the unit tests, a CNN at paper scale in the
benchmarks, and a reduced LM in the integration tests.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.aggregation import ClientResult, Op

Pytree = Any
GradFn = Callable[[Pytree, Any], Tuple[jnp.ndarray, Pytree]]


def tree_add(a, b, scale=1.0):
    return jax.tree.map(lambda x, y: x + scale * y, a, b)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


@dataclass
class ClientData:
    """One client's local data: an iterable of batches (repeated E epochs by
    the algorithm) plus its sample count N_m (the scheduling signal)."""
    batches: List[Any]
    n_samples: int


class FLAlgorithm:
    name: str = "base"
    stateful: bool = False

    def __init__(self, grad_fn: GradFn, lr: float, local_epochs: int = 1,
                 server_lr: float = 1.0, **kw):
        self.grad_fn = grad_fn
        self.lr = lr
        self.local_epochs = local_epochs
        self.server_lr = server_lr

    # --- interface -------------------------------------------------------
    def ops(self) -> Dict[str, Op]:
        raise NotImplementedError

    def broadcast_payload(self, params: Pytree, server_state: Dict) -> Dict:
        """Θ^r — what the server sends to every executor each round."""
        return {"params": params}

    def client_init_state(self, params: Pytree) -> Optional[Pytree]:
        return None

    def client_update(self, payload: Dict, data: ClientData,
                      state: Optional[Pytree]
                      ) -> Tuple[ClientResult, Optional[Pytree]]:
        raise NotImplementedError

    def server_init(self, params: Pytree) -> Dict:
        return {}

    def server_update(self, params: Pytree, agg: Dict, server_state: Dict,
                      n_total_clients: int) -> Tuple[Pytree, Dict]:
        raise NotImplementedError

    # --- shared local-SGD loop --------------------------------------------
    def _local_sgd(self, params0: Pytree, data: ClientData,
                   grad_hook: Optional[Callable] = None) -> Tuple[Pytree, int]:
        """Plain local SGD with an optional per-step gradient correction.
        Returns (final params, number of local steps tau_m)."""
        w = params0
        tau = 0
        for _ in range(self.local_epochs):
            for batch in data.batches:
                _, g = self.grad_fn(w, batch)
                if grad_hook is not None:
                    g = grad_hook(w, g)
                w = tree_add(w, g, -self.lr)
                tau += 1
        return w, tau

    # --- pure per-step form (compiled engine; core/client_step.py) --------
    #
    # Each algorithm re-expresses its local update as a pure
    # ``(carry, batch, mask) -> carry`` function over an explicit carry
    # pytree (params plus whatever the steps read: the FedProx anchor,
    # SCAFFOLD variates, the FedDyn corrector, Mime's frozen momentum).
    # ``mask`` is 1.0 for real steps and 0.0 for the padding steps the
    # engine appends to bucket scan lengths — a masked step multiplies the
    # update by zero, so padding is exact.  The engine rolls ``local_step``
    # into one jitted ``lax.scan`` over all tau = local_epochs x n_batches
    # steps and vmaps it over blocks of clients; ``client_update`` above
    # stays as the eager reference path (used by ``run_flat_reference``).

    def init_carry(self, payload: Dict, state: Optional[Pytree]) -> Pytree:
        return {"w": payload["params"]}

    def step_correction(self, carry: Pytree, g: Pytree) -> Pytree:
        """Per-step gradient correction (the pure analogue of grad_hook)."""
        return g

    def local_step(self, carry: Pytree, batch: Any,
                   mask: jnp.ndarray) -> Pytree:
        _, g = self.grad_fn(carry["w"], batch)
        g = self.step_correction(carry, g)
        # mask is cast to each leaf's dtype (0/1 are exact in any float
        # dtype): an f32 mask would promote a bf16 carry and break the
        # scan's carry-type invariant
        w = jax.tree.map(
            lambda ww, gg: ww - self.lr * mask.astype(ww.dtype) * gg,
            carry["w"], g)
        return dict(carry, w=w)

    def finalize(self, carry: Pytree, payload: Dict, state: Optional[Pytree],
                 batches: Any, mask: jnp.ndarray
                 ) -> Tuple[Dict[str, Any], Optional[Pytree]]:
        """(result payload, new client state) from the final carry — pure;
        the aggregation weight is applied by the caller."""
        raise NotImplementedError

    def _tau(self, mask: jnp.ndarray) -> jnp.ndarray:
        """Real local-step count tau_m = E x n_batches (mask sums the
        un-padded batches), floored at 1 like the eager ``max(tau, 1)``."""
        return jnp.maximum(self.local_epochs * jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Stateless algorithms
# ---------------------------------------------------------------------------

class FedAvg(FLAlgorithm):
    name = "fedavg"

    def ops(self):
        return {"delta": Op.WEIGHTED_AVG}

    def client_update(self, payload, data, state):
        w, tau = self._local_sgd(payload["params"], data)
        delta = tree_sub(w, payload["params"])
        return ClientResult({"delta": delta}, self.ops(),
                            weight=float(data.n_samples)), None

    def server_update(self, params, agg, server_state, n_total_clients):
        return tree_add(params, agg["delta"], self.server_lr), server_state

    def finalize(self, carry, payload, state, batches, mask):
        return {"delta": tree_sub(carry["w"], payload["params"])}, None


class FedProx(FedAvg):
    name = "fedprox"

    def __init__(self, *a, mu: float = 0.01, **kw):
        super().__init__(*a, **kw)
        self.mu = mu

    def client_update(self, payload, data, state):
        anchor = payload["params"]

        def hook(w, g):  # g + mu * (w - w_global)
            return jax.tree.map(lambda gg, ww, aa: gg + self.mu * (ww - aa),
                                g, w, anchor)

        w, tau = self._local_sgd(anchor, data, hook)
        delta = tree_sub(w, anchor)
        return ClientResult({"delta": delta}, self.ops(),
                            weight=float(data.n_samples)), None

    def init_carry(self, payload, state):
        return {"w": payload["params"], "anchor": payload["params"]}

    def step_correction(self, carry, g):  # g + mu * (w - w_global)
        return jax.tree.map(lambda gg, ww, aa: gg + self.mu * (ww - aa),
                            g, carry["w"], carry["anchor"])


class FedNova(FLAlgorithm):
    """Normalised averaging (Wang et al., 2020): clients return the
    step-normalised delta plus an aggregation weight tau (the paper's example
    of an extra averaged parameter)."""
    name = "fednova"

    def ops(self):
        return {"norm_delta": Op.WEIGHTED_AVG, "tau": Op.WEIGHTED_AVG}

    def client_update(self, payload, data, state):
        w, tau = self._local_sgd(payload["params"], data)
        delta = tree_sub(w, payload["params"])
        norm_delta = tree_scale(delta, 1.0 / max(tau, 1))
        return ClientResult(
            {"norm_delta": norm_delta, "tau": jnp.float32(tau)},
            self.ops(), weight=float(data.n_samples)), None

    def server_update(self, params, agg, server_state, n_total_clients):
        tau_eff = agg["tau"]
        new = tree_add(params, tree_scale(agg["norm_delta"], tau_eff),
                       self.server_lr)
        return new, server_state

    def finalize(self, carry, payload, state, batches, mask):
        tau = self._tau(mask)     # traced f32: cast back to the leaf dtype
        delta = tree_sub(carry["w"], payload["params"])
        return {"norm_delta": jax.tree.map(
                    lambda d: (d / tau).astype(d.dtype), delta),
                "tau": jnp.asarray(tau, jnp.float32)}, None


class Mime(FLAlgorithm):
    """Mime (Karimireddy et al., 2020a): the server optimizer state (momentum)
    is broadcast and applied — but not updated — during local steps; clients
    additionally return a full-batch gradient at the *global* params, which
    the paper treats as a Special Param (collected, not averaged): comm size
    O(s_e · M_p) cannot be reduced by hierarchical aggregation (§4.2)."""
    name = "mime"

    def __init__(self, *a, beta: float = 0.9, **kw):
        super().__init__(*a, **kw)
        self.beta = beta

    def ops(self):
        return {"delta": Op.WEIGHTED_AVG, "full_grad": Op.COLLECT}

    def broadcast_payload(self, params, server_state):
        return {"params": params, "momentum": server_state["momentum"]}

    def server_init(self, params):
        return {"momentum": tree_zeros_like(params)}

    def client_update(self, payload, data, state):
        mom = payload["momentum"]

        def hook(w, g):  # momentum-corrected step, momentum frozen locally
            return jax.tree.map(
                lambda gg, mm: (1 - self.beta) * gg + self.beta * mm, g, mom)

        w, tau = self._local_sgd(payload["params"], data, hook)
        # full-batch gradient at the global params (server momentum update)
        gs = None
        n = 0
        for batch in data.batches:
            _, g = self.grad_fn(payload["params"], batch)
            gs = g if gs is None else tree_add(gs, g)
            n += 1
        full_grad = tree_scale(gs, 1.0 / max(n, 1))
        delta = tree_sub(w, payload["params"])
        return ClientResult({"delta": delta, "full_grad": full_grad},
                            self.ops(), weight=float(data.n_samples)), None

    def server_update(self, params, agg, server_state, n_total_clients):
        grads = agg["full_grad"]                  # list of (weight, pytree)
        # one stacked (M_p, ...) weighted average per leaf instead of a
        # per-client python loop over every leaf on the server path
        ws = jnp.asarray([w for w, _ in grads], jnp.float32)
        ws = ws / jnp.maximum(jnp.sum(ws), 1e-12)
        gavg = jax.tree.map(
            lambda *leaves: jnp.tensordot(ws, jnp.stack(leaves), axes=1),
            *[g for _, g in grads])
        # cast back to the momentum dtype: the f32 tensordot must not
        # promote a bf16 momentum (next round's scan carry would mismatch)
        mom = jax.tree.map(
            lambda m, g: (self.beta * m + (1 - self.beta) * g)
            .astype(m.dtype),
            server_state["momentum"], gavg)
        new = tree_add(params, agg["delta"], self.server_lr)
        return new, {"momentum": mom}

    def init_carry(self, payload, state):
        return {"w": payload["params"], "momentum": payload["momentum"]}

    def step_correction(self, carry, g):  # momentum frozen locally
        return jax.tree.map(
            lambda gg, mm: (1 - self.beta) * gg + self.beta * mm,
            g, carry["momentum"])

    def finalize(self, carry, payload, state, batches, mask):
        params0 = payload["params"]

        def acc(gs, xs):  # full-batch gradient at the *global* params
            b, m = xs
            _, g = self.grad_fn(params0, b)
            return jax.tree.map(lambda s, gg: s + m.astype(s.dtype) * gg,
                                gs, g), None

        gsum, _ = jax.lax.scan(acc, tree_zeros_like(params0), (batches, mask))
        n = jnp.maximum(jnp.sum(mask), 1.0)
        full_grad = jax.tree.map(lambda s: (s / n).astype(s.dtype), gsum)
        return {"delta": tree_sub(carry["w"], params0),
                "full_grad": full_grad}, None


# ---------------------------------------------------------------------------
# Stateful algorithms
# ---------------------------------------------------------------------------

class Scaffold(FLAlgorithm):
    """SCAFFOLD (Karimireddy et al., 2020b): client control variates c_m are
    client state held by the state manager; the server variate c is broadcast."""
    name = "scaffold"
    stateful = True

    def ops(self):
        return {"delta": Op.WEIGHTED_AVG, "delta_c": Op.AVG}

    def broadcast_payload(self, params, server_state):
        return {"params": params, "c": server_state["c"]}

    def server_init(self, params):
        return {"c": tree_zeros_like(params)}

    def client_init_state(self, params):
        return {"c_m": tree_zeros_like(params)}

    def client_update(self, payload, data, state):
        c, c_m = payload["c"], state["c_m"]

        def hook(w, g):  # g - c_m + c
            return jax.tree.map(lambda gg, cm, cc: gg - cm + cc, g, c_m, c)

        anchor = payload["params"]
        w, tau = self._local_sgd(anchor, data, hook)
        # option II update of the client variate
        c_m_new = jax.tree.map(
            lambda cm, cc, aa, ww: cm - cc + (aa - ww) / (tau * self.lr),
            c_m, c, anchor, w)
        delta = tree_sub(w, anchor)
        delta_c = tree_sub(c_m_new, c_m)
        return ClientResult({"delta": delta, "delta_c": delta_c}, self.ops(),
                            weight=float(data.n_samples)), {"c_m": c_m_new}

    def server_update(self, params, agg, server_state, n_total_clients):
        new = tree_add(params, agg["delta"], self.server_lr)
        # c += (M_p / M) * avg(delta_c); M_p folded in by the AVG op count
        frac = agg.get("_n_selected", 0) / max(n_total_clients, 1)
        c = tree_add(server_state["c"], agg["delta_c"], frac)
        return new, {"c": c}

    def init_carry(self, payload, state):
        return {"w": payload["params"], "c": payload["c"],
                "c_m": state["c_m"]}

    def step_correction(self, carry, g):  # g - c_m + c
        return jax.tree.map(lambda gg, cm, cc: gg - cm + cc,
                            g, carry["c_m"], carry["c"])

    def finalize(self, carry, payload, state, batches, mask):
        anchor, w = payload["params"], carry["w"]
        c, c_m = carry["c"], carry["c_m"]
        tau = self._tau(mask)     # traced f32: cast back to the leaf dtype
        c_m_new = jax.tree.map(
            lambda cm, cc, aa, ww:
                (cm - cc + (aa - ww) / (tau * self.lr)).astype(cm.dtype),
            c_m, c, anchor, w)
        return ({"delta": tree_sub(w, anchor),
                 "delta_c": tree_sub(c_m_new, c_m)}, {"c_m": c_m_new})


class FedDyn(FLAlgorithm):
    """FedDyn (Acar et al., 2021): clients keep the gradient of their local
    regularised objective as state; the server keeps a drift corrector h."""
    name = "feddyn"
    stateful = True

    def __init__(self, *a, alpha: float = 0.1, **kw):
        super().__init__(*a, **kw)
        self.alpha = alpha

    def ops(self):
        return {"delta": Op.WEIGHTED_AVG}

    def server_init(self, params):
        return {"h": tree_zeros_like(params)}

    def client_init_state(self, params):
        return {"grad_corr": tree_zeros_like(params)}

    def client_update(self, payload, data, state):
        anchor = payload["params"]
        gc = state["grad_corr"]

        def hook(w, g):  # g + alpha * (w - anchor) - grad_corr
            return jax.tree.map(
                lambda gg, ww, aa, hh: gg + self.alpha * (ww - aa) - hh,
                g, w, anchor, gc)

        w, tau = self._local_sgd(anchor, data, hook)
        gc_new = jax.tree.map(lambda hh, ww, aa: hh - self.alpha * (ww - aa),
                              gc, w, anchor)
        delta = tree_sub(w, anchor)
        return ClientResult({"delta": delta}, self.ops(),
                            weight=float(data.n_samples)), {"grad_corr": gc_new}

    def server_update(self, params, agg, server_state, n_total_clients):
        # h^{r+1} = h^r - alpha * frac * delta_avg;
        # theta^{r+1} = avg(w) - h^{r+1}/alpha
        #            = theta^r + delta_avg * (1 + frac)   (telescoped form)
        frac = agg.get("_n_selected", 0) / max(n_total_clients, 1)
        h = tree_add(server_state["h"], agg["delta"], -self.alpha * frac)
        new = tree_add(params, agg["delta"], self.server_lr * (1.0 + frac))
        return new, {"h": h}

    def init_carry(self, payload, state):
        return {"w": payload["params"], "anchor": payload["params"],
                "grad_corr": state["grad_corr"]}

    def step_correction(self, carry, g):  # g + alpha * (w - anchor) - h
        return jax.tree.map(
            lambda gg, ww, aa, hh: gg + self.alpha * (ww - aa) - hh,
            g, carry["w"], carry["anchor"], carry["grad_corr"])

    def finalize(self, carry, payload, state, batches, mask):
        anchor, w = payload["params"], carry["w"]
        gc_new = jax.tree.map(lambda hh, ww, aa: hh - self.alpha * (ww - aa),
                              state["grad_corr"], w, anchor)
        return {"delta": tree_sub(w, anchor)}, {"grad_corr": gc_new}


ALGORITHMS = {
    "fedavg": FedAvg,
    "fedprox": FedProx,
    "fednova": FedNova,
    "mime": Mime,
    "scaffold": Scaffold,
    "feddyn": FedDyn,
}


def make_algorithm(name: str, grad_fn: GradFn, lr: float, **kw) -> FLAlgorithm:
    return ALGORITHMS[name](grad_fn, lr, **kw)
