"""Trace-driven network & availability simulation (DESIGN.md §9).

The virtual clock (§4) prices compute only; without this module uploads,
downloads and client churn are free and instantaneous, so compression and
the scheduler's per-executor offset have zero observable effect on the
simulated makespan.  This module adds the comm axis:

* :class:`NetworkModel` — per-client uplink/downlink bandwidth and latency
  (:class:`LinkProfile`), either uniform, sampled deterministically from a
  seeded distribution, or loaded from FedScale-style trace rows
  (``data/traces.py``).  A chunk's upload is priced
  ``latency + wire_bytes / uplink_bw`` at the chunk's *bottleneck* client
  (min bandwidth, max latency: the executor's partial is not ready before
  its slowest constituent has shipped), using the compressor's achieved
  wire size — top-k / int8 finally move the makespan.  A round's model
  broadcast is priced the same way on the downlink.

* :class:`ClientAvailability` — per-client active windows (join/leave), a
  synthetic diurnal generator, or FedScale behavior-trace rows.
  Unavailable clients are filtered at selection; a client predicted to
  leave mid-chunk is dropped at dispatch and re-enters through the engine's
  existing re-run path (semi-sync carry pool / async re-selection).

* :class:`CommEvent` — the payload of a ``"chunk_arrived"`` event on the
  shared :class:`~repro.core.clock.VirtualClock`: the engines push it at
  ``compute_done + upload_time`` and fold the carried wire partial when it
  pops, so uploads overlap the executor's next chunk exactly as they would
  on a real link.

With ``network=None`` and ``availability=None`` (the defaults) none of
this is consulted and the engines take their pre-existing code paths
bit-exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class LinkProfile:
    """One client's link: bandwidths in bytes/second, latency in seconds."""
    uplink_bps: float
    downlink_bps: float
    latency_s: float = 0.0


#: infinite bandwidth, zero latency — comm-transparent (the pre-network
#: behaviour expressed as a link)
FREE_LINK = LinkProfile(uplink_bps=math.inf, downlink_bps=math.inf,
                        latency_s=0.0)

_KBPS_TO_BPS = 1000.0 / 8.0          # FedScale kbps -> bytes/second


@dataclass(frozen=True)
class CommEvent:
    """A partial in flight: pushed on the VirtualClock at its arrival time.

    ``partial`` is the decompressed wire copy (it crossed the comm layer at
    send time, keeping error-feedback residual order deterministic);
    ``version`` is the payload round the chunk trained against, so the
    async staleness count includes the comm delay.
    """
    executor: int
    partial: Any
    record: Optional[Any]            # workload RunRecord (or None)
    n_tasks: int
    completed_clients: Tuple[int, ...]
    wire_bytes: int
    version: int = 0
    # local virtual time the upload left the executor (telemetry: the
    # arrival-minus-sent delta feeds the upload-delay histogram)
    t_sent: float = 0.0


class NetworkModel:
    """Per-client link parameters with deterministic constructors.

    ``links`` maps client id -> :class:`LinkProfile`; absent clients take
    ``default`` (``FREE_LINK`` unless overridden, so a partial trace only
    constrains the clients it names).
    """

    def __init__(self, links: Dict[int, LinkProfile],
                 default: LinkProfile = FREE_LINK):
        self._links = dict(links)
        self.default = default

    def link(self, client: int) -> LinkProfile:
        return self._links.get(client, self.default)

    # -- pricing -----------------------------------------------------------
    @staticmethod
    def _xfer(nbytes: float, bw: float, latency: float) -> float:
        if nbytes <= 0:
            return max(latency, 0.0)
        if bw <= 0:
            return math.inf
        return max(latency, 0.0) + nbytes / bw

    def upload_time(self, clients: Iterable[int], nbytes: int) -> float:
        """Latency + wire time of one upload whose content gates on every
        named client (bottleneck: min uplink, max latency).  No clients ->
        0 (nothing shipped)."""
        links = [self.link(c) for c in clients]
        if not links:
            return 0.0
        return self._xfer(nbytes, min(l.uplink_bps for l in links),
                          max(l.latency_s for l in links))

    def download_time(self, clients: Iterable[int], nbytes: int) -> float:
        """One model broadcast to the named clients (they download in
        parallel; the chunk starts when the slowest has the payload)."""
        links = [self.link(c) for c in clients]
        if not links:
            return 0.0
        return self._xfer(nbytes, min(l.downlink_bps for l in links),
                          max(l.latency_s for l in links))

    def client_comm_time(self, client: int, down_bytes: int,
                         up_bytes: int) -> float:
        """One client's round-trip comm (Eq. 4's bandwidth-aware addend):
        download the model, upload the update."""
        l = self.link(client)
        return (self._xfer(down_bytes, l.downlink_bps, l.latency_s)
                + self._xfer(up_bytes, l.uplink_bps, l.latency_s))

    def chunk_comm_time(self, clients: Iterable[int], down_bytes: int,
                        up_bytes: int) -> float:
        """Predicted comm span of one chunk: broadcast down + partial up."""
        clients = list(clients)
        return (self.download_time(clients, down_bytes)
                + self.upload_time(clients, up_bytes))

    # -- transforms --------------------------------------------------------
    def scaled(self, factor: float) -> "NetworkModel":
        """Every bandwidth multiplied by ``factor`` (latency unchanged) —
        the makespan-monotonicity property's knob."""

        def s(l: LinkProfile) -> LinkProfile:
            return LinkProfile(l.uplink_bps * factor,
                               l.downlink_bps * factor, l.latency_s)

        return NetworkModel({c: s(l) for c, l in self._links.items()},
                            default=s(self.default))

    # -- constructors ------------------------------------------------------
    @classmethod
    def uniform(cls, uplink_bps: float, downlink_bps: Optional[float] = None,
                latency_s: float = 0.0) -> "NetworkModel":
        """Every client on the same link."""
        down = uplink_bps if downlink_bps is None else downlink_bps
        return cls({}, default=LinkProfile(uplink_bps, down, latency_s))

    @classmethod
    def from_trace(cls, rows: Sequence[Any],
                   default: LinkProfile = FREE_LINK) -> "NetworkModel":
        """FedScale-style capacity rows (``data/traces.py: CapacityRow`` or
        equivalent dicts; kbps / ms units)."""
        links = {}
        for r in rows:
            get = r.get if isinstance(r, dict) else lambda k, _r=r: getattr(_r, k)
            links[int(get("client_id"))] = LinkProfile(
                uplink_bps=float(get("uplink_kbps")) * _KBPS_TO_BPS,
                downlink_bps=float(get("downlink_kbps")) * _KBPS_TO_BPS,
                latency_s=float(get("latency_ms")) / 1000.0)
        return cls(links, default=default)

    @classmethod
    def lognormal(cls, n_clients: int, seed: int = 0,
                  median_uplink_kbps: float = 12_000.0, sigma: float = 1.0,
                  down_up_ratio: float = 5.0,
                  latency_ms_range: Tuple[float, float] = (20.0, 120.0)
                  ) -> "NetworkModel":
        """Seeded lognormal bandwidth population (the measured-trace shape)."""
        from repro.data.traces import synthesize_capacity_trace
        return cls.from_trace(synthesize_capacity_trace(
            n_clients, seed=seed, dist="lognormal",
            median_uplink_kbps=median_uplink_kbps, sigma=sigma,
            down_up_ratio=down_up_ratio, latency_ms_range=latency_ms_range))


class ClientAvailability:
    """Per-client active windows on the virtual-time axis.

    ``windows`` maps client id -> sorted ``(start, end)`` active spans; a
    ``period`` folds the query time (diurnal traces), otherwise spans are
    absolute.  Clients without an entry take ``default`` (available).  With
    a periodic wrap-around window split in two, ``remaining`` is evaluated
    within the current span only — a conservative (never optimistic)
    under-estimate across the period boundary.
    """

    def __init__(self, windows: Dict[int, Sequence[Tuple[float, float]]],
                 period: Optional[float] = None, default: bool = True):
        self.period = None if period is None else float(period)
        self.default = bool(default)
        self._win: Dict[int, Tuple[Tuple[float, float], ...]] = {
            int(c): tuple(sorted((float(a), float(b)) for a, b in ws))
            for c, ws in windows.items()}

    def _fold(self, t: float) -> float:
        return t % self.period if self.period else t

    def available(self, client: int, t: float) -> bool:
        ws = self._win.get(client)
        if ws is None:
            return self.default
        lt = self._fold(t)
        return any(a <= lt < b for a, b in ws)

    def remaining(self, client: int, t: float) -> float:
        """Seconds until the client leaves (0 when unavailable, inf when
        unconstrained)."""
        ws = self._win.get(client)
        if ws is None:
            return math.inf if self.default else 0.0
        lt = self._fold(t)
        for a, b in ws:
            if a <= lt < b:
                return b - lt
        return 0.0

    def next_available(self, client: int, t: float) -> float:
        """Earliest virtual time >= ``t`` at which the client is available
        (``t`` itself if available now; inf if never again)."""
        ws = self._win.get(client)
        if ws is None:
            return t if self.default else math.inf
        if not ws:                   # trace row with no active windows
            return math.inf
        lt = self._fold(t)
        for a, b in ws:
            if a <= lt < b:
                return t
        nxt = [a for a, _ in ws if a > lt]
        if nxt:
            return t + (nxt[0] - lt)
        if self.period is None:
            return math.inf
        return t + (self.period - lt) + ws[0][0]

    def fits(self, client: int, t: float, duration: float) -> bool:
        """True when the client is available at ``t`` AND stays available
        for the next ``duration`` seconds — the window-fit selection test
        (DESIGN.md §12): a client whose window closes mid-span would land a
        dispatch-time skip or a lost upload, so the control plane filters
        it at selection instead."""
        return self.available(client, t) and self.remaining(client, t) >= duration

    # -- constructors ------------------------------------------------------
    @classmethod
    def always(cls) -> "ClientAvailability":
        return cls({}, period=None, default=True)

    @classmethod
    def diurnal(cls, n_clients: int, period_s: float = 86_400.0,
                duty_mean: float = 0.6, duty_jitter: float = 0.15,
                seed: int = 0) -> "ClientAvailability":
        """Seeded synthetic diurnal churn (``data/traces.py`` generator)."""
        from repro.data.traces import synthesize_behavior_trace
        return cls.from_trace(synthesize_behavior_trace(
            n_clients, seed=seed, period_s=period_s, duty_mean=duty_mean,
            duty_jitter=duty_jitter))

    @classmethod
    def from_trace(cls, rows: Sequence[Any],
                   default: bool = True) -> "ClientAvailability":
        """FedScale-style behavior rows (``data/traces.py: BehaviorRow`` or
        equivalent dicts).  All rows must share one ``period_s`` (or none)."""
        windows: Dict[int, Sequence[Tuple[float, float]]] = {}
        periods = set()
        for r in rows:
            get = r.get if isinstance(r, dict) else lambda k, _r=r: getattr(_r, k)
            windows[int(get("client_id"))] = list(get("active"))
            periods.add(get("period_s"))
        if len(periods) > 1:
            raise ValueError(f"behavior trace mixes periods: {periods}")
        period = periods.pop() if periods else None
        return cls(windows, period=period, default=default)
