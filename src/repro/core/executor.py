"""Executors: sequential client simulation on a device (Algorithm 2,
``Device_Executes``).

``SequentialExecutor`` is the real thing: it loads client state, runs the
algorithm's client_update, saves state, and folds results into the local
aggregator — measuring per-task wall time for the workload estimator.

``speed_model`` implements the paper's Appendix-A protocol for benchmarking
scheduling under heterogeneous / unstable devices on homogeneous hardware: a
per-(executor, round) slowdown ratio η_k(r) scales the *reported* task time.
We account the scaled time in virtual time rather than sleeping, which makes
the paper's timing experiments deterministic and fast; the round engine then
computes the BSP round time as max_k Σ_task time — exactly the paper's
"server waits for the slowest executor".

Straggler backup tasks: when ``backup_fraction > 0`` the round engine
duplicates the tail of the predicted-slowest queue onto the
predicted-fastest executor (speculative duplicates resolved through the
``skip_clients`` hook below, so each client folds exactly once) — tail
mitigation at 1000-node scale where a single dead/slow host would
otherwise stall every round.

Aggregation inside ``run_queue`` uses the flat-buffer ``LocalAggregator``:
the first round builds a ``FlatLayout`` for the algorithm's payload, which
is cached here and reused for every subsequent round (flatten-once), and
client deltas fold in micro-batches of ``agg_micro_batch`` — one kernel
dispatch per B clients instead of one per pytree leaf per client.

Chunked execution (DESIGN.md §3): a *chunk* — a slice of the queue run as
its own span via ``run_queue(<slice>, task_offset=)``, yielding its own
shippable flat partial — is the executor-side unit the event-driven engines
dispatch.  The engines drive chunks one at a time through the shared
virtual clock (lazy dispatch is what makes the DES causally correct), so
they call ``run_queue`` per chunk themselves; ``run_queue(chunk_size=,
on_partial=)`` is the self-contained streaming form of the same contract
for callers without an event loop, and delegates to the identical per-chunk
path.  The wall-clock source is injectable (``timer``; see
``core/clock.py``) so engine-equivalence tests can pin down measured
durations deterministically.

Client training itself runs through the compiled engine
(``core.client_step``): ``run_queue`` groups same-signature clients into
blocks of ``client_block`` and runs one vmapped jit-scan per block, folding
the stacked (B, ...) deltas straight into the flat aggregator
(``fold_block``) — no per-client ``ClientResult`` round-trip.  Virtual time
for a block is attributed per client (block time / B, scaled by the speed
model's η), so the workload estimator keeps seeing per-client records.  The
eager per-task path is kept for ``use_compiled_steps=False``, for ragged
clients, and for rounds with a pending ``fail_at`` injection (task-index
granularity must stay exact there).
"""
from __future__ import annotations

import time
import weakref
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core import client_step
from repro.core.aggregation import ClientResult, LocalAggregator, Op
from repro.core.algorithms import ClientData, FLAlgorithm
from repro.core.scheduler import ClientTask
from repro.core.state_manager import ClientStateManager
from repro.core.workload import RunRecord


SpeedModel = Callable[[int, int], float]   # (executor, round) -> eta >= 0


def homogeneous(executor: int, rnd: int) -> float:
    return 0.0


def hetero_gpus(ratios: Dict[int, float]) -> SpeedModel:
    """Fixed per-executor slowdown ratios η_k (paper Appendix A, Hete. GPU)."""
    return lambda k, r: ratios.get(k, 0.0)


def dynamic_env(n_executors: int, total_rounds: int) -> SpeedModel:
    """Unstable devices: η_k(r) = 1 + cos(3.14 r / R + k) (paper Appendix A)."""
    import math

    def eta(k: int, r: int) -> float:
        return 1.0 + math.cos(3.14 * r / max(total_rounds, 1) + k)

    return eta


@dataclass
class ExecutorReport:
    executor: int
    partial: Dict[str, Any]
    records: List[RunRecord]
    virtual_time: float          # Σ per-task simulated time (BSP makespan input)
    wall_time: float
    n_tasks: int
    completed_clients: List[int] = field(default_factory=list)


class SequentialExecutor:
    """One Parrot device (a GPU in the paper; a mesh slice on TPU)."""

    def __init__(self, executor_id: int, algorithm: FLAlgorithm,
                 state_manager: Optional[ClientStateManager] = None,
                 speed_model: SpeedModel = homogeneous,
                 use_agg_kernel: bool = False,
                 agg_micro_batch: int = 16,
                 use_compiled_steps: bool = True,
                 client_block: int = 8,
                 fail_at: Optional[Tuple[int, int]] = None,
                 timer: Optional[Callable[[], float]] = None):
        self.id = executor_id
        self.algorithm = algorithm
        self.state_manager = state_manager
        self.speed_model = speed_model
        self.use_agg_kernel = use_agg_kernel
        self.agg_micro_batch = agg_micro_batch
        self.use_compiled_steps = use_compiled_steps
        self.client_block = max(1, int(client_block))
        # injectable wall-clock source (core/clock.py): the engine
        # equivalence tests swap in a deterministic TickTimer so measured
        # durations become a pure function of the code path taken
        self.timer = timer or time.perf_counter
        self._layout_cache = None   # FlatLayout, computed once, reused per round
        # steady-state block cost per (signature, B): running minimum of
        # clean measurements — virtual time stays deterministic-ish on a
        # noisy shared host, as the paper's Appendix-A protocol intends
        self._block_cost: Dict[Any, float] = {}
        # per-client batch signature, keyed on the ClientData identity (a
        # weakref, so a swapped dataset re-keys and a recycled id() cannot
        # alias): the walk is O(n_batches x n_leaves) and must not repeat
        # every round
        self._sig_cache: Dict[int, Tuple[Any, Any]] = {}
        # fault-injection hook for the fault-tolerance tests:
        # (round, task_index) at which this executor dies.
        self.fail_at = fail_at

    def run_queue(self, rnd: int, tasks: List[ClientTask], payload: Dict,
                  data_by_client: Dict[int, ClientData],
                  skip_clients: Optional[set] = None,
                  chunk_size: Optional[int] = None,
                  on_partial: Optional[Callable[["ExecutorReport"], None]]
                  = None,
                  task_offset: int = 0) -> ExecutorReport:
        """Run a task queue (``Device_Executes``).

        ``chunk_size`` switches to chunked *streaming* execution: the queue
        is cut into chunks of at most that many tasks, each chunk runs as
        its own span (own LocalAggregator, so its partial is shippable on
        its own) and is emitted through ``on_partial`` the moment it
        completes.  The returned report merges the chunk reports; its
        ``partial`` is the merge of the chunk partials (identical aggregate
        to one unchunked run).  The engines themselves call this method once
        per chunk with ``task_offset`` instead (their event loop owns the
        interleaving) — both routes run the same per-chunk code.

        ``task_offset`` keeps ``fail_at``'s task index global to the
        executor's dispatch stream when the caller passes slices of it.
        """
        if chunk_size is not None:
            return self._run_chunked(rnd, tasks, payload, data_by_client,
                                     skip_clients, chunk_size, on_partial,
                                     task_offset)
        agg = LocalAggregator(self.algorithm.ops(),
                              use_kernel=self.use_agg_kernel,
                              micro_batch=self.agg_micro_batch,
                              layout=self._layout_cache)
        records: List[RunRecord] = []
        completed: List[int] = []
        t_start = self.timer()
        eta = self.speed_model(self.id, rnd)
        # fail_at is task-index-granular: a round with a pending injection
        # runs the eager per-task loop so the index semantics stay exact
        # (round -1 is a wildcard: fire at that dispatch index in any round
        # — the async engine's dispatch stream spans update boundaries)
        if self.use_compiled_steps and not (
                self.fail_at is not None and self.fail_at[0] in (rnd, -1)):
            vtime = self._run_blocked(rnd, tasks, payload, data_by_client,
                                      skip_clients, agg, records, completed,
                                      eta)
        else:
            vtime = self._run_eager(rnd, tasks, payload, data_by_client,
                                    skip_clients, agg, records, completed,
                                    eta, task_offset)
        self._layout_cache = agg.layout     # flatten-once across rounds
        return ExecutorReport(
            executor=self.id, partial=agg.partial(), records=records,
            virtual_time=vtime, wall_time=self.timer() - t_start,
            n_tasks=len(completed), completed_clients=completed)

    def _run_chunked(self, rnd, tasks, payload, data_by_client, skip_clients,
                     chunk_size, on_partial, task_offset) -> ExecutorReport:
        from repro.core.aggregation import merge_partials
        from repro.core.scheduler import split_chunks
        merged: Optional[Dict] = None
        records: List[RunRecord] = []
        completed: List[int] = []
        vtime = wall = 0.0
        offset = task_offset
        for chunk in split_chunks(tasks, chunk_size):
            rep = self.run_queue(rnd, chunk, payload, data_by_client,
                                 skip_clients, task_offset=offset)
            offset += len(chunk)
            if on_partial is not None:
                on_partial(rep)
            merged = merge_partials(merged, rep.partial)
            records.extend(rep.records)
            completed.extend(rep.completed_clients)
            vtime += rep.virtual_time
            wall += rep.wall_time
        return ExecutorReport(
            executor=self.id, partial=merged if merged is not None else
            LocalAggregator(self.algorithm.ops()).partial(),
            records=records, virtual_time=vtime, wall_time=wall,
            n_tasks=len(completed), completed_clients=completed)

    # ------------------------------------------------------------------
    def _run_eager(self, rnd, tasks, payload, data_by_client, skip_clients,
                   agg, records, completed, eta, task_offset=0) -> float:
        """Legacy per-task reference path (one eager client_update per
        task; also the fault-injection path)."""
        vtime = 0.0
        for i, task in enumerate(tasks, start=task_offset):
            if self.fail_at is not None and self.fail_at[1] == i \
                    and self.fail_at[0] in (rnd, -1):
                raise ExecutorFailure(self.id, rnd, i)
            if skip_clients and task.client in skip_clients:
                continue  # result already produced by a backup replica
            t0 = self.timer()
            state = None
            if self.algorithm.stateful:
                state = self.state_manager.load(task.client)
                if state is None:
                    state = self.algorithm.client_init_state(payload["params"])
            result, new_state = self.algorithm.client_update(
                payload, data_by_client[task.client], state)
            if self.algorithm.stateful and new_state is not None:
                self.state_manager.save(task.client, new_state)
            agg.fold(result)
            completed.append(task.client)
            measured = self.timer() - t0
            simulated = measured * (1.0 + eta)
            vtime += simulated
            records.append(RunRecord(round=rnd, client=task.client,
                                     executor=self.id,
                                     n_samples=task.n_samples,
                                     time=simulated))
        return vtime

    # ------------------------------------------------------------------
    def _plan_blocks(self, tasks: List[ClientTask],
                     data_by_client: Dict[int, ClientData]
                     ) -> List[Tuple[Tuple, List[ClientTask]]]:
        """Group same-signature clients into blocks of ``client_block``
        (first-seen group order; queue order within a group).  Ragged
        clients get singleton eager blocks."""
        groups: Dict[Any, List[ClientTask]] = {}
        order: List[Any] = []
        for t in tasks:
            data = data_by_client[t.client]
            cached = self._sig_cache.get(t.client)
            if cached is not None and cached[0]() is data:
                sig = cached[1]
            else:
                sig = client_step.batch_signature(data)
                self._sig_cache[t.client] = (weakref.ref(data), sig)
            key = ("eager", t.client) if sig is None else ("block", sig)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(t)
        blocks: List[Tuple[Any, List[ClientTask]]] = []
        for key in order:
            q = groups[key]
            if key[0] == "eager":
                blocks.append((key, q))
            else:
                for i in range(0, len(q), self.client_block):
                    blocks.append((key, q[i:i + self.client_block]))
        return blocks

    def _run_blocked(self, rnd, tasks, payload, data_by_client, skip_clients,
                     agg, records, completed, eta) -> float:
        """Compiled-engine path: one vmapped jit-scan per block, stacked
        deltas folded straight into the flat aggregator."""
        engine = client_step.engine_for(self.algorithm)
        todo = [t for t in tasks
                if not (skip_clients and t.client in skip_clients)]
        vtime = 0.0
        for key, block in self._plan_blocks(todo, data_by_client):
            kind = key[0]
            compiles0 = client_step.compile_events()
            states = None
            if self.algorithm.stateful:
                states = self.state_manager.load_many(
                    [t.client for t in block])
                states = [s if s is not None
                          else self.algorithm.client_init_state(
                              payload["params"])
                          for s in states]
            datas = [data_by_client[t.client] for t in block]

            # the timed span is exactly the client compute (stack + engine
            # + sync on the outputs; jax dispatch is async, so without the
            # sync it would measure host dispatch, not training); state IO
            # and the aggregation fold stay outside so the compile
            # re-measure below can reproduce the identical span
            def run_engine():
                if len(block) == 1:
                    res, st = engine.run_client(
                        payload, datas[0], states[0] if states else None,
                        assume_uniform=True)
                    jax.block_until_ready((res.payload, st))
                    return res, st
                out = engine.run_block(payload, datas, states)
                jax.block_until_ready(out)
                return out

            t0 = self.timer()
            if kind == "eager":           # ragged batches: reference path
                assert len(block) == 1
                result, new_state = self.algorithm.client_update(
                    payload, datas[0], states[0] if states else None)
                new_states = [new_state]
            else:
                out = run_engine()
                new_states = None
            measured = self.timer() - t0
            # a first-seen shape just paid its one-off compile inside the
            # timed span; re-run the (pure) computation once, result
            # discarded, so virtual time and the workload estimator see
            # steady-state throughput, not compile spikes
            if kind != "eager" and client_step.compile_events() > compiles0:
                t0 = self.timer()
                run_engine()
                measured = self.timer() - t0

            if kind == "eager":
                agg.fold(result)
            elif len(block) == 1:
                result, new_state = out
                agg.fold(result)
                new_states = [new_state]
            else:
                stacked, new_states = out
                agg.fold_block(stacked,
                               [float(d.n_samples) for d in datas])
                if new_states is None:
                    new_states = [None] * len(block)
            if self.algorithm.stateful:
                self.state_manager.save_many(
                    {t.client: s for t, s in zip(block, new_states)
                     if s is not None})
            completed.extend(t.client for t in block)
            if kind != "eager":
                # steady-state filter: host-noise spikes (GC, co-tenant
                # load) would otherwise dominate the BSP makespan now that
                # a round is a handful of coarse blocks instead of many
                # small tasks
                cost_key = (key[1], len(block))
                measured = min(measured,
                               self._block_cost.get(cost_key, measured))
                self._block_cost[cost_key] = measured
            # per-client virtual-time attribution: the block's measured time
            # splits evenly across its B clients (same batch bucket => same
            # compute), each scaled by the speed model's η
            simulated = measured * (1.0 + eta)
            per_client = simulated / len(block)
            vtime += simulated
            records.extend(
                RunRecord(round=rnd, client=t.client, executor=self.id,
                          n_samples=t.n_samples, time=per_client)
                for t in block)
        return vtime


class ExecutorFailure(RuntimeError):
    def __init__(self, executor: int, rnd: int, task_index: int):
        super().__init__(f"executor {executor} failed at round {rnd}, "
                         f"task {task_index}")
        self.executor = executor
        self.rnd = rnd
        self.task_index = task_index
