"""Executors: sequential client simulation on a device (Algorithm 2,
``Device_Executes``).

``SequentialExecutor`` is the real thing: it loads client state, runs the
algorithm's client_update, saves state, and folds results into the local
aggregator — measuring per-task wall time for the workload estimator.

``speed_model`` implements the paper's Appendix-A protocol for benchmarking
scheduling under heterogeneous / unstable devices on homogeneous hardware: a
per-(executor, round) slowdown ratio η_k(r) scales the *reported* task time.
We account the scaled time in virtual time rather than sleeping, which makes
the paper's timing experiments deterministic and fast; the round engine then
computes the BSP round time as max_k Σ_task time — exactly the paper's
"server waits for the slowest executor".

Straggler backup tasks: when ``backup_fraction > 0`` the round engine
duplicates the tail of the predicted-slowest queue onto the
predicted-fastest executor (speculative duplicates resolved through the
``skip_clients`` hook below, so each client folds exactly once) — tail
mitigation at 1000-node scale where a single dead/slow host would
otherwise stall every round.

Aggregation inside ``run_queue`` uses the flat-buffer ``LocalAggregator``:
the first round builds a ``FlatLayout`` for the algorithm's payload, which
is cached here and reused for every subsequent round (flatten-once), and
client deltas fold in micro-batches of ``agg_micro_batch`` — one kernel
dispatch per B clients instead of one per pytree leaf per client.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.aggregation import ClientResult, LocalAggregator, Op
from repro.core.algorithms import ClientData, FLAlgorithm
from repro.core.scheduler import ClientTask
from repro.core.state_manager import ClientStateManager
from repro.core.workload import RunRecord


SpeedModel = Callable[[int, int], float]   # (executor, round) -> eta >= 0


def homogeneous(executor: int, rnd: int) -> float:
    return 0.0


def hetero_gpus(ratios: Dict[int, float]) -> SpeedModel:
    """Fixed per-executor slowdown ratios η_k (paper Appendix A, Hete. GPU)."""
    return lambda k, r: ratios.get(k, 0.0)


def dynamic_env(n_executors: int, total_rounds: int) -> SpeedModel:
    """Unstable devices: η_k(r) = 1 + cos(3.14 r / R + k) (paper Appendix A)."""
    import math

    def eta(k: int, r: int) -> float:
        return 1.0 + math.cos(3.14 * r / max(total_rounds, 1) + k)

    return eta


@dataclass
class ExecutorReport:
    executor: int
    partial: Dict[str, Any]
    records: List[RunRecord]
    virtual_time: float          # Σ per-task simulated time (BSP makespan input)
    wall_time: float
    n_tasks: int
    completed_clients: List[int] = field(default_factory=list)


class SequentialExecutor:
    """One Parrot device (a GPU in the paper; a mesh slice on TPU)."""

    def __init__(self, executor_id: int, algorithm: FLAlgorithm,
                 state_manager: Optional[ClientStateManager] = None,
                 speed_model: SpeedModel = homogeneous,
                 use_agg_kernel: bool = False,
                 agg_micro_batch: int = 16,
                 fail_at: Optional[Tuple[int, int]] = None):
        self.id = executor_id
        self.algorithm = algorithm
        self.state_manager = state_manager
        self.speed_model = speed_model
        self.use_agg_kernel = use_agg_kernel
        self.agg_micro_batch = agg_micro_batch
        self._layout_cache = None   # FlatLayout, computed once, reused per round
        # fault-injection hook for the fault-tolerance tests:
        # (round, task_index) at which this executor dies.
        self.fail_at = fail_at

    def run_queue(self, rnd: int, tasks: List[ClientTask], payload: Dict,
                  data_by_client: Dict[int, ClientData],
                  skip_clients: Optional[set] = None) -> ExecutorReport:
        agg = LocalAggregator(self.algorithm.ops(),
                              use_kernel=self.use_agg_kernel,
                              micro_batch=self.agg_micro_batch,
                              layout=self._layout_cache)
        records: List[RunRecord] = []
        completed: List[int] = []
        vtime = 0.0
        t_start = time.perf_counter()
        eta = self.speed_model(self.id, rnd)
        for i, task in enumerate(tasks):
            if self.fail_at is not None and self.fail_at == (rnd, i):
                raise ExecutorFailure(self.id, rnd, i)
            if skip_clients and task.client in skip_clients:
                continue  # result already produced by a backup replica
            t0 = time.perf_counter()
            state = None
            if self.algorithm.stateful:
                state = self.state_manager.load(task.client)
                if state is None:
                    state = self.algorithm.client_init_state(payload["params"])
            result, new_state = self.algorithm.client_update(
                payload, data_by_client[task.client], state)
            if self.algorithm.stateful and new_state is not None:
                self.state_manager.save(task.client, new_state)
            agg.fold(result)
            completed.append(task.client)
            measured = time.perf_counter() - t0
            simulated = measured * (1.0 + eta)
            vtime += simulated
            records.append(RunRecord(round=rnd, client=task.client,
                                     executor=self.id,
                                     n_samples=task.n_samples,
                                     time=simulated))
        self._layout_cache = agg.layout     # flatten-once across rounds
        return ExecutorReport(
            executor=self.id, partial=agg.partial(), records=records,
            virtual_time=vtime, wall_time=time.perf_counter() - t_start,
            n_tasks=len(completed), completed_clients=completed)


class ExecutorFailure(RuntimeError):
    def __init__(self, executor: int, rnd: int, task_index: int):
        super().__init__(f"executor {executor} failed at round {rnd}, "
                         f"task {task_index}")
        self.executor = executor
        self.rnd = rnd
        self.task_index = task_index
