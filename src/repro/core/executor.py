"""Executors: sequential client simulation on a device (Algorithm 2,
``Device_Executes``).

``SequentialExecutor`` is the real thing: it loads client state, runs the
algorithm's client_update, saves state, and folds results into the local
aggregator — measuring per-task wall time for the workload estimator.

``speed_model`` implements the paper's Appendix-A protocol for benchmarking
scheduling under heterogeneous / unstable devices on homogeneous hardware: a
per-(executor, round) slowdown ratio η_k(r) scales the *reported* task time.
We account the scaled time in virtual time rather than sleeping, which makes
the paper's timing experiments deterministic and fast; the round engine then
computes the BSP round time as max_k Σ_task time — exactly the paper's
"server waits for the slowest executor".

Straggler backup tasks: when ``backup_fraction > 0`` the round engine
duplicates the tail of the predicted-slowest queue onto the
predicted-fastest executor (speculative duplicates resolved through the
``skip_clients`` hook below, so each client folds exactly once) — tail
mitigation at 1000-node scale where a single dead/slow host would
otherwise stall every round.

Aggregation inside ``run_queue`` uses the flat-buffer ``LocalAggregator``:
the first round builds a ``FlatLayout`` for the algorithm's payload, which
is cached here and reused for every subsequent round (flatten-once), and
client deltas fold in micro-batches of ``agg_micro_batch`` — one kernel
dispatch per B clients instead of one per pytree leaf per client.

Chunked execution (DESIGN.md §3): a *chunk* — a slice of the queue run as
its own span via ``run_queue(<slice>, task_offset=)``, yielding its own
shippable flat partial — is the executor-side unit the event-driven engines
dispatch.  The engines drive chunks one at a time through the shared
virtual clock (lazy dispatch is what makes the DES causally correct), so
they call ``run_queue`` per chunk themselves; ``run_queue(chunk_size=,
on_partial=)`` is the self-contained streaming form of the same contract
for callers without an event loop, and delegates to the identical per-chunk
path.  The wall-clock source is injectable (``timer``; see
``core/clock.py``) so engine-equivalence tests can pin down measured
durations deterministically.

Client training itself runs through the compiled engine
(``core.client_step``): ``run_queue`` groups same-signature clients into
blocks of ``client_block`` and runs one vmapped jit-scan per block, folding
the stacked (B, ...) deltas straight into the flat aggregator
(``fold_block``) — no per-client ``ClientResult`` round-trip.  Virtual time
for a block is attributed per client (block time / B, scaled by the speed
model's η), so the workload estimator keeps seeing per-client records.  The
eager per-task path is kept for ``use_compiled_steps=False``, for ragged
clients, and for rounds with a pending ``fail_at`` injection (task-index
granularity must stay exact there).

Device pinning (DESIGN.md §8): ``device=`` pins the executor to one local
JAX device — the broadcast payload is committed there once per round, the
client-step executables compile per device (``engine_for(algorithm,
device)``), client states load onto / stay resident on it, the flat
aggregator folds there, and the emitted partial ships device-resident.  A
pinned executor also dispatches *steady-state* blocks without blocking
(``nonblocking``): once a (signature, B) block cost has been measured, the
cached cost stands in for the wall measurement and the device computation is
left in flight — K pinned executors driven from one Python thread then
genuinely overlap on K devices, which is where the device-count speedup
comes from.  Virtual-time semantics are unchanged: the cached cost is
exactly what the running-min filter would have converged to, and under a
``TickTimer`` both paths measure identical durations (every same-shaped
span contains the same number of timer calls), so the K-device parity tests
stay bit-exact.

Stacked-batch cache: stacking a client's batches (host stack + transfer)
repeats every round in the vanilla path; ``batch_cache_bytes`` bounds an
LRU cache of per-client stacked (batches, mask) arrays resident on the
executor's device, so steady-state rounds re-use them and the block stack
runs on-device (``jnp.stack``).
"""
from __future__ import annotations

import time
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import client_step
from repro.core.aggregation import ClientResult, LocalAggregator, Op
from repro.core.algorithms import ClientData, FLAlgorithm
from repro.core.scheduler import ClientTask
from repro.core.state_manager import ClientStateManager
from repro.core.workload import RunRecord


SpeedModel = Callable[[int, int], float]   # (executor, round) -> eta >= 0


def homogeneous(executor: int, rnd: int) -> float:
    return 0.0


def hetero_gpus(ratios: Dict[int, float]) -> SpeedModel:
    """Fixed per-executor slowdown ratios η_k (paper Appendix A, Hete. GPU)."""
    return lambda k, r: ratios.get(k, 0.0)


def dynamic_env(n_executors: int, total_rounds: int) -> SpeedModel:
    """Unstable devices: η_k(r) = 1 + cos(3.14 r / R + k) (paper Appendix A)."""
    import math

    def eta(k: int, r: int) -> float:
        return 1.0 + math.cos(3.14 * r / max(total_rounds, 1) + k)

    return eta


@dataclass
class ExecutorReport:
    executor: int
    partial: Dict[str, Any]
    records: List[RunRecord]
    virtual_time: float          # Σ per-task simulated time (BSP makespan input)
    wall_time: float
    n_tasks: int
    completed_clients: List[int] = field(default_factory=list)
    # achieved wire size of the shipped partial (set by the engines when a
    # NetworkModel prices uploads; 0 = not measured)
    wire_bytes: int = 0
    # jit compiles observed while this report ran (jax.monitoring listener
    # in client_step) — host-side cost attribution, process-local: warm jit
    # caches legitimately zero it, so it never enters trace determinism
    compiles: int = 0


class SequentialExecutor:
    """One Parrot device (a GPU in the paper; a mesh slice on TPU)."""

    def __init__(self, executor_id: int, algorithm: FLAlgorithm,
                 state_manager: Optional[ClientStateManager] = None,
                 speed_model: SpeedModel = homogeneous,
                 use_agg_kernel: bool = False,
                 agg_micro_batch: int = 16,
                 use_compiled_steps: bool = True,
                 client_block: int = 8,
                 fail_at: Optional[Tuple[int, int]] = None,
                 timer: Optional[Callable[[], float]] = None,
                 device: Optional[Any] = None,
                 nonblocking: Optional[bool] = None,
                 batch_cache_bytes: int = 128 << 20):
        self.id = executor_id
        self.algorithm = algorithm
        self.state_manager = state_manager
        self.speed_model = speed_model
        self.use_agg_kernel = use_agg_kernel
        self.agg_micro_batch = agg_micro_batch
        self.use_compiled_steps = use_compiled_steps
        self.client_block = max(1, int(client_block))
        # device pin (core/placement.py): None = process default device
        # (the pre-multi-device behaviour, bit-for-bit)
        self.device = device
        # non-blocking steady-state dispatch only makes sense when pinned
        # (unpinned executors all share the default device anyway)
        self.nonblocking = (device is not None if nonblocking is None
                            else bool(nonblocking))
        # LRU cache of per-client stacked (batches, mask), device-resident
        # when pinned; 0 disables
        self.batch_cache_bytes = int(batch_cache_bytes)
        self._batch_cache: "OrderedDict[int, Tuple[Any, Any, Any, int]]" = \
            OrderedDict()
        self._batch_cache_used = 0
        # whole-block stacks for the gang path (repeated cohorts re-use the
        # assembled (B, ...) arrays; shares the byte budget above).  Not
        # kept on donating backends — the block jit would invalidate them.
        self._block_stack_cache: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self._payload_cache = client_step.PlacedCache()
        # injectable wall-clock source (core/clock.py): the engine
        # equivalence tests swap in a deterministic TickTimer so measured
        # durations become a pure function of the code path taken
        self.timer = timer or time.perf_counter
        self._layout_cache = None   # FlatLayout, computed once, reused per round
        # steady-state block cost per (signature, B): running minimum of
        # clean measurements — virtual time stays deterministic-ish on a
        # noisy shared host, as the paper's Appendix-A protocol intends
        self._block_cost: Dict[Any, float] = {}
        # per-client batch signature, keyed on the ClientData identity (a
        # weakref, so a swapped dataset re-keys and a recycled id() cannot
        # alias): the walk is O(n_batches x n_leaves) and must not repeat
        # every round
        self._sig_cache: Dict[int, Tuple[Any, Any]] = {}
        # fault-injection hook for the fault-tolerance tests:
        # (round, task_index) at which this executor dies.  Round -1 is a
        # wildcard (any round); see ``fail_pending``.  Scheduled fault plans
        # (core/faults.py) are the first-class path — this remains the
        # task-index-granular escape hatch.
        self.fail_at = fail_at

    def fail_pending(self, rnd: int) -> bool:
        """A ``fail_at`` injection is armed for round ``rnd`` (round -1
        wildcards to every round).  The single definition of the wildcard —
        ``run_queue``'s eager-path switch and the gang-dispatch eligibility
        check must agree, or a gang wave could run a round the injection
        was due to interrupt at task granularity."""
        return self.fail_at is not None and self.fail_at[0] in (rnd, -1)

    # ------------------------------------------------------------- device
    def set_device(self, device: Optional[Any]) -> None:
        """Re-pin the executor (placement remap after a device failure).
        Device-resident caches are dropped; measured block costs survive
        (they describe the computation, not the silicon it sat on)."""
        if device is self.device:
            return
        self.device = device
        self._batch_cache.clear()
        self._block_stack_cache.clear()
        self._batch_cache_used = 0
        self._payload_cache.clear()
        if self.nonblocking and device is None:
            self.nonblocking = False

    def _place_payload(self, payload: Dict) -> Dict:
        """Commit the broadcast payload to the executor's device ONCE per
        payload object (engines broadcast one object per round/version;
        chunks of the same round reuse the committed copy).  This covers
        the eager path too; the engine's own ``_commit_payload`` memo then
        sees the placed object and its walk is a one-time no-op."""
        if self.device is None:
            return payload
        return self._payload_cache.get(
            (payload,), lambda: jax.device_put(payload, self.device))

    def _prep_batches(self, client: int, data: ClientData) -> Tuple[Any, Any]:
        """The client's stacked (batches, mask), served from the
        device-resident LRU cache (capped at ``batch_cache_bytes``)."""
        hit = self._batch_cache.get(client)
        if hit is not None and hit[0]() is data:
            self._batch_cache.move_to_end(client)
            return hit[1], hit[2]
        stacked, mask = client_step.stack_batches(data, assume_uniform=True)
        if self.device is not None:
            stacked = jax.device_put(stacked, self.device)
            mask = jax.device_put(mask, self.device)
        if self.batch_cache_bytes <= 0:
            return stacked, mask
        nbytes = int(mask.nbytes) + sum(
            int(x.nbytes) for x in jax.tree.leaves(stacked))
        if hit is not None:          # stale entry (dataset swapped)
            self._batch_cache_used -= self._batch_cache.pop(client)[3]
        self._batch_cache[client] = (weakref.ref(data), stacked, mask, nbytes)
        self._batch_cache_used += nbytes
        self._evict_to_budget()
        return stacked, mask

    def _evict_to_budget(self) -> None:
        """Shrink the shared byte budget across BOTH stacked-batch caches:
        cohort block stacks go first (they are speculative — a cohort that
        never repeats is dead weight, and per-client entries can rebuild
        them), then per-client LRU entries down to the last one."""
        while self._batch_cache_used > self.batch_cache_bytes:
            if self._block_stack_cache:
                self._batch_cache_used -= \
                    self._block_stack_cache.popitem(last=False)[1][3]
            elif len(self._batch_cache) > 1:
                self._batch_cache_used -= \
                    self._batch_cache.popitem(last=False)[1][3]
            else:
                break

    def _prep_block_stack(self, block: List[ClientTask],
                          data_by_client: Dict[int, ClientData],
                          B_pad: int) -> Tuple[Any, Any]:
        """The block's padded (B_pad, ...) stacked batches + masks, cached
        by cohort: repeated schedules (full participation, stable LPT
        splits) re-dispatch the identical block every round, so the
        assembled device arrays are re-served instead of re-stacked.
        Falls through to a fresh stack on donating backends (the block jit
        consumes its batch buffers there) or when caching is disabled."""
        cacheable = (self.batch_cache_bytes > 0
                     and jax.default_backend() not in ("tpu", "gpu"))
        key = (tuple(t.client for t in block), B_pad)
        if cacheable:
            hit = self._block_stack_cache.get(key)
            if hit is not None and all(
                    w() is data_by_client[c]
                    for c, w in zip(key[0], hit[0])):
                self._block_stack_cache.move_to_end(key)
                return hit[1], hit[2]
        cp = [self._prep_batches(t.client, data_by_client[t.client])
              for t in block]
        cp = cp + [cp[0]] * (B_pad - len(block))
        eng = client_step.engine_for(self.algorithm, self.device)
        stacked, mask = eng._stack_jit([p[0] for p in cp],
                                       [p[1] for p in cp])
        if cacheable:
            nbytes = int(mask.nbytes) + sum(
                int(x.nbytes) for x in jax.tree.leaves(stacked))
            refs = tuple(weakref.ref(data_by_client[c]) for c in key[0])
            if key in self._block_stack_cache:
                self._batch_cache_used -= self._block_stack_cache.pop(key)[3]
            self._block_stack_cache[key] = (refs, stacked, mask, nbytes)
            self._batch_cache_used += nbytes
            self._evict_to_budget()
        return stacked, mask

    def run_queue(self, rnd: int, tasks: List[ClientTask], payload: Dict,
                  data_by_client: Dict[int, ClientData],
                  skip_clients: Optional[set] = None,
                  chunk_size: Optional[int] = None,
                  on_partial: Optional[Callable[["ExecutorReport"], None]]
                  = None,
                  task_offset: int = 0) -> ExecutorReport:
        """Run a task queue (``Device_Executes``).

        ``chunk_size`` switches to chunked *streaming* execution: the queue
        is cut into chunks of at most that many tasks, each chunk runs as
        its own span (own LocalAggregator, so its partial is shippable on
        its own) and is emitted through ``on_partial`` the moment it
        completes.  The returned report merges the chunk reports; its
        ``partial`` is the merge of the chunk partials (identical aggregate
        to one unchunked run).  The engines themselves call this method once
        per chunk with ``task_offset`` instead (their event loop owns the
        interleaving) — both routes run the same per-chunk code.

        ``task_offset`` keeps ``fail_at``'s task index global to the
        executor's dispatch stream when the caller passes slices of it.
        """
        if chunk_size is not None:
            return self._run_chunked(rnd, tasks, payload, data_by_client,
                                     skip_clients, chunk_size, on_partial,
                                     task_offset)
        agg = LocalAggregator(self.algorithm.ops(),
                              use_kernel=self.use_agg_kernel,
                              micro_batch=self.agg_micro_batch,
                              layout=self._layout_cache,
                              device=self.device)
        payload = self._place_payload(payload)
        records: List[RunRecord] = []
        completed: List[int] = []
        t_start = self.timer()
        c0 = client_step.compile_events()
        eta = self.speed_model(self.id, rnd)
        # fail_at is task-index-granular: a round with a pending injection
        # runs the eager per-task loop so the index semantics stay exact
        # (round -1 is a wildcard: fire at that dispatch index in any round
        # — the async engine's dispatch stream spans update boundaries)
        if self.use_compiled_steps and not self.fail_pending(rnd):
            vtime = self._run_blocked(rnd, tasks, payload, data_by_client,
                                      skip_clients, agg, records, completed,
                                      eta)
        else:
            vtime = self._run_eager(rnd, tasks, payload, data_by_client,
                                    skip_clients, agg, records, completed,
                                    eta, task_offset)
        self._layout_cache = agg.layout     # flatten-once across rounds
        return ExecutorReport(
            executor=self.id, partial=agg.partial(), records=records,
            virtual_time=vtime, wall_time=self.timer() - t_start,
            n_tasks=len(completed), completed_clients=completed,
            compiles=client_step.compile_events() - c0)

    def _run_chunked(self, rnd, tasks, payload, data_by_client, skip_clients,
                     chunk_size, on_partial, task_offset) -> ExecutorReport:
        from repro.core.aggregation import merge_partials
        from repro.core.scheduler import split_chunks
        merged: Optional[Dict] = None
        records: List[RunRecord] = []
        completed: List[int] = []
        vtime = wall = 0.0
        compiles = 0
        offset = task_offset
        for chunk in split_chunks(tasks, chunk_size):
            rep = self.run_queue(rnd, chunk, payload, data_by_client,
                                 skip_clients, task_offset=offset)
            offset += len(chunk)
            if on_partial is not None:
                on_partial(rep)
            merged = merge_partials(merged, rep.partial)
            records.extend(rep.records)
            completed.extend(rep.completed_clients)
            vtime += rep.virtual_time
            wall += rep.wall_time
            compiles += rep.compiles
        return ExecutorReport(
            executor=self.id, partial=merged if merged is not None else
            LocalAggregator(self.algorithm.ops()).partial(),
            records=records, virtual_time=vtime, wall_time=wall,
            n_tasks=len(completed), completed_clients=completed,
            compiles=compiles)

    # ------------------------------------------------------------------
    def _run_eager(self, rnd, tasks, payload, data_by_client, skip_clients,
                   agg, records, completed, eta, task_offset=0) -> float:
        """Legacy per-task reference path (one eager client_update per
        task; also the fault-injection path)."""
        vtime = 0.0
        for i, task in enumerate(tasks, start=task_offset):
            if self.fail_at is not None and self.fail_at[1] == i \
                    and self.fail_pending(rnd):
                raise ExecutorFailure(
                    self.id, rnd, i, device=self.device,
                    chunk=(task_offset, task_offset + len(tasks)),
                    vtime=vtime)
            if skip_clients and task.client in skip_clients:
                continue  # result already produced by a backup replica
            t0 = self.timer()
            state = None
            if self.algorithm.stateful:
                state = self.state_manager.load(task.client)
                if state is None:
                    state = self.algorithm.client_init_state(payload["params"])
            result, new_state = self.algorithm.client_update(
                payload, data_by_client[task.client], state)
            if self.algorithm.stateful and new_state is not None:
                self.state_manager.save(task.client, new_state)
            agg.fold(result)
            completed.append(task.client)
            measured = self.timer() - t0
            simulated = measured * (1.0 + eta)
            vtime += simulated
            records.append(RunRecord(round=rnd, client=task.client,
                                     executor=self.id,
                                     n_samples=task.n_samples,
                                     time=simulated))
        return vtime

    # ------------------------------------------------------------------
    def _plan_blocks(self, tasks: List[ClientTask],
                     data_by_client: Dict[int, ClientData]
                     ) -> List[Tuple[Tuple, List[ClientTask]]]:
        """Group same-signature clients into blocks of ``client_block``
        (first-seen group order; queue order within a group).  Ragged
        clients get singleton eager blocks."""
        groups: Dict[Any, List[ClientTask]] = {}
        order: List[Any] = []
        for t in tasks:
            data = data_by_client[t.client]
            cached = self._sig_cache.get(t.client)
            if cached is not None and cached[0]() is data:
                sig = cached[1]
            else:
                sig = client_step.batch_signature(data)
                self._sig_cache[t.client] = (weakref.ref(data), sig)
            key = ("eager", t.client) if sig is None else ("block", sig)
            if key not in groups:
                groups[key] = []
                order.append(key)
            groups[key].append(t)
        if len(self._sig_cache) > 4096:
            # streamed populations cycle through many distinct clients; an
            # entry whose ClientData was evicted (dead weakref) can never
            # hit again, so shed those instead of growing O(M)
            self._sig_cache = {c: v for c, v in self._sig_cache.items()
                               if v[0]() is not None}
        blocks: List[Tuple[Any, List[ClientTask]]] = []
        for key in order:
            q = groups[key]
            if key[0] == "eager":
                blocks.append((key, q))
            else:
                for i in range(0, len(q), self.client_block):
                    blocks.append((key, q[i:i + self.client_block]))
        return blocks

    def _run_blocked(self, rnd, tasks, payload, data_by_client, skip_clients,
                     agg, records, completed, eta) -> float:
        """Compiled-engine path: one vmapped jit-scan per block, stacked
        deltas folded straight into the flat aggregator.  Device-pinned
        executors serve stacked batches from the on-device LRU cache and
        dispatch steady-state blocks without blocking (the cached block
        cost stands in for the measurement), so the device computation is
        left in flight while the caller moves on to another executor."""
        engine = client_step.engine_for(self.algorithm, self.device)
        todo = [t for t in tasks
                if not (skip_clients and t.client in skip_clients)]
        vtime = 0.0
        blocks = self._plan_blocks(todo, data_by_client)
        for bi, (key, block) in enumerate(blocks):
            kind = key[0]
            if self.algorithm.stateful and self.state_manager is not None \
                    and bi + 1 < len(blocks):
                # schedule-keyed look-ahead: stage the NEXT block's state
                # shards into the manager's RAM tier while this block's
                # compute occupies the device — the load overlaps compute
                # on the virtual clock (prefetch is outside the timed span
                # and never perturbs the per-client LRU)
                self.state_manager.prefetch(
                    [t.client for t in blocks[bi + 1][1]])
            compiles0 = client_step.compile_events()
            states = None
            if self.algorithm.stateful:
                states = self.state_manager.load_many(
                    [t.client for t in block], device=self.device)
                states = [s if s is not None
                          else self.algorithm.client_init_state(
                              payload["params"])
                          for s in states]
            datas = [data_by_client[t.client] for t in block]

            # the timed span is exactly the client compute (stack + engine
            # + sync on the outputs; jax dispatch is async, so without the
            # sync it would measure host dispatch, not training); state IO
            # and the aggregation fold stay outside so the compile
            # re-measure below can reproduce the identical span.  The
            # stacked-batch prep runs lazily INSIDE the span — the cache
            # makes repeat rounds cheap, but the cost that IS paid must
            # show up in the measured block time (virtual-time accounting
            # on the unpinned default path stays faithful to the work
            # done)
            preps = None

            def run_engine(sync: bool = True):
                nonlocal preps
                if preps is None:
                    preps = [self._prep_batches(t.client,
                                                data_by_client[t.client])
                             for t in block]
                if len(block) == 1:
                    res, st = engine.run_client(
                        payload, datas[0], states[0] if states else None,
                        assume_uniform=True, prep=preps[0])
                    if sync:
                        jax.block_until_ready((res.payload, st))
                    return res, st
                out = engine.run_block(payload, datas, states, preps=preps)
                if sync:
                    jax.block_until_ready(out)
                return out

            cost_key = (key[1], len(block)) if kind != "eager" else None
            steady = (self.nonblocking and cost_key is not None
                      and cost_key in self._block_cost)
            t0 = self.timer()
            if kind == "eager":           # ragged batches: reference path
                assert len(block) == 1
                result, new_state = self.algorithm.client_update(
                    payload, datas[0], states[0] if states else None)
                new_states = [new_state]
                measured = self.timer() - t0
            elif steady:
                # non-blocking dispatch: the executable for this
                # (signature, B) exists (its cost was measured), so no
                # compile can hide in the span; the device crunches while
                # the host dispatches the next executor's chunk
                out = run_engine(sync=False)
                new_states = None
                self.timer()              # span close (call parity with
                measured = self._block_cost[cost_key]   # the synced path)
            else:
                out = run_engine()
                new_states = None
                measured = self.timer() - t0
                # a first-seen shape just paid its one-off compile inside
                # the timed span; re-run the (pure) computation once,
                # result discarded, so virtual time and the workload
                # estimator see steady-state throughput, not compile spikes
                if client_step.compile_events() > compiles0:
                    t0 = self.timer()
                    run_engine()
                    measured = self.timer() - t0

            if kind == "eager":
                agg.fold(result)
            elif len(block) == 1:
                result, new_state = out
                agg.fold(result)
                new_states = [new_state]
            else:
                stacked, new_states = out
                agg.fold_block(stacked,
                               [float(d.n_samples) for d in datas])
                if new_states is None:
                    new_states = [None] * len(block)
            if self.algorithm.stateful:
                self.state_manager.save_many(
                    {t.client: s for t, s in zip(block, new_states)
                     if s is not None},
                    keep_device=self.device is not None)
            completed.extend(t.client for t in block)
            if cost_key is not None and not steady:
                # steady-state filter: host-noise spikes (GC, co-tenant
                # load) would otherwise dominate the BSP makespan now that
                # a round is a handful of coarse blocks instead of many
                # small tasks
                measured = min(measured,
                               self._block_cost.get(cost_key, measured))
                self._block_cost[cost_key] = measured
            # per-client virtual-time attribution: the block's measured time
            # splits evenly across its B clients (same batch bucket => same
            # compute), each scaled by the speed model's η
            simulated = measured * (1.0 + eta)
            per_client = simulated / len(block)
            vtime += simulated
            records.extend(
                RunRecord(round=rnd, client=t.client, executor=self.id,
                          n_samples=t.n_samples, time=per_client)
                for t in block)
        return vtime


def run_queues_ganged(executors: Dict[int, "SequentialExecutor"], rnd: int,
                      queues: Dict[int, List[ClientTask]], payload: Dict,
                      data_by_client: Dict[int, ClientData],
                      placement, skip_map: Optional[Dict[int, set]] = None
                      ) -> Optional[Dict[int, "ExecutorReport"]]:
    """SPMD gang dispatch of a whole BSP round (DESIGN.md §8).

    Per-device dispatches serialize inside the CPU PJRT client (virtual
    host devices share one execute thread), so the per-executor
    non-blocking path cannot realise wall-clock overlap there.  This path
    can: when every live executor is pinned to its own device and their
    queues plan into aligned block *waves* — wave i holds every executor's
    i-th block, all sharing one (signature, padded-B) bucket — each wave
    runs as ONE sharded execution over the placement mesh
    (``ClientStepEngine.run_blocks_sharded``), which XLA fans out with one
    thread per device.  Folds, state IO and virtual-time accounting stay
    per-executor on the per-device output shards, so reports are identical
    in content and order to the per-executor path (and bit-identical on
    CPU: the local shard program equals the single-device block program).

    Returns executor-id -> ExecutorReport, or None when the round is not
    gangable (heterogeneous waves, ragged/eager clients, a pending
    ``fail_at`` injection, executors sharing devices, K == 1, ...) — the
    caller then falls back to the ordinary per-executor dispatch."""
    if placement is None or len(queues) < 2:
        return None
    live = sorted(queues)
    exs = [executors[k] for k in live]
    devs = [ex.device for ex in exs]
    if any(d is None for d in devs) or \
            len({d.id for d in devs}) != len(devs):
        return None
    mesh = placement.mesh()
    if [d.id for d in mesh.devices.flat] != [d.id for d in devs]:
        return None
    algo = exs[0].algorithm
    timer = exs[0].timer
    for ex in exs:
        if (not ex.use_compiled_steps or ex.algorithm is not algo
                or ex.timer is not timer or ex.fail_pending(rnd)):
            # gang waves are timed once on the shared timer; executors with
            # private timers keep per-executor measurement semantics via
            # the fallback path
            return None

    # ---- plan waves -----------------------------------------------------
    plans = []
    for k, ex in zip(live, exs):
        todo = [t for t in queues[k]
                if not (skip_map and t.client in skip_map.get(k, ()))]
        plans.append(ex._plan_blocks(todo, data_by_client))
    n_waves = len(plans[0])
    if any(len(p) != n_waves for p in plans):
        return None
    for i in range(n_waves):
        keys = {(p[i][0], client_step._bucket(len(p[i][1]))) for p in plans}
        if len(keys) != 1 or next(iter(keys))[0][0] != "block":
            return None

    # ---- run ------------------------------------------------------------
    engine = client_step.engine_for(algo)       # hosts the sharded cache
    gang_c0 = client_step.compile_events()      # gang-level compile delta
    etas = [ex.speed_model(ex.id, rnd) for ex in exs]
    aggs, placed = [], []
    for ex in exs:
        aggs.append(LocalAggregator(algo.ops(), use_kernel=ex.use_agg_kernel,
                                    micro_batch=ex.agg_micro_batch,
                                    layout=ex._layout_cache,
                                    device=ex.device))
        placed.append(ex._place_payload(payload))
    records: List[List[RunRecord]] = [[] for _ in exs]
    completed: List[List[int]] = [[] for _ in exs]
    vtimes = [0.0] * len(exs)
    walls = [0.0] * len(exs)
    gang_cost = placement._gang_cost

    for i in range(n_waves):
        blocks = [p[i][1] for p in plans]
        sig = plans[0][i][0][1]
        B_pad = client_step._bucket(max(len(b) for b in blocks))
        if algo.stateful and i + 1 < n_waves:
            # stage wave i+1's state shards while wave i computes
            for j, ex in enumerate(exs):
                if ex.state_manager is not None:
                    ex.state_manager.prefetch(
                        [t.client for t in plans[j][i + 1][1]])
        preps, states = [], None
        if algo.stateful:
            states = []
        for j, (k, ex) in enumerate(zip(live, exs)):
            block = blocks[j]
            preps.append(ex._prep_block_stack(block, data_by_client, B_pad))
            if algo.stateful:
                st = ex.state_manager.load_many(
                    [t.client for t in block], device=ex.device)
                st = [s if s is not None
                      else algo.client_init_state(placed[j]["params"])
                      for s in st]
                st = st + [st[0]] * (B_pad - len(block))
                states.append(jax.tree.map(lambda *xs: jnp.stack(xs), *st))

        cost_key = (sig, B_pad, len(live))
        steady = all(ex.nonblocking for ex in exs) and cost_key in gang_cost
        compiles0 = client_step.compile_events()
        t0 = timer()
        outs = engine.run_blocks_sharded(payload, preps, states, mesh)
        if steady:
            timer()                         # span close (call parity)
            measured = gang_cost[cost_key]
        else:
            jax.block_until_ready(outs)
            measured = timer() - t0
            if client_step.compile_events() > compiles0 \
                    and jax.default_backend() == "cpu":
                # first-seen bucket paid its compile in the span: re-run
                # once from the warm cache for a steady-state measurement
                # (CPU only: on TPU/GPU the block jit donates the batch
                # buffers, so the wave's preps cannot be replayed)
                t0 = timer()
                jax.block_until_ready(
                    engine.run_blocks_sharded(payload, preps, states, mesh))
                measured = timer() - t0
            measured = min(measured, gang_cost.get(cost_key, measured))
            gang_cost[cost_key] = measured

        for j, (k, ex) in enumerate(zip(live, exs)):
            block = blocks[j]
            out_payload, new_states = outs[j]
            if B_pad > len(block):
                out_payload = jax.tree.map(lambda x: x[:len(block)],
                                           out_payload)
            aggs[j].fold_block(
                out_payload,
                [float(t.n_samples) for t in block])
            if algo.stateful and new_states is not None:
                ex.state_manager.save_many(
                    {t.client: jax.tree.map(lambda x: x[b], new_states)
                     for b, t in enumerate(block)},
                    keep_device=ex.device is not None)
            completed[j].extend(t.client for t in block)
            simulated = measured * (1.0 + etas[j])
            vtimes[j] += simulated
            walls[j] += measured
            per_client = simulated / len(block)
            records[j].extend(
                RunRecord(round=rnd, client=t.client, executor=k,
                          n_samples=t.n_samples, time=per_client)
                for t in block)

    reports = {}
    for j, (k, ex) in enumerate(zip(live, exs)):
        ex._layout_cache = aggs[j].layout
        reports[k] = ExecutorReport(
            executor=k, partial=aggs[j].partial(), records=records[j],
            virtual_time=vtimes[j], wall_time=walls[j],
            n_tasks=len(completed[j]), completed_clients=completed[j],
            # sharded waves compile once for the whole gang: the delta is
            # attributed to the first lane (host-side accounting only)
            compiles=(client_step.compile_events() - gang_c0
                      if j == 0 else 0))
    return reports


class ExecutorFailure(RuntimeError):
    """An executor died mid-dispatch.

    Carries where (device), what was in flight (the chunk's global task
    range) and when (virtual seconds into the chunk's span) — and pickles
    round-trip cleanly (``__reduce__``), so an in-flight failure can ride a
    checkpoint blob across process boundaries."""

    def __init__(self, executor: int, rnd: int, task_index: int,
                 device: Optional[Any] = None,
                 chunk: Optional[Tuple[int, int]] = None,
                 vtime: Optional[float] = None):
        # keep only the plain device id: jax Device objects don't pickle
        device = getattr(device, "id", device)
        msg = f"executor {executor} failed at round {rnd}, task {task_index}"
        detail = []
        if device is not None:
            detail.append(f"device={device}")
        if chunk is not None:
            detail.append(f"chunk=[{chunk[0]},{chunk[1]})")
        if vtime is not None:
            detail.append(f"t={vtime:.6g}s")
        if detail:
            msg += " (" + ", ".join(detail) + ")"
        super().__init__(msg)
        self.executor = executor
        self.rnd = rnd
        self.task_index = task_index
        self.device = device
        self.chunk = chunk
        self.vtime = vtime

    def __reduce__(self):
        # RuntimeError's default reduce would replay __init__ with the
        # formatted message as the sole argument; rebuild from fields so
        # pickle.loads(pickle.dumps(e)) preserves every attribute
        return (type(self), (self.executor, self.rnd, self.task_index,
                             self.device, self.chunk, self.vtime))
