"""Workload model and estimation (paper §4.3).

The per-task running time on executor ``k`` is modelled as

    T_{m,k} = N_m * t_k^sample + b_k                       (Eq. 2)

with ``t_k^sample`` and ``b_k`` fitted by least squares on *measured*
(N_m, T̂_{m,k}) pairs recorded by the executors.  The Time-Window variant
(§4.4, "Tackling Dynamic Hardware Environments") restricts the fit to the
most recent ``tau`` rounds so drifting device speeds don't poison the model.

Under the event-driven round engines (semi-sync / async) the unit of
execution is a *chunk* of tasks rather than a single client, and timing is
recorded per chunk: one :class:`RunRecord` with ``n_samples`` = the chunk's
total sample count, ``time`` = the chunk's virtual duration and ``n_tasks``
= the number of clients it covered.  Eq. 2 is linear in N, so chunk records
fit the same model (the offset ``b`` then absorbs per-chunk instead of
per-task overhead — consistent as long as predictions are made at the same
granularity, which the engines do).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class RunRecord:
    round: int
    client: int          # first client of the span (chunk records cover more)
    executor: int
    n_samples: int       # total samples in the span
    time: float
    n_tasks: int = 1     # clients covered: 1 (per-client) or chunk size


@dataclass
class WorkloadModel:
    """Fitted (t_sample, b) per executor."""
    t_sample: float
    b: float

    def predict(self, n_samples: float) -> float:
        return n_samples * self.t_sample + self.b


DEFAULT_MODEL = WorkloadModel(t_sample=1.0, b=0.0)


def fleet_average(models: Dict[int, "WorkloadModel"]
                  ) -> Optional["WorkloadModel"]:
    """Mean (t_sample, b) over the fitted executors — the stand-in for
    executors with no history yet (fresh/elastic joiners) and for
    executor-agnostic span predictions (window-fit selection, which must
    price a client before knowing where it will be scheduled).  None when
    nothing has been fitted."""
    if not models:
        return None
    return WorkloadModel(
        t_sample=sum(m.t_sample for m in models.values()) / len(models),
        b=sum(m.b for m in models.values()) / len(models))


def _lstsq(n: np.ndarray, t: np.ndarray) -> WorkloadModel:
    A = np.stack([n, np.ones_like(n)], axis=1)
    (ts, b), *_ = np.linalg.lstsq(A, t, rcond=None)
    # physical constraints: non-negative per-sample time and offset
    return WorkloadModel(t_sample=max(float(ts), 1e-9), b=max(float(b), 0.0))


def _robust_fit(n: np.ndarray, t: np.ndarray) -> WorkloadModel:
    """Least squares with one outlier-rejection pass: first-execution jit
    compiles and GC pauses produce multi-hundred-x residuals that would
    otherwise poison the model for the whole run (paper Fig. 6 assumes clean
    timings; real executors do not provide them)."""
    m = _lstsq(n, t)
    if len(n) < 6:
        return m
    resid = np.abs(t - (n * m.t_sample + m.b))
    cut = 4.0 * max(float(np.median(resid)), 1e-9)
    keep = resid <= cut
    if keep.sum() >= 4 and keep.sum() < len(n):
        m = _lstsq(n[keep], t[keep])
    return m


class WorkloadEstimator:
    """Records run times and fits Eq. 2 per executor.

    ``time_window=0`` uses all history (the paper's default); ``tau > 0``
    keeps only rounds in ``[r - tau, r - 1]``.
    """

    def __init__(self, time_window: int = 0):
        self.time_window = time_window
        self._records: Dict[int, List[RunRecord]] = collections.defaultdict(list)
        self.last_fit: Dict[int, WorkloadModel] = {}
        self.fit_time_s: float = 0.0

    def record(self, rec: RunRecord) -> None:
        self._records[rec.executor].append(rec)

    def record_many(self, recs: Iterable[RunRecord]) -> None:
        for r in recs:
            self.record(r)

    def executors(self) -> List[int]:
        return sorted(self._records)

    def n_records(self, executor: Optional[int] = None) -> int:
        if executor is not None:
            return len(self._records.get(executor, ()))
        return sum(len(v) for v in self._records.values())

    def fit(self, current_round: int) -> Dict[int, WorkloadModel]:
        """Least-squares fit of Eq. 2 for each executor (paper Alg. 3,
        Estimate_Workload)."""
        import time as _time
        t0 = _time.perf_counter()
        models: Dict[int, WorkloadModel] = {}
        lo = current_round - self.time_window if self.time_window else -1
        for k, recs in self._records.items():
            use = [r for r in recs if r.round >= lo] if self.time_window else recs
            if len(use) < 2:
                # too little data: reuse the previous fit if any, otherwise
                # leave the executor absent so the scheduler substitutes the
                # fleet average (a DEFAULT here starves fresh executors)
                if k in self.last_fit:
                    models[k] = self.last_fit[k]
                continue
            n = np.asarray([r.n_samples for r in use], np.float64)
            t = np.asarray([r.time for r in use], np.float64)
            if np.ptp(n) < 1e-12:
                # degenerate: all tasks same size -> pure-offset model
                models[k] = WorkloadModel(t_sample=float(np.median(t) / max(n[0], 1.0)),
                                          b=0.0)
                continue
            models[k] = _robust_fit(n, t)
        self.last_fit = models
        self.fit_time_s = _time.perf_counter() - t0
        return models

    def estimation_error(self, models: Dict[int, WorkloadModel],
                         recs: Iterable[RunRecord]) -> float:
        """Mean relative |predicted - measured| / measured (paper Fig. 6/11)."""
        errs = []
        for r in recs:
            m = models.get(r.executor)
            if m is None or r.time <= 0:
                continue
            errs.append(abs(m.predict(r.n_samples) - r.time) / r.time)
        return float(np.mean(errs)) if errs else float("nan")
