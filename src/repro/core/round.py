"""Parrot round engine — Algorithm 2 (``Server_Executes``).

One ``ParrotServer`` owns: the FL algorithm, the heterogeneity-aware
scheduler + workload estimator, K sequential executors, the client state
managers, a Communicator, and (optionally) a checkpoint manager and a delta
compressor.  ``run_round`` is the paper's loop:

  select clients → Task_Schedule (Alg. 3) → broadcast Θ^r + queues →
  Device_Executes on each executor → collect K partials (one trip each) →
  GlobalAggregate → server update.

Round time under the BSP/SPMD model is ``max_k Σ_{m∈M_k} T̂_{m,k}`` — the
makespan the scheduler minimises.  Executor failures mid-round are handled by
re-running the dead executor's *remaining* queue on the surviving executors
(clients are idempotent within a round: state saves are keyed per round) and
shrinking K for subsequent rounds (elastic membership).

``mode="parrot"`` uses hierarchical aggregation; ``mode="flat"`` emulates
SD-Dist/FA-Dist accounting (every client result shipped to the server
individually) for the Table-1 comparison benchmarks.
"""
from __future__ import annotations

import concurrent.futures as cf
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.comm.base import Communicator
from repro.comm.local import LocalComm
from repro.core.aggregation import (ClientResult, LocalAggregator, Op,
                                    flat_aggregate, global_aggregate,
                                    payload_bytes)
from repro.core.algorithms import ClientData, FLAlgorithm
from repro.core.executor import (ExecutorFailure, ExecutorReport,
                                 SequentialExecutor)
from repro.core.scheduler import ClientTask, ParrotScheduler, Schedule
from repro.core.workload import WorkloadEstimator


@dataclass
class RoundMetrics:
    round: int
    makespan: float               # BSP round time (max executor virtual time)
    wall_time: float
    schedule_time: float
    estimate_time: float
    predicted_makespan: float
    comm_bytes: int
    comm_trips: int
    n_clients: int
    n_executors: int
    estimation_error: float = float("nan")
    failures: int = 0
    extra: Dict[str, float] = field(default_factory=dict)


class ParrotServer:
    def __init__(self, *, params: Any, algorithm: FLAlgorithm,
                 executors: Sequence[SequentialExecutor],
                 data_by_client: Dict[int, ClientData],
                 clients_per_round: int,
                 scheduler_policy: str = "parrot",
                 time_window: int = 0,
                 warmup_rounds: int = 1,
                 comm: Optional[Communicator] = None,
                 compressor: Optional[Any] = None,
                 checkpoint_manager: Optional[Any] = None,
                 mode: str = "parrot",
                 parallel_dispatch: bool = False,
                 overlap_scheduling: bool = False,
                 backup_fraction: float = 0.0,
                 seed: int = 0):
        self.params = params
        self.algorithm = algorithm
        self.executors: Dict[int, SequentialExecutor] = {e.id: e for e in executors}
        self.data_by_client = data_by_client
        self.clients_per_round = clients_per_round
        self.estimator = WorkloadEstimator(time_window=time_window)
        self.scheduler = ParrotScheduler(self.estimator,
                                         warmup_rounds=warmup_rounds,
                                         policy=scheduler_policy)
        self.comm = comm or LocalComm()
        self.compressor = compressor
        self.checkpoint_manager = checkpoint_manager
        self.mode = mode
        self.parallel_dispatch = parallel_dispatch
        self.overlap_scheduling = overlap_scheduling
        self.backup_fraction = backup_fraction
        self._next_tasks: Optional[List[ClientTask]] = None
        self.server_state = algorithm.server_init(params)
        self.rng = np.random.default_rng(seed)
        self.round = 0
        self.history: List[RoundMetrics] = []
        self._pending_schedule: Optional[Schedule] = None

    # ------------------------------------------------------------------
    def select_clients(self) -> List[ClientTask]:
        ids = self.rng.choice(sorted(self.data_by_client),
                              size=min(self.clients_per_round,
                                       len(self.data_by_client)),
                              replace=False)
        return [ClientTask(int(c), self.data_by_client[int(c)].n_samples)
                for c in ids]

    # ------------------------------------------------------------------
    def _plan_backups(self, schedule: Schedule
                      ) -> Tuple[Dict[int, Set[int]], int]:
        """Speculative backup tasks (tail mitigation at 1000-node scale):
        duplicate the tail of the predicted-slowest queue onto the
        predicted-fastest executor and tell the slow executor to skip those
        clients (the ``skip_clients`` hook) — each client still folds exactly
        once, so aggregation stays exact, and if either executor dies the
        normal leftover re-run covers the duplicated clients."""
        if self.backup_fraction <= 0 or len(self.executors) < 2:
            return {}, 0
        models = self.estimator.last_fit

        def load(k: int) -> float:
            m = models.get(k)
            q = schedule.queue(k)
            if m is not None:
                return sum(m.predict(t.n_samples) for t in q)
            return float(sum(t.n_samples for t in q))

        ks = list(self.executors)
        slow = max(ks, key=load)
        fast = min(ks, key=load)
        queue = schedule.queue(slow)
        if slow == fast or not queue:
            return {}, 0
        n = min(len(queue), max(1, int(round(self.backup_fraction
                                             * len(queue)))))
        tail = queue[-n:]
        schedule.assignment.setdefault(fast, []).extend(tail)
        return {slow: {t.client for t in tail}}, len(tail)

    # ------------------------------------------------------------------
    def _dispatch(self, rnd: int, schedule: Schedule, payload: Dict,
                  skip_map: Optional[Dict[int, Set[int]]] = None
                  ) -> Tuple[List[ExecutorReport], int]:
        live = list(self.executors)
        self.comm.broadcast(payload, live, tag="broadcast")
        reports: List[ExecutorReport] = []
        failed: List[int] = []
        done_clients: set = set()

        def run(k: int) -> ExecutorReport:
            return self.executors[k].run_queue(
                rnd, schedule.queue(k), payload, self.data_by_client,
                skip_clients=(skip_map or {}).get(k))

        if self.parallel_dispatch:
            with cf.ThreadPoolExecutor(max_workers=len(live)) as pool:
                futs = {pool.submit(run, k): k for k in live}
                for fut in cf.as_completed(futs):
                    k = futs[fut]
                    try:
                        reports.append(fut.result())
                    except ExecutorFailure as e:
                        failed.append(k)
        else:
            for k in live:
                try:
                    reports.append(run(k))
                except ExecutorFailure:
                    failed.append(k)

        # ---- fault handling: re-run failed queues on the survivors -------
        if failed:
            for rep in reports:
                done_clients.update(rep.completed_clients)
            survivors = [k for k in live if k not in failed]
            if not survivors:
                raise RuntimeError("all executors failed")
            # dedup by client: with backup duplicates a task can sit in two
            # failed queues at once and must still re-run (and fold) once
            leftovers: List[ClientTask] = []
            for k in failed:
                for t in schedule.queue(k):
                    if t.client not in done_clients:
                        done_clients.add(t.client)
                        leftovers.append(t)
                del self.executors[k]          # elastic K shrink
            for i, t in enumerate(leftovers):  # round-robin retry placement
                k = survivors[i % len(survivors)]
                rep = self.executors[k].run_queue(
                    rnd, [t], payload, self.data_by_client)
                reports.append(rep)

        # the partial that reaches aggregation is the one that crossed the
        # wire: compress once, ship, and aggregate the decompressed copy
        # (error-feedback residuals and the aggregated values stay in sync)
        for rep in reports:
            self.comm.executor_send(rep.executor,
                                    self._maybe_compress(rep.partial),
                                    tag="partial")
            rep.partial = self._maybe_decompress(
                self.comm.recv_from_executor(rep.executor, tag="partial"))
        return reports, len(failed)

    def _maybe_compress(self, partial: Dict) -> Dict:
        if self.compressor is None:
            return partial
        return self.compressor.compress_partial(partial)

    def _maybe_decompress(self, partial: Dict) -> Dict:
        if self.compressor is None:
            return partial
        return self.compressor.decompress_partial(partial)

    # ------------------------------------------------------------------
    def run_round(self) -> RoundMetrics:
        rnd = self.round
        t_wall = time.perf_counter()
        if self._next_tasks is not None:
            tasks, self._next_tasks = self._next_tasks, None
        else:
            tasks = self.select_clients()

        # compute-comm overlap: the schedule for this round may have been
        # prepared while the previous round's global reduce was in flight
        # (host-side O(K·M_p) work hidden behind the collective).
        if self._pending_schedule is not None:
            schedule, overlapped = self._pending_schedule, True
            self._pending_schedule = None
        else:
            schedule, overlapped = self.scheduler.schedule(
                rnd, tasks, list(self.executors)), False

        payload = self.algorithm.broadcast_payload(self.params,
                                                   self.server_state)
        skip_map, n_backups = self._plan_backups(schedule)
        reports, n_failed = self._dispatch(rnd, schedule, payload, skip_map)

        # ---- aggregation ------------------------------------------------
        # overlap: prepare round r+1's schedule "while the reduce is in
        # flight" (before the global_aggregate below consumes the partials)
        if self.overlap_scheduling:
            self.estimator.record_many(
                [rec for r in reports for rec in r.records])
            self._next_tasks = self.select_clients()
            self._pending_schedule = self.scheduler.schedule(
                rnd + 1, self._next_tasks, list(self.executors))

        partials = [r.partial for r in reports]   # already the wire copies
        ops = self.algorithm.ops()
        agg = global_aggregate(partials, ops)
        agg["_n_selected"] = sum(r.n_tasks for r in reports)
        self.params, self.server_state = self.algorithm.server_update(
            self.params, agg, self.server_state, len(self.data_by_client))

        # ---- bookkeeping --------------------------------------------------
        records = [rec for r in reports for rec in r.records]
        err = float("nan")
        if self.estimator.last_fit:
            err = self.estimator.estimation_error(self.estimator.last_fit,
                                                  records)
        if not self.overlap_scheduling:   # overlap path already recorded them
            self.estimator.record_many(records)
        makespan = max((r.virtual_time for r in reports), default=0.0)
        stats = self.comm.stats.reset()
        metrics = RoundMetrics(
            round=rnd, makespan=makespan,
            wall_time=time.perf_counter() - t_wall,
            schedule_time=0.0 if overlapped else schedule.schedule_time_s,
            estimate_time=0.0 if overlapped else schedule.estimate_time_s,
            predicted_makespan=schedule.predicted_makespan,
            comm_bytes=stats.bytes_sent, comm_trips=stats.trips,
            n_clients=len(tasks), n_executors=len(self.executors),
            estimation_error=err, failures=n_failed,
            extra={"backup_tasks": float(n_backups)})
        self.history.append(metrics)
        self.round += 1

        if self.checkpoint_manager is not None:
            self.checkpoint_manager.maybe_save(self)
        return metrics

    def run(self, n_rounds: int) -> List[RoundMetrics]:
        return [self.run_round() for _ in range(n_rounds)]


def run_flat_reference(params, algorithm: FLAlgorithm,
                       data_by_client: Dict[int, ClientData],
                       clients_per_round: int, n_rounds: int, seed: int = 0,
                       state_store: Optional[Dict[int, Any]] = None):
    """Single-process original-FL reference (SP scheme): the ground truth the
    hierarchical scheme must match (used by the Fig. 4 equivalence tests)."""
    rng = np.random.default_rng(seed)
    server_state = algorithm.server_init(params)
    state_store = {} if state_store is None else state_store
    for rnd in range(n_rounds):
        ids = rng.choice(sorted(data_by_client),
                         size=min(clients_per_round, len(data_by_client)),
                         replace=False)
        results = []
        for c in ids:
            c = int(c)
            state = state_store.get(c)
            if algorithm.stateful and state is None:
                state = algorithm.client_init_state(params)
            payload = algorithm.broadcast_payload(params, server_state)
            res, new_state = algorithm.client_update(
                payload, data_by_client[c], state)
            if algorithm.stateful and new_state is not None:
                state_store[c] = new_state
            results.append(res)
        agg = flat_aggregate(results, algorithm.ops())
        agg["_n_selected"] = len(results)
        params, server_state = algorithm.server_update(
            params, agg, server_state, len(data_by_client))
    return params, server_state
