"""Parrot server — Algorithm 2 (``Server_Executes``) over a pluggable
round engine.

One ``ParrotServer`` owns: the FL algorithm, the heterogeneity-aware
scheduler + workload estimator, K sequential executors, the client state
managers, a Communicator, and (optionally) a checkpoint manager and a delta
compressor.  ``run_round`` delegates to a :class:`~repro.core.engine.
RoundEngine` — the synchronization policy is a constructor knob
(``round_engine=``, DESIGN.md §3):

  bsp        — the paper's loop, strict barrier:
               select clients → Task_Schedule (Alg. 3) → broadcast Θ^r +
               queues → Device_Executes on each executor → collect K
               partials (one trip each) → GlobalAggregate → server update.
               Round time is ``max_k Σ_{m∈M_k} T̂_{m,k}`` — the makespan the
               scheduler minimises.
  semi-sync  — over-select, fold whatever landed by a model-derived
               virtual-time deadline, carry the rest to the next round.
  async      — fold chunk partials as they land with a bounded-staleness
               weight; update every ``clients_per_round`` folds; idle
               executors steal from the predicted-slowest queue.

Executor failures mid-round are engine events: the dead executor's
*remaining* work re-runs on the survivors (clients are idempotent within a
round: state saves are keyed per round) and K shrinks for subsequent rounds
(elastic membership).

``mode="parrot"`` uses hierarchical aggregation; ``mode="flat"`` emulates
SD-Dist/FA-Dist accounting (every client result shipped to the server
individually) for the Table-1 comparison benchmarks.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.comm.base import Communicator
from repro.comm.local import LocalComm
from repro.core.aggregation import (flat_aggregate, global_aggregate,
                                    is_flat_partial, tree_reduce_partials)
from repro.core.algorithms import ClientData, FLAlgorithm
from repro.core.executor import SequentialExecutor
from repro.core.population import ClientPopulation, as_population
from repro.core.faults import FaultInjector, FaultPlan, RetryPolicy
from repro.core.network import ClientAvailability, NetworkModel
from repro.core.placement import DevicePlacement
from repro.core.scheduler import ClientTask, ParrotScheduler, Schedule
from repro.core.workload import WorkloadEstimator


@dataclass
class RoundMetrics:
    round: int
    makespan: float               # BSP round time (max executor virtual time)
    wall_time: float
    schedule_time: float
    estimate_time: float
    predicted_makespan: float
    comm_bytes: int
    comm_trips: int
    n_clients: int
    n_executors: int
    estimation_error: float = float("nan")
    failures: int = 0
    # deliberately Any-valued: alongside scalar counters/gauges this carries
    # the nested state-manager stats dict and per-executor utilization dict.
    # The full key schema lives in telemetry.EXTRA_SCHEMA / DESIGN.md §13;
    # a server with telemetry attached mirrors every numeric key into the
    # typed MetricsRegistry at round commit.
    extra: Dict[str, Any] = field(default_factory=dict)


class ParrotServer:
    def __init__(self, *, params: Any, algorithm: FLAlgorithm,
                 executors: Sequence[SequentialExecutor],
                 data_by_client: Dict[int, ClientData],
                 clients_per_round: int,
                 scheduler_policy: str = "parrot",
                 time_window: int = 0,
                 warmup_rounds: int = 1,
                 comm: Optional[Communicator] = None,
                 compressor: Optional[Any] = None,
                 checkpoint_manager: Optional[Any] = None,
                 mode: str = "parrot",
                 parallel_dispatch: bool = False,
                 overlap_scheduling: bool = False,
                 backup_fraction: float = 0.0,
                 round_engine: str = "bsp",
                 engine_opts: Optional[Dict[str, Any]] = None,
                 placement: Optional[DevicePlacement] = None,
                 gang_dispatch: bool = True,
                 network: Optional[NetworkModel] = None,
                 availability: Optional[ClientAvailability] = None,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None,
                 control: Optional[Any] = None,
                 telemetry: Optional[Any] = None,
                 fold_fan_in: int = 16,
                 seed: int = 0):
        from repro.core.engine import make_engine
        self.params = params
        self.algorithm = algorithm
        self.executors: Dict[int, SequentialExecutor] = {e.id: e for e in executors}
        # device placement (DESIGN.md §8): an explicit placement pins the
        # executors here; otherwise one is derived from executors that were
        # constructed pre-pinned (``device=``).  None = the single default
        # device, bit-for-bit the pre-multi-device behaviour.
        if placement is not None:
            placement.assign(executors)
        else:
            pins = {e.id: e.device for e in executors
                    if getattr(e, "device", None) is not None}
            if pins:
                placement = DevicePlacement.from_pins(pins)
        self.placement = placement
        # SPMD gang dispatch of gangable BSP rounds (no-op without a
        # multi-device placement; see engine.BSPEngine._dispatch)
        self.gang_dispatch = bool(gang_dispatch)
        # the population axis (DESIGN.md §11): a plain dict wraps into an
        # EagerPopulation (cached sorted-id registry); a ClientPopulation —
        # e.g. a registry-backed LazyPopulation streaming batches through a
        # bounded fetch cache — passes through, so dataset memory can stay
        # O(cohort) at million-client scale.  ``data_by_client`` stays the
        # read path everywhere (populations are Mappings).
        self.population: ClientPopulation = as_population(data_by_client)
        self.data_by_client = self.population
        self.clients_per_round = clients_per_round
        # hierarchical aggregation (executor → group → server): partial
        # lists wider than this fold through a fan-in tree of merge_partials
        # levels so server-side buffers stay O(fan_in), not O(K).  At or
        # below the fan-in (every pinned small-K configuration) the flat
        # left-fold runs unchanged — bit-exactly the legacy path.
        # ``fold_fan_in=0`` disables the tree outright.
        self.fold_fan_in = int(fold_fan_in)
        # previous cumulative state-manager counters (per-round deltas for
        # RoundMetrics.extra["state_manager"])
        self._sm_stats_prev: Dict[str, float] = {}
        self.estimator = WorkloadEstimator(time_window=time_window)
        self.scheduler = ParrotScheduler(self.estimator,
                                         warmup_rounds=warmup_rounds,
                                         policy=scheduler_policy)
        self.comm = comm or LocalComm()
        if isinstance(compressor, str):
            # convenience: compressor="topk"/"int8"/"powersgd" builds the
            # compiled default via make_compressor
            from repro.core.compression import make_compressor
            compressor = make_compressor(compressor)
        self.compressor = compressor
        self.checkpoint_manager = checkpoint_manager
        self.mode = mode
        # trace-driven network & availability simulation (DESIGN.md §9):
        # None for both (the default) keeps every engine on its pre-network
        # code path bit-exactly — params AND makespan histories unchanged
        self.network = network
        self.availability = availability
        # fault injection (DESIGN.md §10): a seeded FaultPlan schedules
        # crashes / restarts / dropouts / corruption / blackouts / slowdowns
        # on the virtual axis; None (the default) keeps every engine on its
        # pre-fault code path bit-exactly.  An empty plan behaves
        # identically to None (pinned by the equivalence tests).
        self.faults: Optional[FaultInjector] = (
            FaultInjector(faults, retry) if faults is not None
            or retry is not None else None)
        # adaptive control plane (DESIGN.md §12): self-tuning λ / deadline
        # controllers, window-fit selection, comm overlap, gang waves and
        # queue rebalancing, plus oracle-gap tracking.  None (the default)
        # keeps every engine on its pre-control code path bit-exactly, and
        # ControlPlane.observer() is pinned behaviour-identical to None.
        self.control = control
        # virtual-time telemetry (DESIGN.md §13): span tracer + metrics
        # registry + utilization accounting.  None (the default) is
        # consulted nowhere — every engine stays bit-exact (params AND
        # makespans); ``telemetry=True`` builds a default bundle.  The same
        # object is shared with the fault injector and control plane so
        # their events land on the common lanes.
        if telemetry is True:
            from repro.core.telemetry import Telemetry
            telemetry = Telemetry()
        self.telemetry = telemetry
        if telemetry is not None:
            if self.faults is not None:
                self.faults.telemetry = telemetry
                telemetry.trace_plan(self.faults.plan)
            if control is not None and hasattr(control, "telemetry"):
                control.telemetry = telemetry
        # crashed executors park here so a scheduled restart (or a
        # checkpoint restore of a pre-crash topology) can revive them
        self._retired: Dict[int, SequentialExecutor] = {}
        # cumulative simulated time across rounds — the availability axis
        # (BSP / semi-sync advance it by each round's makespan; async pins
        # it to its persistent clock)
        self.virtual_now = 0.0
        self._last_payload_nbytes = 0    # comm-cost estimates (round r-1's)
        self._wire_ratio = 1.0           # achieved wire/raw compression ratio
        self.parallel_dispatch = parallel_dispatch
        self.overlap_scheduling = overlap_scheduling
        self.backup_fraction = backup_fraction
        self._next_tasks: Optional[List[ClientTask]] = None
        self.server_state = algorithm.server_init(params)
        self.rng = np.random.default_rng(seed)
        self.round = 0
        self.history: List[RoundMetrics] = []
        self._pending_schedule: Optional[Schedule] = None
        self.engine = make_engine(round_engine, **(engine_opts or {}))
        if self.engine.mode != "bsp":
            # BSP-specific knobs would silently no-op under the DES engines
            # (which serialize execution and mitigate tails via deadline
            # carry-over / work stealing instead of backups) — fail loudly
            for knob, val in (("backup_fraction", backup_fraction),
                              ("parallel_dispatch", parallel_dispatch),
                              ("overlap_scheduling", overlap_scheduling)):
                if val:
                    raise ValueError(
                        f"{knob} only applies to round_engine='bsp' "
                        f"(got {self.engine.mode!r})")

    # ------------------------------------------------------------------
    def select_clients(self, n: Optional[int] = None,
                       exclude: Optional[Any] = None) -> List[ClientTask]:
        """Sample the round's cohort without replacement.  ``n`` overrides
        ``clients_per_round`` (semi-sync over-selection, async refills);
        ``exclude`` removes clients already in flight.  With an availability
        model, clients offline at the current virtual time are filtered
        before sampling.

        Cost is O(cohort), not O(M log M): the population keeps a cached
        sorted-id registry, draws positional indices into the virtual
        (ids minus exclude) pool and rank-adjusts past the excluded
        positions — rng-identical to the original
        ``rng.choice(sorted_pool, ...)`` (pinned by tests/test_population.
        py), so every engine bit-exactness pin holds.  Availability/fault
        filters apply per candidate without materialising a boxed-int
        pool.  Task sample counts come from the registry, so selection
        never materialises client batches."""
        filters = []
        if self.availability is not None:
            av, now = self.availability, self.virtual_now
            filters.append(lambda c: av.available(c, now))
            ctrl = self.control
            if ctrl is not None and getattr(ctrl, "window_fit", False):
                # window-fit selection (DESIGN.md §12): skip clients whose
                # availability window can't hold their predicted span (+
                # comm round-trip) — they'd only land a dispatch-time skip
                # or a lost upload.  Needs at least one fitted model (the
                # fleet average prices executor-agnostically, since the
                # client isn't scheduled yet); before the first fit this
                # filter is inert, preserving the warmup cohort.
                from repro.core.workload import fleet_average
                avg = fleet_average(self.estimator.last_fit)
                if avg is not None:
                    n_of = self.population.n_samples
                    net, down = self.network, self._last_payload_nbytes
                    up = int(down * self._wire_ratio)

                    def _fits(c, av=av, now=now, avg=avg, n_of=n_of,
                              net=net, down=down, up=up):
                        dur = avg.predict(n_of(c))
                        if net is not None:
                            dur += net.client_comm_time(c, down, up)
                        return av.fits(c, now, dur)

                    filters.append(_fits)
        if self.faults is not None:
            fi, now = self.faults, self.virtual_now
            filters.append(lambda c: not fi.client_down(c, now))
        ids = self.population.sample(
            self.rng, self.clients_per_round if n is None else n,
            exclude=exclude, filters=filters)
        n_of = self.population.n_samples
        return [ClientTask(c, n_of(c)) for c in ids]

    # ------------------------------------------------------------------
    def _plan_backups(self, schedule: Schedule
                      ) -> Tuple[Dict[int, Set[int]], int]:
        """Speculative backup tasks (tail mitigation at 1000-node scale):
        duplicate the tail of the predicted-slowest queue onto the
        predicted-fastest executor and tell the slow executor to skip those
        clients (the ``skip_clients`` hook) — each client still folds exactly
        once, so aggregation stays exact, and if either executor dies the
        normal leftover re-run covers the duplicated clients."""
        if self.backup_fraction <= 0 or len(self.executors) < 2:
            return {}, 0
        models = self.estimator.last_fit

        def load(k: int) -> float:
            m = models.get(k)
            q = schedule.queue(k)
            if m is not None:
                return sum(m.predict(t.n_samples) for t in q)
            return float(sum(t.n_samples for t in q))

        ks = list(self.executors)
        slow = max(ks, key=load)
        fast = min(ks, key=load)
        queue = schedule.queue(slow)
        if slow == fast or not queue:
            return {}, 0
        n = min(len(queue), max(1, int(round(self.backup_fraction
                                             * len(queue)))))
        tail = queue[-n:]
        schedule.assignment.setdefault(fast, []).extend(tail)
        return {slow: {t.client for t in tail}}, len(tail)

    def global_fold(self, partials: List[Dict]) -> Dict[str, Any]:
        """``GlobalAggregate`` routed through the device placement when one
        is active: device-resident flat partials reduce with one sharded
        psum per weight group (or colocating D2D left-folds — both
        bit-identical to the host path), landing on the server device.  The
        engines call this instead of ``global_aggregate`` directly.

        Partial lists wider than ``fold_fan_in`` first reduce through the
        hierarchical fan-in tree (executor → group → server, reusing the
        flat incremental fold at each level) so the final reduce — and the
        placement's collective — sees at most ``fold_fan_in`` partials.  At
        or below the fan-in this is byte-for-byte the legacy flat
        left-fold."""
        ops = self.algorithm.ops()
        if (self.fold_fan_in > 1 and len(partials) > self.fold_fan_in
                and all(is_flat_partial(p) for p in partials)):
            partials = tree_reduce_partials(partials, self.fold_fan_in)
        if self.placement is not None:
            return self.placement.global_fold(partials, ops)
        return global_aggregate(partials, ops)

    def _state_manager_extra(self) -> Optional[Dict[str, Any]]:
        """Per-round client-state cache observability: cumulative
        ``ClientStateManager.stats`` counters (deduped across executors
        sharing one manager) are diffed against the previous round, and the
        current tier byte gauges are attached as-is.  Engines put the
        result under ``RoundMetrics.extra["state_manager"]``."""
        managers = {}
        for ex in self.executors.values():
            sm = getattr(ex, "state_manager", None)
            if sm is not None and hasattr(sm, "stats_snapshot"):
                managers[id(sm)] = sm
        if not managers or not self.algorithm.stateful:
            return None
        total: Dict[str, float] = {}
        for sm in managers.values():
            for key, val in sm.stats_snapshot().items():
                total[key] = total.get(key, 0) + val
        out: Dict[str, float] = {}
        for key, val in total.items():
            if key.endswith("_bytes"):
                out[key] = val                               # gauge
            else:
                out[key] = val - self._sm_stats_prev.get(key, 0)
        self._sm_stats_prev = total
        return out

    def _drop_executor(self, k: int) -> None:
        """Elastic K shrink: retire a dead executor (and release its device
        pin).  The object parks in ``_retired`` so a scheduled restart can
        rejoin it later — its measured block costs survive the outage."""
        ex = self.executors.pop(k, None)
        if ex is not None:
            self._retired[k] = ex
        if self.placement is not None:
            self.placement.release(k)

    def _revive_executor(self, k: int) -> bool:
        """A crashed executor rejoins (restart fault event / restore of a
        pre-crash topology): re-pin it through the placement's deterministic
        least-loaded choice and put it back in the live set.  Subsequent
        schedules see K grow again.  False if ``k`` is not revivable."""
        ex = self._retired.pop(k, None)
        if ex is None or k in self.executors:
            return False
        if self.placement is not None:
            ex.set_device(self.placement.pin(k))
        self.executors[k] = ex
        # canonical live order: plain insertion would park the revived k at
        # the dict's tail, making round iteration (dispatch and fold order)
        # depend on the process's crash history — a resumed process rebuilds
        # the dict in constructor order and would fold in a different order,
        # breaking bit-exact auto-resume
        if list(self.executors) != sorted(self.executors):
            self.executors = {j: self.executors[j]
                              for j in sorted(self.executors)}
        return True

    # ------------------------------------------------------------------
    # network/availability plumbing (no-ops when both are None)
    def _sched_comm_cost(self):
        """Per-task comm-cost closure for the scheduler's Eq. 4 (None when
        no network is modelled).  Prices one client round-trip at the last
        broadcast's size and the compressor's last achieved wire ratio —
        round 0 prices latency only (no payload has been sized yet), which
        the uniform warmup schedule ignores anyway."""
        if self.network is None:
            return None
        net, down = self.network, self._last_payload_nbytes
        up = int(down * self._wire_ratio)
        return lambda task: net.client_comm_time(task.client, down, up)

    def _next_available_time(self, exclude: Optional[Any] = None) -> float:
        """Earliest virtual time any selectable client comes online (inf if
        never) — the engines fast-forward an empty round to it."""
        if self.availability is None:
            return self.virtual_now
        ex = {int(c) for c in (exclude or ())}
        return min((self.availability.next_available(int(c), self.virtual_now)
                    for c in self.population.ids_array()
                    if int(c) not in ex), default=float("inf"))

    def _next_availability_change(self, exclude: Optional[Any] = None
                                  ) -> float:
        """Earliest FUTURE instant any selectable client's availability
        flips: window start for offline clients, window *end* for online
        ones.  The fast-forward target when a round made zero progress even
        though clients are nominally online — every dropped client was
        predicted to expire mid-chunk, and within its current window that
        prediction can only get worse, so time must jump past a window
        boundary for the availability state to change at all."""
        if self.availability is None:
            return float("inf")
        t = self.virtual_now
        best = float("inf")
        ex = {int(c) for c in (exclude or ())}
        for c in self.population.ids_array():
            c = int(c)
            if c in ex:
                continue
            if self.availability.available(c, t):
                r = self.availability.remaining(c, t)
                if math.isfinite(r) and r > 0:
                    best = min(best, t + r)
            else:
                nxt = self.availability.next_available(c, t)
                if nxt > t:
                    best = min(best, nxt)
        return best

    def _maybe_compress(self, partial: Dict,
                        executor: Optional[int] = None) -> Dict:
        if self.compressor is None:
            return partial
        # key stateful compressor state (top-k error-feedback residuals) by
        # the sending executor: each executor owns its residual stream, so
        # compressed values don't depend on cross-executor ship order
        return self.compressor.compress_partial(
            partial, key=None if executor is None else f"exec{executor}")

    def _maybe_decompress(self, partial: Dict) -> Dict:
        if self.compressor is None:
            return partial
        return self.compressor.decompress_partial(partial)

    # ------------------------------------------------------------------
    def _commit_metrics(self, metrics: RoundMetrics, t0: float) -> None:
        """Round-boundary commit: every engine routes its finished
        RoundMetrics through here with the round window's virtual start
        time.  With telemetry attached, the round's extra is ingested into
        the metrics registry and per-executor busy/comm/idle fractions over
        ``[t0, t0 + makespan]`` land in ``metrics.extra["utilization"]``
        BEFORE the metrics join history (so checkpointed history carries
        them); without it this is exactly ``history.append``."""
        if self.telemetry is not None:
            self.telemetry.on_round(self, metrics, t0)
        self.history.append(metrics)

    def run_round(self) -> RoundMetrics:
        """One server round under the configured engine: a full BSP barrier,
        a deadline-bounded semi-sync round, or one bounded-staleness update
        window (see ``core/engine.py``)."""
        return self.engine.run_round(self)

    def run(self, n_rounds: int,
            auto_resume: bool = False) -> List[RoundMetrics]:
        """Run rounds.  With ``auto_resume=True``, first restore the newest
        valid checkpoint (walking past torn/corrupt ones) and then run until
        ``n_rounds`` TOTAL rounds have completed — the crash-recovery entry
        point: after a mid-round kill, a fresh server constructed with the
        same configuration resumes from the last durable round boundary and
        replays deterministically (params digest matches the uninterrupted
        run).  Without it, behaviour is unchanged: ``n_rounds`` more rounds
        from wherever the server stands."""
        if not auto_resume:
            return [self.run_round() for _ in range(n_rounds)]
        if self.checkpoint_manager is None:
            raise ValueError("auto_resume needs a checkpoint_manager")
        from repro.checkpoint.manager import restore_latest
        restore_latest(self, self.checkpoint_manager.directory)
        while self.round < n_rounds:
            self.run_round()
        return list(self.history[:n_rounds])


def run_flat_reference(params, algorithm: FLAlgorithm,
                       data_by_client: Dict[int, ClientData],
                       clients_per_round: int, n_rounds: int, seed: int = 0,
                       state_store: Optional[Dict[int, Any]] = None):
    """Single-process original-FL reference (SP scheme): the ground truth the
    hierarchical scheme must match (used by the Fig. 4 equivalence tests)."""
    rng = np.random.default_rng(seed)
    server_state = algorithm.server_init(params)
    state_store = {} if state_store is None else state_store
    for rnd in range(n_rounds):
        ids = rng.choice(sorted(data_by_client),
                         size=min(clients_per_round, len(data_by_client)),
                         replace=False)
        results = []
        for c in ids:
            c = int(c)
            state = state_store.get(c)
            if algorithm.stateful and state is None:
                state = algorithm.client_init_state(params)
            payload = algorithm.broadcast_payload(params, server_state)
            res, new_state = algorithm.client_update(
                payload, data_by_client[c], state)
            if algorithm.stateful and new_state is not None:
                state_store[c] = new_state
            results.append(res)
        agg = flat_aggregate(results, algorithm.ops())
        agg["_n_selected"] = len(results)
        params, server_state = algorithm.server_update(
            params, agg, server_state, len(data_by_client))
    return params, server_state
