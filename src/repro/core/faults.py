"""Fault injection & recovery (DESIGN.md §10).

Parrot's pitch is simulation→production without code change, but production
means executors crash, uploads stall and payloads corrupt.  This module
makes those behaviours first-class *virtual-time events* so every recovery
path is exercised — and testable — inside the deterministic simulation:

* :class:`FaultEvent` — one declarative fault on the virtual-time axis.
  Kinds:

  - ``crash``    — executor ``executor`` dies at ``time`` (in-flight chunk
                   lost; queue re-homes through the engine's failure path;
                   K shrinks until a matching ``restart``).
  - ``restart``  — a previously crashed executor rejoins at ``time`` (its
                   device pin is re-established through
                   ``DevicePlacement.pin`` / ``SequentialExecutor.
                   set_device``; it picks up work at the next schedule).
  - ``dropout``  — client ``client`` goes offline for ``duration`` seconds
                   starting at ``time``.  A chunk *dispatched* into the
                   window loses the client up front (mid-compute dropout);
                   an upload whose flight window contains the dropout start
                   is lost in transit (mid-upload dropout) and the chunk's
                   clients re-enter the engine's re-run pool.
  - ``corrupt``  — the next partial executor ``executor`` ships at or after
                   ``time`` arrives corrupted; the server detects and
                   discards it, and the chunk's clients retry through the
                   re-run pool (bounded by the :class:`RetryPolicy`).
  - ``blackout`` — no traffic moves on the (global, or ``executor``-local)
                   server link during ``[time, time+duration)``; transfers
                   in flight pause and resume, which can trip the chunk
                   timeout.
  - ``slowdown`` — executor ``executor`` computes ``factor``× slower during
                   ``[time, time+duration)``; chunk virtual durations AND
                   the scheduler's span predictions both see the factor.

* :class:`FaultPlan` — an immutable, seeded collection of events.
  ``FaultPlan.random`` synthesizes a chaos plan deterministically from a
  seed (crashes always paired with restarts; ``spare`` executors are never
  crashed so the run cannot lose its last device).

* :class:`RetryPolicy` — chunk-level timeouts with bounded retry and
  exponential backoff, all priced on the virtual clock: a chunk upload that
  exceeds ``timeout_s`` (e.g. across a blackout) is re-sent after
  ``backoff_s · mult^(attempt-1)`` and re-priced through the network model;
  a client whose chunk keeps failing (corruption, lost uploads) re-runs at
  most ``max_retries`` times before it is dropped from the round.

* :class:`FaultInjector` — the runtime the engines consult.  The plan is
  immutable; the injector's only mutable state is which one-shot events
  (crashes, restarts, corruptions) have fired and each client's retry
  budget — a tiny plain-data blob that checkpoints with the server, so a
  killed run resumed with ``auto_resume=True`` replays the remaining faults
  deterministically.

With ``faults=None`` (the default) none of this is consulted and every
engine keeps its pre-fault code path bit-exactly.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

CRASH = "crash"
RESTART = "restart"
DROPOUT = "dropout"
CORRUPT = "corrupt"
BLACKOUT = "blackout"
SLOWDOWN = "slowdown"

KINDS = (CRASH, RESTART, DROPOUT, CORRUPT, BLACKOUT, SLOWDOWN)


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault on the virtual-time axis (plain data: pickles
    into checkpoint blobs and across process boundaries)."""
    time: float
    kind: str
    executor: Optional[int] = None     # crash/restart/corrupt/slowdown;
    #                                    blackout: None = the global link
    client: Optional[int] = None       # dropout
    duration: float = 0.0              # dropout/blackout/slowdown window
    factor: float = 1.0                # slowdown multiplier (>= 1 slows)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {KINDS}")

    @property
    def end(self) -> float:
        return self.time + max(self.duration, 0.0)


@dataclass(frozen=True)
class RetryPolicy:
    """Chunk timeout / retry / backoff knobs, priced on the virtual clock.

    ``timeout_s`` bounds one upload attempt (inf disables the timeout);
    ``max_retries`` bounds per-client re-runs after corruption or payload
    loss AND per-upload re-sends after a timeout; ``backoff_s`` is the base
    delay before retry 1, doubling (``backoff_mult``) per further attempt.
    """
    timeout_s: float = math.inf
    max_retries: int = 2
    backoff_s: float = 1.0
    backoff_mult: float = 2.0

    def backoff(self, attempt: int) -> float:
        """Delay before retry ``attempt`` (1-based)."""
        return self.backoff_s * (self.backoff_mult ** max(attempt - 1, 0))


class FaultPlan:
    """An immutable, seeded, declarative set of fault events.

    Events are kept sorted by ``(time, kind, executor, client)`` so every
    consumer sees one canonical order; the ``seed`` is carried for
    provenance (two plans built from the same seed are identical).
    """

    def __init__(self, events: Iterable[FaultEvent], seed: Optional[int] = None):
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(
            events, key=lambda e: (e.time, KINDS.index(e.kind),
                                   -1 if e.executor is None else e.executor,
                                   -1 if e.client is None else e.client)))
        self.seed = seed
        for ev in self.events:
            if ev.kind in (CRASH, RESTART, CORRUPT, SLOWDOWN) \
                    and ev.executor is None:
                raise ValueError(f"{ev.kind} event needs an executor: {ev}")
            if ev.kind == DROPOUT and ev.client is None:
                raise ValueError(f"dropout event needs a client: {ev}")

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def of_kind(self, kind: str) -> List[FaultEvent]:
        return [e for e in self.events if e.kind == kind]

    # ------------------------------------------------------------------
    @classmethod
    def random(cls, *, seed: int, horizon: float,
               executors: Sequence[int], clients: Sequence[int],
               crash_rate: float = 0.0, restart_delay: float = 5.0,
               dropout_rate: float = 0.0, dropout_duration: float = 5.0,
               corrupt_rate: float = 0.0,
               blackout_rate: float = 0.0, blackout_duration: float = 2.0,
               slowdown_rate: float = 0.0, slowdown_duration: float = 5.0,
               slowdown_factor: float = 4.0,
               spare: int = 1) -> "FaultPlan":
        """Seeded chaos plan over ``[0, horizon)`` virtual seconds.

        ``*_rate`` are expected events per virtual second (Poisson counts,
        uniform placement — all drawn from one ``np.random.default_rng(seed)``
        stream, so the plan is a pure function of its arguments).  Every
        crash is paired with a restart ``restart_delay`` later; the first
        ``spare`` executors (sorted order) are never crashed, so a plan can
        never strand the run with zero live executors.
        """
        rng = np.random.default_rng(seed)
        executors = sorted(executors)
        clients = sorted(clients)
        crashable = executors[spare:] if spare > 0 else list(executors)
        events: List[FaultEvent] = []

        def times(rate: float) -> np.ndarray:
            n = rng.poisson(rate * horizon)
            return np.sort(rng.uniform(0.0, horizon, size=n))

        if crashable:
            # at most one outstanding crash per executor: pair each crash
            # with its restart before the executor may crash again
            busy_until = {k: 0.0 for k in crashable}
            for t in times(crash_rate):
                k = int(rng.choice(crashable))
                if t < busy_until[k]:
                    continue
                events.append(FaultEvent(time=float(t), kind=CRASH,
                                         executor=k))
                events.append(FaultEvent(time=float(t + restart_delay),
                                         kind=RESTART, executor=k))
                busy_until[k] = t + restart_delay
        if clients:
            for t in times(dropout_rate):
                c = int(rng.choice(clients))
                events.append(FaultEvent(time=float(t), kind=DROPOUT,
                                         client=c,
                                         duration=float(dropout_duration)))
        for t in times(corrupt_rate):
            k = int(rng.choice(executors))
            events.append(FaultEvent(time=float(t), kind=CORRUPT, executor=k))
        for t in times(blackout_rate):
            events.append(FaultEvent(time=float(t), kind=BLACKOUT,
                                     duration=float(blackout_duration)))
        for t in times(slowdown_rate):
            k = int(rng.choice(executors))
            events.append(FaultEvent(time=float(t), kind=SLOWDOWN,
                                     executor=k,
                                     duration=float(slowdown_duration),
                                     factor=float(slowdown_factor)))
        return cls(events, seed=seed)


@dataclass
class FaultCounters:
    """Per-round fault accounting — the engines zero one of these each
    round and surface it through the unified ``RoundMetrics`` schema."""
    retries: int = 0
    corrupt_payloads: int = 0
    dropped_clients: int = 0
    crashes: int = 0
    restarts: int = 0
    timeouts: int = 0
    quorum_commits: int = 0


class FaultInjector:
    """Runtime view of a :class:`FaultPlan` + :class:`RetryPolicy`.

    Almost every query is a pure function of the immutable plan; the only
    mutable state is the set of fired one-shot events (crashes, restarts,
    corruptions — each fires exactly once) and the per-client retry
    budgets, which ``state_dict``/``load_state_dict`` round-trip through
    checkpoints so a resumed run replays the remaining faults exactly.
    """

    def __init__(self, plan: Optional[FaultPlan],
                 retry: Optional[RetryPolicy] = None):
        self.plan = plan if plan is not None else FaultPlan(())
        self.retry = retry or RetryPolicy()
        # wired by ParrotServer when telemetry is attached: crash / timeout
        # / resend instants land on the shared lanes (pure recording — not
        # part of state_dict, never consulted for behaviour)
        self.telemetry: Optional[Any] = None
        # one-shot events by index into plan.events
        self._fired: Set[int] = set()
        self._retry_count: Dict[int, int] = {}     # client -> failed runs
        # fast per-kind views (index, event) preserving canonical order
        self._by_kind: Dict[str, List[Tuple[int, FaultEvent]]] = {
            k: [] for k in KINDS}
        for i, ev in enumerate(self.plan.events):
            self._by_kind[ev.kind].append((i, ev))

    # -- checkpointing -----------------------------------------------------
    def state_dict(self) -> Dict[str, Any]:
        return {"fired": sorted(self._fired),
                "retry_count": dict(self._retry_count)}

    def load_state_dict(self, state: Optional[Dict[str, Any]]) -> None:
        if not state:
            return
        self._fired = set(state["fired"])
        self._retry_count = {int(c): int(n)
                             for c, n in state["retry_count"].items()}

    # -- crash / restart lifecycle -----------------------------------------
    def crash_due(self, executor: int, t: float) -> Optional[float]:
        """Earliest unfired crash for ``executor`` at or before ``t`` (the
        executor is dead *now*), or None.  Does not mark fired — callers
        mark via :meth:`fire` once the engine has actually processed it."""
        for i, ev in self._by_kind[CRASH]:
            if i not in self._fired and ev.executor == executor \
                    and ev.time <= t:
                return ev.time
        return None

    def crash_in(self, executor: int, t_lo: float,
                 t_hi: float) -> Optional[Tuple[int, float]]:
        """Earliest unfired crash for ``executor`` in ``[t_lo, t_hi)`` —
        returns ``(event_index, time)`` or None."""
        for i, ev in self._by_kind[CRASH]:
            if i not in self._fired and ev.executor == executor \
                    and t_lo <= ev.time < t_hi:
                return i, ev.time
        return None

    def fire_crash(self, executor: int, t: float) -> bool:
        """Mark every unfired crash for ``executor`` at or before ``t`` as
        fired (one death consumes stale duplicates too).  True if any."""
        fired = False
        for i, ev in self._by_kind[CRASH]:
            if i not in self._fired and ev.executor == executor \
                    and ev.time <= t:
                self._fired.add(i)
                fired = True
                if self.telemetry is not None:
                    self.telemetry.tracer.instant(
                        f"exec:{executor}", "crash", ev.time, cat="fault")
        return fired

    def restarts_due(self, t: float) -> List[int]:
        """Executors whose unfired restart events are due at ``t`` (marked
        fired — a restart is consumed whether or not the executor was ever
        down; event order is the canonical plan order)."""
        out: List[int] = []
        for i, ev in self._by_kind[RESTART]:
            if i not in self._fired and ev.time <= t:
                self._fired.add(i)
                out.append(ev.executor)
        return out

    # -- slowdown ----------------------------------------------------------
    def slowdown(self, executor: int, t: float) -> float:
        """Compound slowdown multiplier active on ``executor`` at ``t``
        (1.0 outside every window).  Sampled at a chunk's dispatch time and
        applied to its whole span — windows are long relative to chunks."""
        f = 1.0
        for _, ev in self._by_kind[SLOWDOWN]:
            if ev.executor == executor and ev.time <= t < ev.end:
                f *= max(ev.factor, 0.0)
        return f

    def scaled_model(self, model, executor: int, t: float):
        """The scheduler's fitted model with the active slowdown applied —
        what ``predict_span`` must see so deadline/steal decisions anticipate
        a slowed device (Eq. 2 scales linearly)."""
        if model is None:
            return None
        f = self.slowdown(executor, t)
        if f == 1.0:
            return model
        return replace(model, t_sample=model.t_sample * f, b=model.b * f)

    # -- dropout -----------------------------------------------------------
    def client_down(self, client: int, t: float) -> bool:
        return any(ev.client == client and ev.time <= t < ev.end
                   for _, ev in self._by_kind[DROPOUT])

    def dropout_in(self, client: int, t_lo: float, t_hi: float) -> bool:
        """True when ``client`` is down at ``t_lo`` or a dropout window
        *starts* inside ``[t_lo, t_hi)`` — the mid-compute (dispatch-time)
        and mid-upload (flight-window) checks share this predicate."""
        if self.client_down(client, t_lo):
            return True
        return any(ev.client == client and t_lo <= ev.time < t_hi
                   for _, ev in self._by_kind[DROPOUT])

    def split_up(self, tasks: Sequence[Any], t: float,
                 pred_dur: float) -> Tuple[List[Any], List[Any]]:
        """(up, down) split of a chunk's tasks at dispatch: a client down
        now, or whose dropout window opens inside the chunk's predicted
        span, leaves the chunk before it runs (mid-compute dropout)."""
        up, down = [], []
        for task in tasks:
            (down if self.dropout_in(task.client, t, t + max(pred_dur, 0.0))
             else up).append(task)
        return up, down

    def upload_lost(self, clients: Iterable[int], t_lo: float,
                    t_hi: float) -> bool:
        """Mid-upload dropout: the partial is lost in transit when any
        constituent client's dropout window opens during the flight."""
        return any(self.dropout_in(c, t_lo, t_hi) for c in clients)

    # -- corruption --------------------------------------------------------
    def take_corrupt(self, executor: int, t: float) -> bool:
        """Consume (at most) one pending corruption for a partial shipped
        by ``executor`` at time ``t`` — the oldest unfired corrupt event at
        or before ``t`` fires, exactly once."""
        for i, ev in self._by_kind[CORRUPT]:
            if i not in self._fired and ev.executor == executor \
                    and ev.time <= t:
                self._fired.add(i)
                return True
        return False

    # -- blackout / transfer pricing ---------------------------------------
    def _blackouts(self, executor: Optional[int]
                   ) -> List[Tuple[float, float]]:
        return [(ev.time, ev.end) for _, ev in self._by_kind[BLACKOUT]
                if ev.executor is None or ev.executor == executor]

    def xfer_end(self, t_start: float, duration: float,
                 executor: Optional[int] = None) -> float:
        """Completion time of a transfer starting at ``t_start`` with
        ``duration`` seconds of link time, pausing through every blackout
        window that overlaps it (global windows plus ``executor``-local
        ones).  ``duration`` 0 still waits out a blackout covering
        ``t_start`` — the link is down, nothing moves."""
        t, left = t_start, max(duration, 0.0)
        for a, b in sorted(self._blackouts(executor)):
            if b <= t:
                continue
            if a > t + left:
                break
            # link time spent before this window opens
            left -= max(a - t, 0.0)
            t = max(t, b)
        return t + left

    # -- retry budget ------------------------------------------------------
    def charge_retry(self, clients: Iterable[int]
                     ) -> Tuple[List[int], List[int]]:
        """Charge one failed run against each client's retry budget.
        Returns ``(retry, give_up)``: clients with budget left re-enter the
        engine's re-run pool; the rest are dropped from the round."""
        retry, give_up = [], []
        for c in clients:
            n = self._retry_count.get(c, 0) + 1
            self._retry_count[c] = n
            (retry if n <= self.retry.max_retries else give_up).append(c)
        return retry, give_up

    def clear_retries(self, clients: Iterable[int]) -> None:
        """A successful fold resets the client's budget."""
        for c in clients:
            self._retry_count.pop(c, None)

    # -- upload pricing with timeout/retry ---------------------------------
    def price_upload(self, t_send: float, attempt_s: float, netsim,
                     clients: Sequence[int], nbytes: int,
                     counters: Optional[FaultCounters] = None,
                     executor: Optional[int] = None
                     ) -> Optional[float]:
        """Arrival time of a chunk upload under blackouts + the chunk
        timeout, or None when every attempt timed out (payload lost).

        Attempt 1 starts at ``t_send`` and takes ``attempt_s`` of link
        time, paused through blackouts; an attempt whose wall span exceeds
        ``timeout_s`` is abandoned at the timeout and re-sent after the
        exponential backoff, re-priced through the network model (each
        re-send bills comm time and bytes again — retries are not free).
        """
        timeout = self.retry.timeout_s
        lane = f"exec:{executor}:up" if executor is not None else "net"
        t = t_send
        for attempt in range(self.retry.max_retries + 1):
            if attempt > 0:
                # re-price the re-send through the network model (the link
                # is re-acquired; bytes and comm time bill again)
                attempt_s = (netsim.up(clients, nbytes)
                             if netsim is not None and netsim.net is not None
                             else attempt_s)
                if counters is not None:
                    counters.retries += 1
                if self.telemetry is not None:
                    self.telemetry.tracer.instant(
                        lane, "resend", t, cat="fault",
                        args={"attempt": attempt})
            arrival = self.xfer_end(t, attempt_s, executor)
            if arrival - t <= timeout:
                return arrival
            if counters is not None:
                counters.timeouts += 1
            if self.telemetry is not None:
                self.telemetry.tracer.instant(
                    lane, "timeout", t + timeout, cat="fault",
                    args={"attempt": attempt})
            t = t + timeout + self.retry.backoff(attempt + 1)
        return None


def scale_report(rep, factor: float) -> None:
    """Apply an active slowdown factor to a chunk report in place: the
    chunk's virtual span and each per-client record stretch by ``factor``
    (so the workload estimator learns the slowed device, and the engines'
    busy-until arithmetic prices the slowed chunk).  1.0 is an exact
    no-op — no float op touches the report."""
    if factor == 1.0:
        return
    rep.virtual_time *= factor
    rep.records = [replace(r, time=r.time * factor) for r in rep.records]
