"""Shared virtual-time clock for the event-driven round engines.

The paper's timing experiments run on *virtual* time: executors measure the
wall time of each block of client work and scale it by the speed model's
η_k(r), so heterogeneity experiments are deterministic and fast on
homogeneous hardware.  Under BSP the only consumer of those durations is the
end-of-round ``max_k Σ`` makespan, so each executor could keep its own
running sum.  Under semi-sync and async the *interleaving* of executors
matters — which chunk lands first decides fold order, staleness weights and
work stealing — so the clock moves out of the executors into one shared
discrete-event queue:

* :class:`VirtualClock` orders events by ``(time, seq)`` where ``seq`` is a
  monotonic tie-breaker assigned at push time.  Two events at the same
  virtual time therefore pop in push order, which makes the engines'
  behaviour a pure function of the per-chunk durations — deterministic under
  any ``speed_model``, independent of host scheduling.

* Engines run chunks *lazily*: an executor's next chunk is physically
  executed only when its previous completion event pops, i.e. at the chunk's
  virtual dispatch time.  Every event earlier in virtual time has already
  been processed, so the chunk sees exactly the server state (params
  version, queue contents) that a causally-correct parallel execution would
  have shown it.

The queue is not compute-only: under a network model
(``core/network.py``, DESIGN.md §9) the engines also push comm arrivals —
``"chunk_arrived"`` events carrying a :class:`~repro.core.network.CommEvent`
at ``compute_done + latency + wire_bytes/uplink`` — so uploads interleave
with chunk completions on the same deterministic (time, seq) order, and
``"wake"`` events that fast-forward an availability gap.

Timers: executors take an injectable ``timer`` (default
``time.perf_counter``).  :class:`TickTimer` advances a fixed amount per
call, which makes measured durations a pure function of the *call sequence*
— the bit-exactness tests run the legacy loop and the BSP engine under the
same TickTimer and assert identical makespan histories, proving the call
sequences are identical.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

SystemTimer = time.perf_counter


class TickTimer:
    """Deterministic timer: every call advances virtual wall time by ``dt``.

    Durations measured with a TickTimer depend only on how many timer calls
    the measured span contains — i.e. on the exact code path taken — which is
    what the engine-equivalence tests want to pin down.
    """

    def __init__(self, dt: float = 1.0):
        self.dt = float(dt)
        self.now = 0.0

    def __call__(self) -> float:
        self.now += self.dt
        return self.now


@dataclass(frozen=True)
class Event:
    """One scheduled occurrence: ``kind`` names the handler, ``data`` is the
    engine-defined payload."""
    time: float
    seq: int
    kind: str
    data: Any = field(compare=False, default=None)


class VirtualClock:
    """Deterministic discrete-event queue on the simulated (virtual) axis.

    ``now`` is the virtual time of the last popped event and never moves
    backwards; pushing an event earlier than ``now`` is a causality bug and
    raises.
    """

    def __init__(self):
        self._heap: List[Tuple[float, int, Event]] = []
        self._seq = 0
        self.now: float = 0.0

    def push(self, at: float, kind: str, data: Any = None) -> Event:
        if at < self.now - 1e-12:
            raise ValueError(
                f"event '{kind}' at t={at} is earlier than now={self.now}")
        ev = Event(time=float(at), seq=self._seq, kind=kind, data=data)
        self._seq += 1
        heapq.heappush(self._heap, (ev.time, ev.seq, ev))
        return ev

    # ------------------------------------------------------------------
    # checkpointing (async engine in-flight state): the queue is plain data
    # — (time, seq, kind, data) tuples — plus the seq counter and ``now``.
    # The seq counter must round-trip exactly: it breaks same-time ties, so
    # a resumed clock must keep numbering where the saved one stopped.
    def state_dict(self) -> Dict[str, Any]:
        return {"now": self.now, "seq": self._seq,
                "events": [(ev.time, ev.seq, ev.kind, ev.data)
                           for _, _, ev in sorted(self._heap,
                                                  key=lambda e: e[:2])]}

    @classmethod
    def from_state_dict(cls, state: Dict[str, Any]) -> "VirtualClock":
        clock = cls()
        clock.now = float(state["now"])
        clock._seq = int(state["seq"])
        for t, seq, kind, data in state["events"]:
            ev = Event(time=float(t), seq=int(seq), kind=kind, data=data)
            heapq.heappush(clock._heap, (ev.time, ev.seq, ev))
        return clock

    def pop(self) -> Event:
        _, _, ev = heapq.heappop(self._heap)
        self.now = max(self.now, ev.time)
        return ev

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def drain(self) -> Iterator[Event]:
        while self._heap:
            yield self.pop()
