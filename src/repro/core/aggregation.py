"""Hierarchical (local → global) aggregation with OP-typed parameters
(paper §3.2, §4.2) on a flatten-once flat-buffer layout.

Users declare, per communicated entry, an aggregation OP:

  WEIGHTED_AVG — Σ w_m x_m / Σ w_m        (model params/deltas; FedAvg etc.)
  AVG          — simple mean over clients
  SUM          — Σ x_m                    (counters, control-variate deltas)
  COLLECT      — concatenated per-client values ("Special Params."; cannot be
                 reduced, comm size stays O(s_e · M_p) — paper §4.2)

The decomposition is exact: executors fold their clients into a running
partial (``LocalAggregator``), the server combines the K partials
(``global_aggregate``).  ``flat_aggregate`` is the reference original-FL
aggregation; tests assert bit-level agreement for the reducible OPs.

The fold's inner loop (fp32 ``acc += w · x`` over every model parameter for
every simulated client) is the memory-bound hot-spot of the whole simulator.
``LocalAggregator`` therefore flattens each client's reducible payload ONCE
into a contiguous 1-D buffer per weight group (see ``flat.FlatLayout``),
stages up to ``micro_batch`` (B) client buffers, and folds them with a single
multi-client ``agg_weighted_sum`` call at C=B — one kernel dispatch per
micro-batch instead of one per pytree leaf per client.  ``use_kernel=True``
routes the flush through the Pallas kernel (with buffer donation on the
accumulator when it is not externally visible); ``use_kernel=False`` runs the
bit-identical pure-jnp ``w @ D`` contraction.

The partial's wire format is flat too — ``{"sums": {"__flat__": True,
"buffers": {group: (n,) fp32}}, "layout": FlatLayout, ...}`` — so the comm
layer and the delta compressors move one array per partial instead of a
nested dict of leaves; ``global_aggregate`` combines partials with K-1
buffer adds per group and unflattens once at the end.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.flat import (FlatLayout, flat_sums, is_compressed_buffer,
                             is_flat_partial)


class Op(enum.Enum):
    WEIGHTED_AVG = "weighted_avg"
    AVG = "avg"
    SUM = "sum"
    COLLECT = "collect"


@dataclass(frozen=True)
class ClientResult:
    """What one simulated client returns to its executor.

    ``payload`` maps entry name -> pytree; ``ops`` maps entry name -> Op;
    ``weight`` is the client's aggregation weight (typically N_m).
    """
    payload: Dict[str, Any]
    ops: Dict[str, Op]
    weight: float
    metrics: Dict[str, float] = field(default_factory=dict)


@jax.jit
def _flush_jnp(acc, staged, w):
    """Pure-jnp fused micro-batch flush (bit-identical contraction to the
    kernel path's ``w @ D``)."""
    return acc + jnp.dot(w, jnp.stack(staged).astype(jnp.float32))


@jax.jit
def _fold_stacked_jnp(acc, stacked, w):
    """Pure-jnp fold of an already-stacked (B, n) block (same contraction
    as ``_flush_jnp``, minus the stack)."""
    return acc + jnp.dot(w, stacked.astype(jnp.float32))


class LocalAggregator:
    """Per-executor running aggregate (``LocalAggregate`` in Algorithm 2).

    Memory is O(s_a) plus the staged micro-batch (at most ``micro_batch``
    client buffers) regardless of how many clients the executor simulates —
    this is the paper's memory claim for sequential training.

    ``micro_batch`` (B) controls how many client delta buffers are staged
    before ONE multi-client fold at C=B; the kernel path pads the final
    flush to exactly B with zero-weight rows so only a single (B, n) kernel
    specialisation is ever compiled per layout.
    """

    def __init__(self, ops: Dict[str, Op], use_kernel: bool = False,
                 micro_batch: int = 16,
                 layout: Optional[FlatLayout] = None,
                 device: Optional[Any] = None):
        self.ops = dict(ops)
        self.use_kernel = use_kernel
        self.micro_batch = max(1, int(micro_batch))
        self.layout = layout
        # owning device (device-pinned executors): accumulators, staged
        # buffers and the folds all live there; the partial ships
        # device-resident
        self.device = device
        self._acc: Optional[Dict[str, jnp.ndarray]] = None
        self._staged: Dict[str, List[jnp.ndarray]] = {}
        self._staged_w: Dict[str, List[float]] = {}
        self._exposed = False     # acc arrays escaped via partial(): no donate
        self._weights: Dict[str, float] = {}
        self._counts: Dict[str, int] = {}
        self._collected: Dict[str, List[Any]] = {}
        self.n_clients = 0

    def fold(self, result: ClientResult) -> None:
        self.n_clients += 1
        payload = result.payload
        for name in payload:
            op = self.ops[name]
            if op is Op.COLLECT:
                self._collected.setdefault(name, []).append(
                    (result.weight, payload[name]))
                continue
            w = result.weight if op is Op.WEIGHTED_AVG else 1.0
            self._weights[name] = self._weights.get(name, 0.0) + w
            self._counts[name] = self._counts.get(name, 0) + 1
        self._ensure_acc(payload)
        for g, buf in self.layout.flatten(payload, self.device).items():
            self._staged[g].append(buf)
            self._staged_w[g].append(
                result.weight if g == "weighted" else 1.0)
        if any(len(s) >= self.micro_batch for s in self._staged.values()):
            self._flush()

    def _ensure_acc(self, template_payload: Dict[str, Any]) -> None:
        """Lazily build the layout (from one un-batched template payload)
        and the per-group accumulators / staging buffers."""
        if self.layout is None:
            self.layout = FlatLayout.build(self.ops, template_payload)
        if self._acc is None:
            self._acc = self.layout.zeros(self.device)
            self._staged = {g: [] for g in self._acc}
            self._staged_w = {g: [] for g in self._acc}
            # zero rows that pad the final kernel flush up to B (shared)
            self._pad = {g: jnp.zeros((n,), self.layout.group_dtypes[g])
                         for g, n in self.layout.group_sizes.items()}
            if self.device is not None:
                self._pad = {g: jax.device_put(b, self.device)
                             for g, b in self._pad.items()}

    def fold_block(self, stacked: Dict[str, Any],
                   weights: List[float]) -> None:
        """Fold a whole vmapped client block at once.

        ``stacked`` maps entry name -> pytree with a leading (B, ...) client
        axis — exactly what ``ClientStepEngine.run_block`` emits — and
        ``weights`` holds the B per-client aggregation weights.  Reducible
        entries flatten to one (B, n) buffer per group
        (``FlatLayout.flatten_batch``) and fold with ONE C=B dispatch
        straight into the accumulator; COLLECT entries are sliced out per
        client, as ``global_aggregate`` expects per-client values."""
        B = len(weights)
        self.n_clients += B
        for name in stacked:
            op = self.ops[name]
            if op is Op.COLLECT:
                rows = stacked[name]
                lst = self._collected.setdefault(name, [])
                for i in range(B):
                    lst.append((weights[i],
                                jax.tree.map(lambda x: x[i], rows)))
                continue
            wtot = float(sum(weights)) if op is Op.WEIGHTED_AVG else float(B)
            self._weights[name] = self._weights.get(name, 0.0) + wtot
            self._counts[name] = self._counts.get(name, 0) + B
        if self.layout is None or self._acc is None:
            self._ensure_acc({name: jax.tree.map(lambda x: x[0], val)
                              for name, val in stacked.items()})
        bufs = self.layout.flatten_batch(stacked, self.device)
        for g, D in bufs.items():
            w = jnp.asarray(weights if g == "weighted" else [1.0] * B,
                            jnp.float32)
            if self.use_kernel:
                from repro.kernels import ops as kops
                self._acc[g] = kops.agg_weighted_sum(
                    self._acc[g], D, w, donate=not self._exposed)
            else:
                self._acc[g] = _fold_stacked_jnp(self._acc[g], D, w)
        self._exposed = False

    def _flush(self) -> None:
        """Fold the staged micro-batch: ONE fused C=B dispatch per group."""
        for g, staged in self._staged.items():
            if not staged:
                continue
            t = len(staged)
            w = self._staged_w[g]
            if self.use_kernel:
                from repro.kernels import ops as kops
                B = self.micro_batch
                if t < B:   # zero-weight rows keep the (B, n) shape static
                    staged = staged + [self._pad[g]] * (B - t)
                    w = w + [0.0] * (B - t)
                self._acc[g] = kops.agg_fold_batch(
                    self._acc[g], staged, jnp.asarray(w, jnp.float32),
                    donate=not self._exposed)
            else:
                self._acc[g] = _flush_jnp(
                    self._acc[g], tuple(staged), jnp.asarray(w, jnp.float32))
            self._staged[g] = []
            self._staged_w[g] = []
        self._exposed = False

    def partial(self) -> Dict[str, Any]:
        """The G_k message sent to the server: one trip, O(s_a K) total —
        one flat fp32 buffer per group instead of a nested dict of leaves."""
        if any(self._staged.values()):
            self._flush()
        self._exposed = True    # returned arrays must survive further folds
        return {
            "sums": flat_sums(dict(self._acc) if self._acc is not None else {}),
            "layout": self.layout,
            "weights": dict(self._weights),
            "counts": dict(self._counts),
            "collected": {k: list(v) for k, v in self._collected.items()},
            "n_clients": self.n_clients,
        }


# ---------------------------------------------------------------------------
# staleness weighting (async bounded-staleness engine)
# ---------------------------------------------------------------------------

def _colocate(x: Any, like: Any) -> Any:
    """Place ``x`` so it can combine with ``like`` (device-pinned executors
    produce partials committed to different devices; combining them raises
    in jax unless one side moves — a direct D2D copy, no host round-trip)."""
    from repro.core.placement import colocate
    return colocate(x, like)


def merge_partials(acc: Optional[Dict[str, Any]],
                   partial: Dict[str, Any]) -> Dict[str, Any]:
    """Fold one partial into a running partial-of-partials (same wire
    format), so the async engine's server-side buffer stays O(s_a) no matter
    how many chunk partials land between model updates.  ``acc=None`` starts
    the accumulator (the first partial is copied shallowly so later merges
    never mutate an executor's live buffers).  Flat partials merge
    buffer-wise; legacy nested partials merge per-entry."""
    if acc is None:
        out = dict(partial)
        if is_flat_partial(partial):
            # compressed wire buffers (lazy decompress) decode here in one
            # dispatch per group; the accumulator itself stays dense
            from repro.core.compression import densify_buffer
            out["sums"] = flat_sums(
                {g: (densify_buffer(b) if is_compressed_buffer(b) else b)
                 for g, b in partial["sums"]["buffers"].items()})
        else:
            out["sums"] = dict(partial["sums"])
        out["weights"] = dict(partial.get("weights", {}))
        out["counts"] = dict(partial.get("counts", {}))
        out["collected"] = {k: list(v)
                            for k, v in partial.get("collected", {}).items()}
        return out
    if is_flat_partial(acc) != is_flat_partial(partial):
        raise ValueError("cannot merge flat and nested partials")
    if is_flat_partial(acc):
        la, lp = acc.get("layout"), partial.get("layout")
        if la is not None and lp is not None \
                and la.signature() != lp.signature():
            raise ValueError("flat partials built under different layouts")
        from repro.core.compression import densify_buffer, fold_buffer_into
        bufs = acc["sums"]["buffers"]
        for g, b in partial["sums"]["buffers"].items():
            if g not in bufs:
                bufs[g] = densify_buffer(b) if is_compressed_buffer(b) else b
            elif is_compressed_buffer(b):
                # fused decompress-into-fold: segments add straight into the
                # dense accumulator, no per-partial dense intermediate
                bufs[g] = fold_buffer_into(bufs[g], b)
            else:
                bufs[g] = bufs[g] + _colocate(b, bufs[g])
    else:
        sums = acc["sums"]
        for name, v in partial["sums"].items():
            sums[name] = (jax.tree.map(
                lambda x, y: x + _colocate(y, x), sums[name], v)
                if name in sums else v)
    for field_ in ("weights", "counts"):
        dst = acc[field_]
        for k, v in partial.get(field_, {}).items():
            dst[k] = dst.get(k, 0) + v
    for k, v in partial.get("collected", {}).items():
        acc["collected"].setdefault(k, []).extend(v)
    acc["n_clients"] = acc.get("n_clients", 0) + partial.get("n_clients", 0)
    return acc


def tree_reduce_partials(partials: List[Dict[str, Any]],
                         fan_in: int = 8) -> List[Dict[str, Any]]:
    """Hierarchical aggregation tree (executor → group → server): reduce a
    wide partial list level by level, left-folding contiguous groups of
    ``fan_in`` partials with :func:`merge_partials` (the same O(s)
    incremental flat fold the async buffer uses) until at most ``fan_in``
    remain.  The server-side live buffer at any instant is one group
    accumulator — O(fan_in) partials, not O(K) — and the returned list
    feeds the ordinary flat reduce (or the placement collective)
    unchanged.  A list already at or below ``fan_in`` is returned as-is,
    so narrow folds keep the legacy path byte-for-byte.

    Grouping re-associates the float summation relative to the flat
    left-fold, which is why the engines only route through the tree above
    ``fold_fan_in`` (ISSUE pins bit-identity on the exactly-representable
    payloads of tests/test_flat_aggregation.py)."""
    if fan_in < 2:
        raise ValueError(f"fan_in must be >= 2 (got {fan_in})")
    level = list(partials)
    while len(level) > fan_in:
        nxt = []
        for i in range(0, len(level), fan_in):
            acc: Optional[Dict[str, Any]] = None
            for p in level[i:i + fan_in]:
                acc = merge_partials(acc, p)
            nxt.append(acc)
        level = nxt
    return level


def staleness_weight(staleness: float, lam: float) -> float:
    """Bounded-staleness discount γ = 1 / (1 + λ·s): a partial computed
    against a model ``s`` server versions old contributes with weight γ — it
    still moves the model (no work wasted), but cannot drag it back towards
    where it was ``s`` updates ago at full strength."""
    return 1.0 / (1.0 + lam * max(float(staleness), 0.0))


def scale_partial(partial: Dict[str, Any], gamma: float) -> Dict[str, Any]:
    """Scale a partial's *contribution* by ``gamma`` on the wire format.

    Both the numerators (the flat group buffers, or nested sum leaves) and
    the denominators (per-entry weights and counts) scale together, so a
    γ-scaled partial enters WEIGHTED_AVG / AVG entries with relative weight
    γ versus fresh partials, SUM entries are discounted to γ·Σ, and COLLECT
    entries keep their values with γ-scaled client weights.  ``gamma == 1``
    returns the partial unchanged (no copy)."""
    if gamma == 1.0:
        return partial
    out = dict(partial)
    sums = partial.get("sums", {})
    if is_flat_partial(partial):
        from repro.core.compression import scale_buffer
        out["sums"] = flat_sums(
            {g: (scale_buffer(b, gamma) if is_compressed_buffer(b)
                 else b * gamma)
             for g, b in sums["buffers"].items()})
    else:
        out["sums"] = {name: jax.tree.map(lambda x: x * gamma, v)
                       for name, v in sums.items()}
    out["weights"] = {k: v * gamma
                      for k, v in partial.get("weights", {}).items()}
    out["counts"] = {k: v * gamma
                     for k, v in partial.get("counts", {}).items()}
    out["collected"] = {k: [(w * gamma, v) for w, v in lst]
                        for k, lst in partial.get("collected", {}).items()}
    return out


# ---------------------------------------------------------------------------
# global aggregate
# ---------------------------------------------------------------------------

def _sum_buffers(bufs: List[jnp.ndarray]) -> jnp.ndarray:
    total = bufs[0]
    for b in bufs[1:]:
        total = total + _colocate(b, total)
    return total


def reduce_flat_partials(partials: List[Dict[str, Any]], ops: Dict[str, Op],
                         reduce_fn: Callable[[List[jnp.ndarray]], jnp.ndarray]
                         ) -> Dict[str, Any]:
    """Combine flat partials: ``reduce_fn`` sums the per-group buffers (K-1
    adds here; one sharded collective in ``comm.collective``), then each
    entry is sliced, divided per its OP, and unflattened once."""
    layout = next((p.get("layout") for p in partials
                   if p.get("layout") is not None), None)
    if layout is not None:
        sig = layout.signature()
        for p in partials:
            other = p.get("layout")
            if other is not None and other.signature() != sig:
                raise ValueError("flat partials built under different layouts")
    totals: Dict[str, jnp.ndarray] = {}
    for g in (layout.group_sizes if layout is not None else {}):
        bufs = [p["sums"]["buffers"][g] for p in partials
                if g in p["sums"]["buffers"]]
        if not bufs:
            continue
        if any(is_compressed_buffer(b) for b in bufs):
            # lazily-compressed wire buffers: order-preserving fused
            # decompress-into-fold (reduce_fn — including the sharded psum —
            # needs dense same-device buffers, so the compressed path folds
            # here instead)
            from repro.core.compression import (densify_buffer,
                                                fold_buffer_into)
            total = (densify_buffer(bufs[0])
                     if is_compressed_buffer(bufs[0]) else bufs[0])
            for b in bufs[1:]:
                total = (fold_buffer_into(total, b)
                         if is_compressed_buffer(b)
                         else total + _colocate(b, total))
            totals[g] = total
        else:
            totals[g] = reduce_fn(bufs)
    out: Dict[str, Any] = {}
    for name, op in ops.items():
        if op is Op.COLLECT:
            coll: List[Any] = []
            for p in partials:
                coll.extend(p["collected"].get(name, []))
            out[name] = coll
            continue
        span = layout.spans.get(name) if layout is not None else None
        if span is None or span.group not in totals:
            continue
        seg = totals[span.group][span.offset:span.offset + span.size]
        if op is Op.AVG:
            n = sum(p["counts"].get(name, 0) for p in partials)
            seg = seg / max(n, 1)
        elif op is Op.WEIGHTED_AVG:
            wtot = sum(p["weights"].get(name, 0.0) for p in partials)
            seg = seg / max(wtot, 1e-12)
        out[name] = layout.unflatten_entry(name, seg)
    return out


def global_aggregate(partials: List[Dict[str, Any]],
                     ops: Dict[str, Op]) -> Dict[str, Any]:
    """``GlobalAggregate`` in Algorithm 2: combine the K partials (K-1 sums
    at the server instead of M_p-1).  Flat partials combine buffer-wise —
    one add chain per group; legacy nested partials keep the per-entry
    tree-map path (mixed inputs degrade flat ones to nested)."""
    if partials and all(is_flat_partial(p) for p in partials):
        return reduce_flat_partials(partials, ops, _sum_buffers)
    if any(is_flat_partial(p) for p in partials):
        from repro.core.flat import to_nested_sums
        partials = [dict(p, sums=to_nested_sums(p)) if is_flat_partial(p)
                    else p for p in partials]
    out: Dict[str, Any] = {}
    for name, op in ops.items():
        if op is Op.COLLECT:
            coll: List[Any] = []
            for p in partials:
                coll.extend(p["collected"].get(name, []))
            out[name] = coll
            continue
        sums = [p["sums"][name] for p in partials if name in p["sums"]]
        if not sums:
            continue
        total = jax.tree.map(
            lambda *xs: _sum_buffers(list(xs)) if hasattr(xs[0], "sharding")
            else sum(xs), *sums)
        if op is Op.SUM:
            out[name] = total
        elif op is Op.AVG:
            n = sum(p["counts"].get(name, 0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(n, 1), total)
        else:  # WEIGHTED_AVG
            wtot = sum(p["weights"].get(name, 0.0) for p in partials)
            out[name] = jax.tree.map(lambda a: a / max(wtot, 1e-12), total)
    return out


def flat_aggregate(results: List[ClientResult],
                   ops: Dict[str, Op]) -> Dict[str, Any]:
    """Reference original-FL aggregation (server folds every client) used to
    verify exactness of the hierarchical scheme."""
    agg = LocalAggregator(ops)
    for r in results:
        agg.fold(r)
    return global_aggregate([agg.partial()], ops)


def payload_bytes(tree: Any) -> int:
    """Wire size of a payload/partial: arrays at shape x itemsize (flat group
    buffers included), compressed tensors at their achieved nbytes, scalars
    at 8; layout metadata is free."""
    total = 0
    for a in jax.tree.leaves(tree):
        # CompressedTensor carries shape + a *str* dtype: require a real
        # dtype (itemsize) before the dense branch, else fall to nbytes
        if hasattr(a, "shape") and hasattr(getattr(a, "dtype", None),
                                           "itemsize"):
            total += int(np.prod(a.shape)) * a.dtype.itemsize
        elif hasattr(a, "nbytes"):      # CompressedTensor and friends
            total += int(a.nbytes)
        elif isinstance(a, (int, float, bool)):
            total += 8
    return total


def wire_bytes(payload: Any) -> int:
    """Achieved wire size of a payload: a compressed partial (compressors
    stamp ``_wire_bytes`` on the sums they shrank) counts its compressed
    sums plus the uncompressed rest; everything else is ``payload_bytes``.
    This is the size the comm layer accounts AND the size the network model
    prices uploads at (``core/network.py``) — one definition for both."""
    if isinstance(payload, dict) and "_wire_bytes" in payload:
        rest = {k: v for k, v in payload.items()
                if k not in ("sums", "_wire_bytes")}
        return int(payload["_wire_bytes"]) + payload_bytes(rest)
    return payload_bytes(payload)
